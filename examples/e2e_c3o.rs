//! End-to-end driver (DESIGN.md experiment E7): the complete C3O system on
//! a realistic multi-user workload, proving all layers compose —
//!
//!   Pallas/JAX artifacts → PJRT engine → models → configurator → hub →
//!   simulated cloud → feedback loop.
//!
//! Scenario: a hub is seeded with the full 930-experiment shared corpus
//! (Table I). Twelve users arrive with their own jobs (sizes, parameters
//! and deadlines drawn from realistic ranges), follow the Fig. 4 workflow
//! (fetch → configure → execute → contribute), and the run reports the
//! paper's headline metrics: prediction MAPE against live executions,
//! deadline hit rate vs the requested confidence, total cost, and hub
//! growth. Recorded in EXPERIMENTS.md §E7.
//!
//! Run with:  cargo run --release --example e2e_c3o

use std::sync::Arc;

use c3o::api::service::PredictionService;
use c3o::cloud::{Catalog, CloudProvider, ClusterConfig};
use c3o::configurator::{configure, UserGoals};
use c3o::data::JobKind;
use c3o::hub::{HubClient, HubServer, HubState, Repository, ValidationPolicy};
use c3o::runtime::{Engine, FitBackend, NativeBackend};
use c3o::sim::{generate_all, Executor, GeneratorConfig, JobInput, WorkloadModel};
use c3o::util::prng::Pcg;
use c3o::util::stats;

const CONFIDENCE: f64 = 0.9;

fn user_job(rng: &mut Pcg) -> (JobKind, JobInput) {
    let job = *rng.choose(&JobKind::ALL);
    let input = match job {
        JobKind::Sort => JobInput::new(job, rng.range_f64(10.0, 20.0), vec![]),
        JobKind::Grep => JobInput::new(
            job,
            rng.range_f64(10.0, 20.0),
            vec![*rng.choose(&[0.001, 0.01, 0.1])],
        ),
        JobKind::Sgd => JobInput::new(
            job,
            rng.range_f64(10.0, 30.0),
            vec![*rng.choose(&[10.0, 25.0, 50.0]), *rng.choose(&[10.0, 50.0, 100.0])],
        ),
        JobKind::KMeans => JobInput::new(
            job,
            rng.range_f64(10.0, 20.0),
            vec![rng.range(3, 10) as f64, 0.001],
        ),
        JobKind::PageRank => JobInput::new(
            job,
            rng.range_f64(0.13, 0.44),
            vec![*rng.choose(&[0.05, 0.1, 0.2]), *rng.choose(&[0.01, 0.001, 0.0001])],
        ),
    };
    (job, input)
}

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let backend: Arc<dyn FitBackend> = match Engine::load_default() {
        Ok(e) => {
            println!("[e2e] PJRT engine: {}", e.artifact_dir().display());
            Arc::new(e)
        }
        Err(e) => {
            println!("[e2e] native backend ({e:#})");
            Arc::new(NativeBackend::new())
        }
    };

    // --- Stand up the hub with the full shared corpus.
    let catalog = Catalog::aws_like();
    let state = Arc::new(HubState::new());
    for ds in generate_all(&GeneratorConfig::default(), &catalog)? {
        let mut repo = Repository::new(ds.job, &format!("standard Spark {}", ds.job));
        repo.maintainer_machine = Some("m5.xlarge".to_string());
        repo.data = ds;
        state.insert(repo);
    }
    let service = Arc::new(PredictionService::new(
        state,
        catalog.clone(),
        ValidationPolicy::default(),
        backend.clone(),
    ));
    let server = HubServer::start("127.0.0.1:0", service)?;
    println!("[e2e] hub listening on {}", server.addr);

    // --- The cloud.
    let provider = CloudProvider::new(Catalog::aws_like());
    let executor = Executor::new(&provider, WorkloadModel::default(), 0xE7E2E);

    // --- Users.
    let mut rng = Pcg::seed(0x05E12);
    let mut pct_errors = Vec::new();
    let mut deadline_total = 0usize;
    let mut deadline_hits = 0usize;
    let mut contributions_accepted = 0usize;

    for user in 0..16 {
        let mut client = HubClient::connect(&server.addr.to_string())?;
        let (job, input) = user_job(&mut rng);

        // Fig. 4 step 1-2: fetch the repository.
        let repo = client.get_repo(job)?;

        // Step 3: goals. Deadline from a feasibility-aware draw.
        let model = WorkloadModel::default();
        let mt = catalog.get("m5.xlarge")?;
        let t_fast = model.mean_runtime(mt, 12, &input);
        let t_slow = model.mean_runtime(mt, 2, &input);
        let deadline = t_fast + rng.range_f64(0.5, 1.1) * (t_slow - t_fast);
        let goals = UserGoals { deadline_s: Some(deadline), confidence: CONFIDENCE };

        // Step 4-5: configure.
        let choice = match configure(
            &catalog,
            &repo.data,
            repo.maintainer_machine.as_deref(),
            &input,
            &goals,
            backend.clone(),
        ) {
            Ok(c) => c,
            Err(e) => {
                println!("[user {user:>2}] {job}: infeasible deadline ({e:#}); skipping");
                continue;
            }
        };

        // Execute on the (simulated) public cloud.
        let report = executor.run(
            &ClusterConfig {
                machine_type: choice.machine_type.clone(),
                scale_out: choice.scale_out,
            },
            &input,
            Some(deadline),
        )?;
        let err =
            (choice.predicted_runtime_s - report.record.runtime_s) / report.record.runtime_s;
        pct_errors.push(err.abs() * 100.0);
        deadline_total += 1;
        if report.deadline_met == Some(true) {
            deadline_hits += 1;
        }

        // Step 6: contribute the observation back.
        let mut contrib = c3o::data::Dataset::new(job);
        contrib.push(report.record.clone())?;
        if client.submit_runs(&contrib)?.accepted {
            contributions_accepted += 1;
        }

        println!(
            "[user {user:>2}] {job:<9} {:>5.1} GB -> {} x{:<2} pred {:>6.0}s actual {:>6.0}s ({:>+5.1}%) cost ${:.3} deadline {}",
            input.data_size_gb,
            choice.machine_type,
            choice.scale_out,
            choice.predicted_runtime_s,
            report.record.runtime_s,
            err * 100.0,
            report.cost_usd,
            if report.deadline_met == Some(true) { "HIT" } else { "MISS" },
        );
    }

    // --- Headline report.
    let mut client = HubClient::connect(&server.addr.to_string())?;
    let hub_stats = client.stats()?;
    let (acc, rej) = (hub_stats.accepted, hub_stats.rejected);
    println!("\n=== E7 end-to-end report ===");
    println!("users served            : {deadline_total}");
    println!(
        "live prediction MAPE    : {:.2}% (median {:.2}%)",
        stats::mean(&pct_errors),
        stats::median(&pct_errors)
    );
    println!(
        "deadline hit rate       : {}/{} = {:.0}% (requested confidence {:.0}%)",
        deadline_hits,
        deadline_total,
        100.0 * deadline_hits as f64 / deadline_total.max(1) as f64,
        100.0 * CONFIDENCE
    );
    println!("hub contributions       : {contributions_accepted} submitted-accepted ({acc} acc / {rej} rej total)");
    println!("total cloud spend       : ${:.2}", provider.total_cost_usd());
    println!("leaked clusters         : {}", provider.active_clusters());
    println!("wall clock              : {:.1}s", t0.elapsed().as_secs_f64());

    server.shutdown();
    anyhow::ensure!(provider.active_clusters() == 0, "cluster leak!");
    anyhow::ensure!(deadline_total >= 8, "too few feasible users");
    Ok(())
}
