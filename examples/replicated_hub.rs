//! Replication story (DESIGN.md §11): one leader, two followers, zero
//! divergence.
//!
//! Act 1 — leader + followers: a durable leader hub accepts submits;
//!   two follower hubs tail its WAL over TCP and converge to the same
//!   corpus, byte for byte.
//! Act 2 — read scaling: the followers answer `predict_batch` from
//!   their own fitted-model caches, bit-identically to the leader —
//!   read capacity now scales with hubs, writes stay on the leader.
//! Act 3 — the write fence: `submit_runs` on a follower is refused with
//!   a typed `not_leader` error naming the leader; lag is observable by
//!   comparing per-repo `stats` watermarks.
//!
//! Run with:  cargo run --release --example replicated_hub

use std::sync::Arc;
use std::time::{Duration, Instant};

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::data::{Dataset, JobKind};
use c3o::hub::{HubClient, HubServer, HubState, Repository, ValidationPolicy};
use c3o::replication::{FollowerConfig, Tailer};
use c3o::runtime::{Engine, FitBackend, NativeBackend};
use c3o::sim::{JobInput, WorkloadModel};
use c3o::storage::{DurableStore, StorageConfig};
use c3o::util::prng::Pcg;

fn backend() -> Arc<dyn FitBackend> {
    match Engine::load_default() {
        Ok(e) => Arc::new(e),
        Err(_) => Arc::new(NativeBackend::new()),
    }
}

/// A durable hub on an ephemeral port: empty Sort repository, own data
/// dir, optionally tailing a leader.
fn start_hub(tag: &str, follow: Option<&str>) -> anyhow::Result<HubServer> {
    let dir = std::env::temp_dir()
        .join(format!("c3o_repl_example_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (store, _) = DurableStore::open(&dir, StorageConfig::default())?;
    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::Sort, "standard Spark sort");
    repo.maintainer_machine = Some("m5.xlarge".into());
    state.insert(repo);
    state.set_storage(Arc::new(store))?;
    // Bootstrap regime: the §III-C-b gate is collaborative_hub.rs's
    // story; here every honest submit accepts deterministically so the
    // replication acts cannot be upstaged by a retrain verdict.
    let policy = ValidationPolicy { min_existing: usize::MAX, ..Default::default() };
    let service = Arc::new(PredictionService::new(
        state,
        Catalog::aws_like(),
        policy,
        backend(),
    ));
    if let Some(leader) = follow {
        service.set_follower_of(leader);
    }
    let mut server = HubServer::start("127.0.0.1:0", service)?;
    if let Some(leader) = follow {
        let tailer = Tailer::start(server.service().clone(), FollowerConfig::new(leader));
        server.attach_tailer(tailer);
    }
    Ok(server)
}

fn honest_runs(n: usize, seed: u64) -> anyhow::Result<Dataset> {
    let catalog = Catalog::aws_like();
    let model = WorkloadModel::default();
    let mt = catalog.get("m5.xlarge")?;
    let mut rng = Pcg::seed(seed);
    let mut ds = Dataset::new(JobKind::Sort);
    for _ in 0..n {
        let s = rng.range(2, 13) as u32;
        let input = JobInput::new(JobKind::Sort, rng.range_f64(10.0, 20.0), vec![]);
        ds.push(model.observe(mt, s, &input, &mut rng))?;
    }
    Ok(ds)
}

fn main() -> anyhow::Result<()> {
    // ---------- Act 1: leader + followers converge ----------
    println!("=== Act 1: a leader and two followers ===");
    let leader = start_hub("leader", None)?;
    let leader_addr = leader.addr.to_string();
    let mut lc = HubClient::connect(&leader_addr)?;
    for (n, seed) in [(30, 1), (20, 2)] {
        let v = lc.submit_runs(&honest_runs(n, seed)?)?;
        anyhow::ensure!(v.accepted, "honest submit rejected: {}", v.reason);
    }
    let leader_rev = lc.get_repo(JobKind::Sort)?.revision;
    println!("  leader {leader_addr}: sort repository at revision {leader_rev}");

    let fa = start_hub("follower_a", Some(&leader_addr))?;
    let fb = start_hub("follower_b", Some(&leader_addr))?;
    let mut ca = HubClient::connect(&fa.addr.to_string())?;
    let mut cb = HubClient::connect(&fb.addr.to_string())?;
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let ra = ca.get_repo(JobKind::Sort)?.revision;
        let rb = cb.get_repo(JobKind::Sort)?.revision;
        if ra == leader_rev && rb == leader_rev {
            break;
        }
        anyhow::ensure!(Instant::now() < deadline, "followers did not converge");
        std::thread::sleep(Duration::from_millis(50));
    }
    let corpus = |c: &mut HubClient| -> anyhow::Result<String> {
        c.get_repo(JobKind::Sort)?.data.to_table()?.to_text()
    };
    let want = corpus(&mut lc)?;
    anyhow::ensure!(corpus(&mut ca)? == want, "follower A corpus diverged");
    anyhow::ensure!(corpus(&mut cb)? == want, "follower B corpus diverged");
    println!("  followers converged to revision {leader_rev}: corpora byte-identical\n");

    // ---------- Act 2: reads scale, answers do not drift ----------
    println!("=== Act 2: followers answer reads bit-identically ===");
    let rows: Vec<Vec<f64>> = (2..=12).map(|s| vec![s as f64, 15.0]).collect();
    let l = lc.predict_batch(JobKind::Sort, None, &rows)?;
    let a = ca.predict_batch(JobKind::Sort, None, &rows)?;
    let b = cb.predict_batch(JobKind::Sort, None, &rows)?;
    let identical = |x: &[f64], y: &[f64]| {
        x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
    };
    let all_identical =
        identical(&l.runtimes, &a.runtimes) && identical(&l.runtimes, &b.runtimes);
    println!(
        "  predict_batch ({} rows): model {} everywhere, runtimes {}",
        rows.len(),
        l.model,
        if all_identical { "bit-identical" } else { "DIVERGED" }
    );

    // ---------- Act 3: the write fence ----------
    println!("\n=== Act 3: writes stay on the leader ===");
    let err = match ca.submit_runs(&honest_runs(5, 9)?) {
        Err(e) => e.to_string(),
        Ok(_) => anyhow::bail!("follower accepted a write"),
    };
    println!("  submit_runs on follower A     : {err}");
    let v = lc.submit_runs(&honest_runs(10, 3)?)?;
    anyhow::ensure!(v.accepted, "leader submit rejected: {}", v.reason);
    let deadline = Instant::now() + Duration::from_secs(30);
    while ca.get_repo(JobKind::Sort)?.revision < v.revision {
        anyhow::ensure!(Instant::now() < deadline, "follower missed the new submit");
        std::thread::sleep(Duration::from_millis(50));
    }
    let ls = lc.stats()?;
    let fs = ca.stats()?;
    println!(
        "  after one more leader submit  : leader watermarks {:?}, follower {:?}",
        ls.per_repo
            .iter()
            .map(|r| (r.job.to_string(), r.revision))
            .collect::<Vec<_>>(),
        fs.per_repo
            .iter()
            .map(|r| (r.job.to_string(), r.revision))
            .collect::<Vec<_>>(),
    );

    let fa_dir = fa.state().storage().map(|s| s.dir().to_path_buf());
    let fb_dir = fb.state().storage().map(|s| s.dir().to_path_buf());
    let l_dir = leader.state().storage().map(|s| s.dir().to_path_buf());
    fa.shutdown();
    fb.shutdown();
    leader.shutdown();
    for dir in [fa_dir, fb_dir, l_dir].into_iter().flatten() {
        let _ = std::fs::remove_dir_all(&dir);
    }

    anyhow::ensure!(all_identical, "followers must predict bit-identically");
    anyhow::ensure!(err.contains("not_leader"), "write fence must be typed not_leader");
    anyhow::ensure!(
        ls.per_repo == fs.per_repo,
        "follower watermarks must match the leader's"
    );
    Ok(())
}
