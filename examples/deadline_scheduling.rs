//! Deadline scheduling under the §IV-B confidence rule.
//!
//! Sweeps the confidence parameter c and shows (a) how the chosen
//! scale-out grows with c, and (b) that the *empirical* deadline-hit rate
//! across many executions tracks the requested confidence — the
//! operational meaning of `ŝ = min { s | t_s + μ + Φ⁻¹(c)σ ≤ t_max }`.
//!
//! Run with:  cargo run --release --example deadline_scheduling

use std::sync::Arc;

use c3o::cloud::{Catalog, CloudProvider, ClusterConfig};
use c3o::configurator::{select_scale_out, UserGoals};
use c3o::data::JobKind;
use c3o::models::{C3oPredictor, TrainData};
use c3o::runtime::{Engine, FitBackend, NativeBackend};
use c3o::sim::{generate_job, Executor, GeneratorConfig, JobInput, WorkloadModel};
use c3o::util::erf::confidence_multiplier;
use c3o::util::prng::Pcg;

fn main() -> anyhow::Result<()> {
    let backend: Arc<dyn FitBackend> = match Engine::load_default() {
        Ok(e) => Arc::new(e),
        Err(_) => Arc::new(NativeBackend::new()),
    };
    let catalog = Catalog::aws_like();

    // Train the predictor on the shared Grep corpus (m5.xlarge slice).
    let shared = generate_job(JobKind::Grep, &GeneratorConfig::default(), &catalog)?
        .for_machine("m5.xlarge");
    let data = TrainData::from_dataset(&shared)?;
    let mut predictor = C3oPredictor::new(backend);
    let report = predictor.fit(&data)?;
    let (mu, sigma) = (report.chosen_score.resid_mean, report.chosen_score.resid_std);
    println!(
        "predictor: chose {} (CV MAPE {:.2}%, residuals mu={:.1}s sigma={:.1}s)\n",
        report.chosen, report.chosen_score.mape, mu, sigma
    );

    let input = JobInput::new(JobKind::Grep, 16.0, vec![0.01]);
    let model = WorkloadModel::default();
    let mt = catalog.get("m5.xlarge")?;
    let deadline = {
        let t_fast = model.mean_runtime(mt, 12, &input);
        let t_slow = model.mean_runtime(mt, 2, &input);
        t_fast + 0.45 * (t_slow - t_fast)
    };
    println!("job: grep 16 GB (ratio 0.01), deadline {deadline:.0}s\n");

    println!(
        "{:<12} {:>12} {:>10} {:>12} {:>14}",
        "confidence", "multiplier", "scale-out", "est cost $", "empirical hit%"
    );
    let provider = CloudProvider::new(Catalog::aws_like());
    let executor = Executor::new(&provider, WorkloadModel::default(), 0xD43);
    for &c in &[0.5, 0.7, 0.8, 0.9, 0.95, 0.99] {
        let goals = UserGoals { deadline_s: Some(deadline), confidence: c };
        let choice = match select_scale_out(
            &catalog, "m5.xlarge", &predictor, &input, &goals, mu, sigma,
        ) {
            Ok(ch) => ch,
            Err(_) => {
                println!("{c:<12} {:>12.3} {:>10}", confidence_multiplier(c), "infeasible");
                continue;
            }
        };
        // Empirical check: execute 200 times at the chosen scale-out.
        let mut rng = Pcg::seed((c * 1e4) as u64);
        let mut hits = 0;
        let trials = 200;
        for _ in 0..trials {
            let t = model.sample_runtime(mt, choice.scale_out, &input, &mut rng);
            if t <= deadline {
                hits += 1;
            }
        }
        println!(
            "{c:<12} {:>12.3} {:>10} {:>12.3} {:>13.1}%",
            confidence_multiplier(c),
            choice.scale_out,
            choice.est_cost_usd,
            100.0 * hits as f64 / trials as f64
        );
        // One real (billed) execution for flavour.
        let _ = executor.run(
            &ClusterConfig {
                machine_type: choice.machine_type.clone(),
                scale_out: choice.scale_out,
            },
            &input,
            Some(deadline),
        )?;
    }
    println!(
        "\ncloud spend across the sweep: ${:.2} (provisioning delay billed per run)",
        provider.total_cost_usd()
    );
    Ok(())
}
