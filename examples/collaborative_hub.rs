//! The collaborative story (paper §III): why sharing runtime data helps,
//! and how the hub defends itself.
//!
//! Act 1 — cold start: a new user with *no* local runtime data gets
//!   accurate predictions from the first execution, because the hub's
//!   global corpus covers their context (the paper's core promise).
//! Act 2 — the validation gate (§III-C-b): honest contributions are
//!   accepted, fabricated ones are rejected, and prediction quality is
//!   unharmed afterwards.
//! Act 3 — the v1 prediction service: the hub answers `predict_batch` and
//!   `configure` itself from its fitted-model cache, so users get
//!   predictions without downloading the corpus or fitting anything.
//! Act 4 — durability (DESIGN.md §9): the hub shuts down, restarts from
//!   its data dir, and serves the recovered corpus — same revision, same
//!   records, bit-identical predictions. Acknowledged contributions are
//!   never lost.
//!
//! Run with:  cargo run --release --example collaborative_hub

use std::sync::Arc;

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::configurator::UserGoals;
use c3o::data::{Dataset, JobKind, RunRecord};
use c3o::hub::{HubClient, HubServer, HubState, Repository, ValidationPolicy};
use c3o::models::{C3oPredictor, TrainData};
use c3o::runtime::{Engine, FitBackend, NativeBackend};
use c3o::sim::{generate_job, GeneratorConfig, JobInput, WorkloadModel};
use c3o::storage::{DurableStore, StorageConfig};
use c3o::util::prng::Pcg;
use c3o::util::stats;

fn main() -> anyhow::Result<()> {
    let backend: Arc<dyn FitBackend> = match Engine::load_default() {
        Ok(e) => Arc::new(e),
        Err(_) => Arc::new(NativeBackend::new()),
    };
    let catalog = Catalog::aws_like();

    // Durable hub with the shared K-Means corpus: contributions accepted
    // over the wire are WAL-logged under `data_dir` before they are
    // acknowledged, so Act 4 can restart the hub and lose nothing.
    let data_dir = std::env::temp_dir().join(format!("c3o_hub_example_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&data_dir);
    let (store, _) = DurableStore::open(&data_dir, StorageConfig::default())?;
    let store = Arc::new(store);

    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::KMeans, "standard Spark K-Means");
    repo.maintainer_machine = Some("m5.xlarge".into());
    repo.data = generate_job(JobKind::KMeans, &GeneratorConfig::default(), &catalog)?;
    state.insert(repo);
    state.snapshot_to(&store)?; // baseline snapshot of the seeded corpus
    state.set_storage(store)?;
    let service = Arc::new(PredictionService::new(
        state,
        catalog.clone(),
        ValidationPolicy::default(),
        backend.clone(),
    ));
    let server = HubServer::start("127.0.0.1:0", service)?;
    let mut client = HubClient::connect(&server.addr.to_string())?;

    // ---------- Act 1: cold start ----------
    // The new user runs K-Means with k=8 — a context they have NO history
    // for. Their "local" alternative is the little data they have from a
    // different context (k=3).
    let model = WorkloadModel::default();
    let mt = catalog.get("m5.xlarge")?;
    let mut rng = Pcg::seed(0xC01D);

    let mut local_only = Dataset::new(JobKind::KMeans);
    for _ in 0..8 {
        let s = rng.range(2, 13) as u32;
        let input = JobInput::new(JobKind::KMeans, rng.range_f64(10.0, 20.0), vec![3.0, 0.001]);
        local_only.push(model.observe(mt, s, &input, &mut rng))?;
    }

    let global = client.get_repo(JobKind::KMeans)?.data.for_machine("m5.xlarge");

    // Ground truth for the user's actual workload (k=8).
    let mut test_rows = Vec::new();
    let mut test_y = Vec::new();
    for _ in 0..40 {
        let s = rng.range(2, 13) as u32;
        let d = rng.range_f64(10.0, 20.0);
        let input = JobInput::new(JobKind::KMeans, d, vec![8.0, 0.001]);
        test_rows.push(vec![s as f64, d, 8.0, 0.001]);
        test_y.push(model.median_of_five(mt, s, &input, &mut rng));
    }
    let test_x = c3o::linalg::Matrix::from_rows(&test_rows)?;

    let score = |train: &Dataset| -> anyhow::Result<(String, f64)> {
        let data = TrainData::from_dataset(train)?;
        let mut p = C3oPredictor::new(backend.clone());
        let report = p.fit(&data)?;
        let preds = (0..test_x.rows())
            .map(|i| p.predict_one(test_x.row(i)))
            .collect::<anyhow::Result<Vec<f64>>>()?;
        Ok((report.chosen, stats::mape(&preds, &test_y)))
    };

    let (m_local, mape_local) = score(&local_only)?;
    let (m_global, mape_global) = score(&global)?;
    println!("=== Act 1: cold start on an unseen context (k=8) ===");
    println!(
        "  local-only ({} pts, k=3 history): {m_local:<4} MAPE {mape_local:.2}%",
        local_only.len()
    );
    println!(
        "  hub global ({} pts, all contexts): {m_global:<4} MAPE {mape_global:.2}%",
        global.len()
    );
    println!(
        "  collaboration gain: {:.1}x lower error\n",
        mape_local / mape_global.max(1e-9)
    );

    // ---------- Act 2: the validation gate ----------
    println!("=== Act 2: contribution validation (§III-C-b) ===");
    // Honest contributor.
    let mut honest = Dataset::new(JobKind::KMeans);
    for _ in 0..10 {
        let s = rng.range(2, 13) as u32;
        let input = JobInput::new(JobKind::KMeans, rng.range_f64(10.0, 20.0), vec![6.0, 0.001]);
        honest.push(model.observe(mt, s, &input, &mut rng))?;
    }
    let v = client.submit_runs(&honest)?;
    println!(
        "  honest user (10 runs, k=6)    : {} — {} (repo revision {})",
        if v.accepted { "ACCEPTED" } else { "REJECTED" },
        v.reason,
        v.revision
    );

    // Saboteur: fabricated runtimes.
    let mut poison = Dataset::new(JobKind::KMeans);
    for _ in 0..25 {
        poison.push(RunRecord {
            machine_type: "m5.xlarge".into(),
            scale_out: rng.range(2, 13) as u32,
            data_size_gb: rng.range_f64(10.0, 20.0),
            context: vec![5.0, 0.001],
            runtime_s: 1.0, // "my cluster is magic"
        })?;
    }
    let v = client.submit_runs(&poison)?;
    println!(
        "  saboteur (25 fabricated runs) : {} — {}",
        if v.accepted { "ACCEPTED" } else { "REJECTED" },
        v.reason
    );

    // Prediction quality after the attack attempt.
    let after = client.get_repo(JobKind::KMeans)?.data.for_machine("m5.xlarge");
    let (_, mape_after) = score(&after)?;
    println!(
        "  global MAPE after the episode : {mape_after:.2}% (before: {mape_global:.2}%)"
    );
    let s = client.stats()?;
    println!(
        "  hub counters                  : {} accepted, {} rejected",
        s.accepted, s.rejected
    );

    // ---------- Act 3: server-side prediction (API v1) ----------
    println!("\n=== Act 3: the hub predicts and configures itself ===");
    let rows: Vec<Vec<f64>> = (2..=12).map(|s| vec![s as f64, 15.0, 8.0, 0.001]).collect();
    let b1 = client.predict_batch(JobKind::KMeans, None, &rows)?;
    let b2 = client.predict_batch(JobKind::KMeans, None, &rows)?;
    println!(
        "  predict_batch ({} rows)       : model {} on {} (cold fit, then cached: {})",
        rows.len(),
        b1.model,
        b1.machine_type,
        b2.cached
    );
    let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
    let choice = client.configure(JobKind::KMeans, 15.0, vec![8.0, 0.001], &goals, None)?;
    println!(
        "  hub-side configure            : {} x{} (est {:.0} s, UCB {:.0} s, ${:.3})",
        choice.machine_type,
        choice.scale_out,
        choice.predicted_runtime_s,
        choice.runtime_ucb_s,
        choice.est_cost_usd
    );
    let s = client.stats()?;
    println!(
        "  prediction service            : {} cold fit(s), {} cache hit(s)",
        s.fits, s.cache_hits
    );

    // ---------- Act 4: restart recovery (DESIGN.md §9) ----------
    println!("\n=== Act 4: the hub restarts and loses nothing ===");
    let before = client.predict_batch(JobKind::KMeans, None, &rows)?;
    let revision_before = client.get_repo(JobKind::KMeans)?.revision;
    drop(client);
    // Graceful drain: WAL fsync + one final compacted snapshot.
    server.shutdown();

    // A brand-new process starts exactly like this: open the data dir,
    // recover snapshot + WAL tail, serve the recovered corpus.
    let (store2, recovered) = DurableStore::open(&data_dir, StorageConfig::default())?;
    let state2 = Arc::new(HubState::new());
    for repo in recovered {
        state2.install_recovered(repo);
    }
    state2.set_storage(Arc::new(store2))?;
    let service2 = Arc::new(PredictionService::new(
        state2,
        catalog.clone(),
        ValidationPolicy::default(),
        backend.clone(),
    ));
    let server2 = HubServer::start("127.0.0.1:0", service2)?;
    let mut client2 = HubClient::connect(&server2.addr.to_string())?;

    let repo2 = client2.get_repo(JobKind::KMeans)?;
    println!(
        "  recovered repository          : {} records at revision {} (pre-restart: {})",
        repo2.data.len(),
        repo2.revision,
        revision_before
    );
    let after_restart = client2.predict_batch(JobKind::KMeans, None, &rows)?;
    let identical = before
        .runtimes
        .iter()
        .zip(&after_restart.runtimes)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "  predictions after restart     : {} across {} rows (model {})",
        if identical { "bit-identical" } else { "DIVERGED" },
        after_restart.runtimes.len(),
        after_restart.model
    );
    server2.shutdown();
    let _ = std::fs::remove_dir_all(&data_dir);

    anyhow::ensure!(mape_global < mape_local, "collaboration must help the cold-start user");
    anyhow::ensure!(mape_after < mape_global * 2.0, "gate failed to protect accuracy");
    anyhow::ensure!(b2.cached, "second batch must be served from the cache");
    anyhow::ensure!(
        repo2.revision == revision_before,
        "repository revision must survive the restart"
    );
    anyhow::ensure!(identical, "recovered hub must predict bit-identically");
    Ok(())
}
