"""L1 Pallas kernels vs pure-jnp/numpy oracles.

The CORE correctness signal for the compile path: every kernel is swept over
shapes, mask densities and conditioning regimes and compared against ref.py.
(hypothesis is not available in this image; the sweeps below are seeded
parametrized equivalents covering the same axes: N, F, B, density, scale.)
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import masked_gram, batched_predict, ref
from compile.kernels.gram import BT


def make_case(seed, n, f, b, density, scale):
    rng = np.random.default_rng(seed)
    x = (scale * rng.normal(size=(n, f))).astype(np.float32)
    y = (scale * rng.normal(size=(n,))).astype(np.float32)
    w = (rng.random((b, n)) < density).astype(np.float32)
    # Guarantee at least F active rows per mask so Gram systems are sane.
    for i in range(b):
        idx = rng.choice(n, size=min(f + 2, n), replace=False)
        w[i, idx] = 1.0
    return x, y, w


SWEEP = [
    # (seed, n, f, b, density, scale, lam)
    (0, 64, 8, 64, 0.7, 1.0, 1e-6),
    (1, 64, 8, 64, 0.3, 1.0, 1e-3),
    (2, 64, 4, 64, 0.9, 10.0, 1e-6),
    (3, 32, 8, 32, 0.5, 0.1, 1e-4),
    (4, 16, 2, 8, 1.0, 1.0, 0.0),
    (5, 64, 8, 8, 0.6, 100.0, 1e-2),
    (6, 48, 6, 16, 0.4, 1.0, 1e-6),
    (7, 64, 1, 64, 0.8, 1.0, 1e-6),
]


@pytest.mark.parametrize("seed,n,f,b,density,scale,lam", SWEEP)
def test_masked_gram_matches_ref(seed, n, f, b, density, scale, lam):
    x, y, w = make_case(seed, n, f, b, density, scale)
    g, c = masked_gram(jnp.array(x), jnp.array(y), jnp.array(w), lam)
    g_ref, c_ref = ref.masked_gram_ref(
        jnp.array(x), jnp.array(y), jnp.array(w), lam
    )
    np.testing.assert_allclose(np.array(g), np.array(g_ref),
                               rtol=1e-5, atol=1e-4 * scale * scale)
    np.testing.assert_allclose(np.array(c), np.array(c_ref),
                               rtol=1e-5, atol=1e-4 * scale * scale)


@pytest.mark.parametrize("seed,n,f,b,density,scale,lam", SWEEP)
def test_batched_predict_matches_ref(seed, n, f, b, density, scale, lam):
    rng = np.random.default_rng(seed + 100)
    xq = (scale * rng.normal(size=(n, f))).astype(np.float32)
    theta = rng.normal(size=(b, f)).astype(np.float32)
    p = batched_predict(jnp.array(xq), jnp.array(theta))
    p_ref = ref.batched_predict_ref(jnp.array(xq), jnp.array(theta))
    np.testing.assert_allclose(np.array(p), np.array(p_ref),
                               rtol=1e-5, atol=1e-4 * scale)


def test_gram_identity_mask_is_plain_gram():
    """w == all-ones reduces to X^T X + lam I exactly."""
    rng = np.random.default_rng(42)
    x = rng.normal(size=(32, 4)).astype(np.float32)
    y = rng.normal(size=(32,)).astype(np.float32)
    w = np.ones((BT, 32), np.float32)
    g, c = masked_gram(jnp.array(x), jnp.array(y), jnp.array(w), 0.5)
    expect_g = x.T @ x + 0.5 * np.eye(4, dtype=np.float32)
    expect_c = x.T @ y
    for i in range(BT):
        np.testing.assert_allclose(np.array(g[i]), expect_g, rtol=1e-5,
                                   atol=1e-4)
        np.testing.assert_allclose(np.array(c[i]), expect_c, rtol=1e-5,
                                   atol=1e-4)


def test_gram_zero_mask_gives_ridge_only():
    """w == 0 leaves exactly lam*I and zero c."""
    rng = np.random.default_rng(43)
    x = rng.normal(size=(16, 4)).astype(np.float32)
    y = rng.normal(size=(16,)).astype(np.float32)
    w = np.zeros((BT, 16), np.float32)
    g, c = masked_gram(jnp.array(x), jnp.array(y), jnp.array(w), 2.0)
    for i in range(BT):
        np.testing.assert_allclose(np.array(g[i]), 2.0 * np.eye(4),
                                   atol=1e-6)
        np.testing.assert_allclose(np.array(c[i]), np.zeros(4), atol=1e-6)


def test_gram_mask_linearity():
    """Gram is linear in w: G(w1+w2) - lam I == (G(w1)-lam I)+(G(w2)-lam I)."""
    rng = np.random.default_rng(44)
    x = rng.normal(size=(24, 3)).astype(np.float32)
    y = rng.normal(size=(24,)).astype(np.float32)
    w1 = rng.random((BT, 24)).astype(np.float32)
    w2 = rng.random((BT, 24)).astype(np.float32)
    lam = 1.0
    g1, c1 = masked_gram(jnp.array(x), jnp.array(y), jnp.array(w1), lam)
    g2, c2 = masked_gram(jnp.array(x), jnp.array(y), jnp.array(w2), lam)
    g12, c12 = masked_gram(jnp.array(x), jnp.array(y),
                           jnp.array(w1 + w2), lam)
    np.testing.assert_allclose(np.array(g12) + lam * np.eye(3),
                               np.array(g1) + np.array(g2),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.array(c12), np.array(c1) + np.array(c2),
                               rtol=1e-4, atol=1e-3)


def test_predict_zero_theta_zero_output():
    xq = np.ones((8, 4), np.float32)
    theta = np.zeros((BT, 4), np.float32)
    p = batched_predict(jnp.array(xq), jnp.array(theta))
    np.testing.assert_array_equal(np.array(p), np.zeros((BT, 8)))
