"""L2 estimator graphs vs f64 numpy oracles + algebraic invariants."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def loocv_case(seed, n_active, f_active, noise=0.01):
    """A padded C3O-style problem: n_active real rows, LOO masks."""
    rng = np.random.default_rng(seed)
    N, F, B = model.N, model.F, model.B
    x = np.zeros((N, F), np.float32)
    y = np.zeros((N,), np.float32)
    xa = np.abs(rng.normal(size=(n_active, f_active))) + 0.1
    beta = np.abs(rng.normal(size=f_active)) + 0.1
    ya = xa @ beta + noise * rng.normal(size=n_active)
    x[:n_active, :f_active] = xa
    y[:n_active] = ya
    w = np.zeros((B, N), np.float32)
    for i in range(min(B, n_active)):
        w[i, :n_active] = 1.0
        w[i, i] = 0.0                      # leave one out
    # Remaining masks: full data (used as "fit on everything" slot).
    for i in range(min(B, n_active), B):
        w[i, :n_active] = 1.0
    return x, y, w


@pytest.mark.parametrize("seed,n_active,f_active", [
    (0, 20, 4), (1, 40, 8), (2, 64, 3), (3, 10, 2), (4, 30, 6),
])
def test_ols_batch_matches_f64_solver(seed, n_active, f_active):
    x, y, w = loocv_case(seed, n_active, f_active)
    lam = np.float32(1e-5)
    th, pr = model.ols_batch(jnp.array(x), jnp.array(y), jnp.array(w), lam)
    th_ref, pr_ref = ref.ols_batch_ref(x, y, w, float(lam))
    np.testing.assert_allclose(np.array(th), th_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.array(pr), pr_ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("seed,n_active,f_active", [
    (10, 20, 4), (11, 40, 6), (12, 64, 8),
])
def test_nnls_batch_matches_f64_pgd(seed, n_active, f_active):
    x, y, w = loocv_case(seed, n_active, f_active)
    lam = np.float32(1e-4)
    th, _ = model.nnls_batch(jnp.array(x), jnp.array(y), jnp.array(w), lam)
    th_ref, _ = ref.nnls_batch_ref(x, y, w, float(lam))
    assert (np.array(th) >= 0).all()
    np.testing.assert_allclose(np.array(th), th_ref, rtol=5e-3, atol=5e-3)


def test_nnls_recovers_nonnegative_truth():
    """On a well-posed nonneg problem NNLS == OLS == truth."""
    rng = np.random.default_rng(7)
    N, F, B = model.N, model.F, model.B
    x = np.abs(rng.normal(size=(N, F))).astype(np.float32) + 0.1
    beta = np.array([1.0, 0.5, 2.0, 0.0, 0.3, 0.0, 1.5, 0.2], np.float32)
    y = (x @ beta).astype(np.float32)
    w = np.ones((B, N), np.float32)
    th, _ = model.nnls_batch(jnp.array(x), jnp.array(y), jnp.array(w),
                             np.float32(1e-6))
    np.testing.assert_allclose(np.array(th[0]), beta, rtol=1e-2, atol=1e-2)


def test_gauss_jordan_vs_numpy_solve():
    rng = np.random.default_rng(8)
    g = rng.normal(size=(16, 8, 8))
    g = (g @ np.transpose(g, (0, 2, 1)) +
         0.1 * np.eye(8)[None]).astype(np.float32)
    c = rng.normal(size=(16, 8)).astype(np.float32)
    th = model.gauss_jordan_solve(jnp.array(g), jnp.array(c))
    expect = np.stack([np.linalg.solve(g[i].astype(np.float64),
                                       c[i].astype(np.float64))
                       for i in range(16)])
    np.testing.assert_allclose(np.array(th), expect, rtol=1e-3, atol=1e-3)


def test_gauss_jordan_needs_pivoting():
    """A system whose natural order has a zero leading pivot."""
    g = np.array([[[0.0, 1.0], [1.0, 0.0]]], np.float32)
    c = np.array([[2.0, 3.0]], np.float32)
    th = model.gauss_jordan_solve(jnp.array(g), jnp.array(c))
    np.testing.assert_allclose(np.array(th[0]), [3.0, 2.0], atol=1e-5)


def test_predict_grid_matches_einsum():
    rng = np.random.default_rng(9)
    theta = rng.normal(size=(model.B, model.F)).astype(np.float32)
    xq = rng.normal(size=(model.Q, model.F)).astype(np.float32)
    p = model.predict_grid(jnp.array(theta), jnp.array(xq))
    np.testing.assert_allclose(np.array(p),
                               np.einsum("qf,bf->bq", xq, theta),
                               rtol=1e-5, atol=1e-4)


def test_loo_residuals_are_honest():
    """LOO prediction for the held-out row differs from in-sample fit.

    Guards against a classic masking bug: if the mask were ignored the
    held-out residual would be (near) the in-sample residual.
    """
    x, y, w = loocv_case(21, 30, 4, noise=0.2)
    lam = np.float32(1e-6)
    th, pr = model.ols_batch(jnp.array(x), jnp.array(y), jnp.array(w), lam)
    pr = np.array(pr)
    # In-sample fit: mask index 30+ trains on all 30 rows.
    insample = pr[30 + 1]
    loo = np.array([pr[i, i] for i in range(30)])
    ins = np.array([insample[i] for i in range(30)])
    # LOO residuals must be strictly larger on average (they are honest).
    resid_loo = np.abs(loo - y[:30])
    resid_ins = np.abs(ins - y[:30])
    assert resid_loo.mean() > resid_ins.mean()


def test_entry_specs_shapes_consistent():
    for fn, name, specs in model.entry_specs():
        out = jax.eval_shape(fn, *specs)
        assert isinstance(out, tuple)
        for o in out:
            assert o.dtype == jnp.float32
