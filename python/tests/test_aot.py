"""AOT export sanity: HLO text artifacts are parseable, stable, manifest-true."""

import hashlib
import os
import subprocess
import sys

import pytest

import jax

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_hlo_module():
    fn, name, specs = model.entry_specs()[2]  # predict_grid: fastest
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # No Mosaic/TPU custom-calls may appear (CPU PJRT cannot run them).
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_export_is_deterministic(tmp_path):
    aot.export_all(str(tmp_path))
    first = {p: open(tmp_path / p).read() for p in os.listdir(tmp_path)}
    aot.export_all(str(tmp_path))
    second = {p: open(tmp_path / p).read() for p in os.listdir(tmp_path)}
    assert first == second


def test_export_writes_all_modules(tmp_path):
    aot.export_all(str(tmp_path))
    names = {f"{name}.hlo.txt" for _, name, _ in model.entry_specs()}
    names.add("MANIFEST.tsv")
    assert set(os.listdir(tmp_path)) == names


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts/ not built")
def test_checked_in_manifest_matches_artifacts():
    manifest = os.path.join(ART, "MANIFEST.tsv")
    if not os.path.exists(manifest):
        pytest.skip("no MANIFEST.tsv")
    with open(manifest) as f:
        lines = [l.rstrip("\n") for l in f if not l.startswith("#")]
    for line in lines:
        name, digest, _shapes = line.split("\t")
        path = os.path.join(ART, f"{name}.hlo.txt")
        text = open(path).read()
        assert hashlib.sha256(text.encode()).hexdigest() == digest, name


def test_module_cli_runs(tmp_path):
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True, text=True, env=env,
    )
    assert r.returncode == 0, r.stderr
    assert (tmp_path / "MANIFEST.tsv").exists()
