"""AOT export: lower the L2 estimator graphs to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Writes:
    artifacts/ols_batch.hlo.txt
    artifacts/nnls_batch.hlo.txt
    artifacts/predict_grid.hlo.txt
    artifacts/MANIFEST.tsv         (name, sha256, shapes) — the Rust runtime
                                   refuses to load artifacts whose manifest
                                   does not match its compiled-in contract.
"""

import argparse
import hashlib
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_rows = []
    for fn, name, specs in model.entry_specs():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()
        shapes = ";".join(
            f"{s.dtype}{list(s.shape)}".replace(" ", "") for s in specs
        )
        manifest_rows.append((name, digest, shapes))
        print(f"wrote {path} ({len(text)} chars, sha256 {digest[:12]})")

    with open(os.path.join(out_dir, "MANIFEST.tsv"), "w") as f:
        f.write(f"# N={model.N}\tF={model.F}\tB={model.B}\tQ={model.Q}\n")
        for name, digest, shapes in manifest_rows:
            f.write(f"{name}\t{digest}\t{shapes}\n")
    print(f"wrote {os.path.join(out_dir, 'MANIFEST.tsv')}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    # Back-compat with a single-file --out target (Makefile sentinel).
    parser.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = parser.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    export_all(out_dir or ".")


if __name__ == "__main__":
    main()
