"""L2: the C3O batched estimator graphs (JAX), calling the L1 kernels.

Three entry points, each lowered to its own HLO module by aot.py:

  ols_batch(X, y, W, lam)  -> (theta[B,F], preds[B,N])
      Batched ridge ordinary least squares.  Backbone of the BOM (linear
      IBM, polynomial SSM) and of every cross-validation split fit.

  nnls_batch(X, y, W, lam) -> (theta[B,F], preds[B,N])
      Batched non-negative least squares (projected gradient, fixed K
      iterations with exact Lipschitz step).  Backbone of the Ernest
      baseline, whose parameters are constrained theta >= 0.

  predict_grid(theta, Xq)  -> preds[B,Q]
      Configurator scale-out sweep: score B fitted models on Q candidate
      configurations in one launch.

Design constraints (see DESIGN.md §3):
  * no LAPACK custom-calls — the xla_extension 0.5.1 CPU client can only run
    plain HLO, so the linear solve is a hand-written Gauss-Jordan with
    partial pivoting expressed with lax primitives;
  * fixed shapes (N=128, F=8, B=128, Q=64) — the Rust runtime pads;
  * Pallas kernels run with interpret=True so the lowered HLO contains no
    Mosaic custom-calls.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import masked_gram, batched_predict

# AOT shape contract — keep in sync with rust/src/runtime/shapes.rs.
N = 128  # max training rows
F = 8    # max features
B = 128  # max CV masks per launch
Q = 64   # max query rows (configurator grid)

# 500 FISTA iterations reach the f32 accuracy floor on this problem class
# (measured: relative prediction error ~1e-2 at 400, 800 and 1500 iters —
# conditioning-bound, not iteration-bound). See EXPERIMENTS.md §Perf.
NNLS_ITERS = 500
RIDGE_DEFAULT = 1e-6


def gauss_jordan_solve(g, c):
    """Solve g @ theta = c for a batch of small SPD-ish systems.

    g: (B, F, F), c: (B, F) -> (B, F).

    Gauss-Jordan elimination with partial pivoting, expressed with
    lax.fori_loop + batched gathers so it lowers to plain HLO (no LAPACK).
    F is tiny (<= 8) so the O(F^3) loop is negligible next to the Gram
    assembly.
    """
    b, f, _ = g.shape
    aug = jnp.concatenate([g, c[:, :, None]], axis=2)  # (B, F, F+1)

    def body(k, aug):
        col = aug[:, :, k]                              # (B, F)
        # Partial pivot: among rows >= k pick the largest |col| entry.
        row_idx = jnp.arange(f)
        masked = jnp.where(row_idx[None, :] >= k, jnp.abs(col), -jnp.inf)
        piv = jnp.argmax(masked, axis=1)                # (B,)

        # Swap row k and row piv per batch element.
        bidx = jnp.arange(b)
        row_k = aug[bidx, k, :]                         # (B, F+1)
        row_p = aug[bidx, piv, :]                       # (B, F+1)
        aug = aug.at[bidx, k, :].set(row_p)
        aug = aug.at[bidx, piv, :].set(row_k)

        # Normalize pivot row, eliminate everywhere else.
        pivval = aug[:, k, k][:, None]                  # (B, 1)
        safe = jnp.where(jnp.abs(pivval) < 1e-30, 1e-30, pivval)
        prow = aug[:, k, :] / safe                      # (B, F+1)
        aug = aug.at[:, k, :].set(prow)
        factors = aug[:, :, k]                          # (B, F)
        factors = factors.at[:, k].set(0.0)
        aug = aug - factors[:, :, None] * prow[:, None, :]
        return aug

    aug = lax.fori_loop(0, f, body, aug)
    return aug[:, :, f]


def ols_batch(x, y, w, lam):
    """Batched ridge OLS.  x:(N,F) y:(N,) w:(B,N) lam:() -> (B,F),(B,N)."""
    g, c = masked_gram(x, y, w, lam)          # L1 Pallas kernel
    theta = gauss_jordan_solve(g, c)
    preds = batched_predict(x, theta)         # L1 Pallas kernel
    return theta, preds


def nnls_batch(x, y, w, lam):
    """Batched NNLS via FISTA (accelerated projected gradient).

    theta_{t+1} = max(0, v_t - (1/L_b)(G_b v_t - c_b)) with Nesterov
    momentum on v; L_b = lambda_max(G_b) from 30 power iterations.
    Accelerated convergence matters here: the fixed iteration budget must
    reach the active-set solution the Rust native backend computes exactly
    (rust/tests/runtime_parity.rs asserts agreement).
    """
    g, c = masked_gram(x, y, w, lam)          # (B,F,F), (B,F)
    b, f = c.shape

    # Power iteration for the per-batch spectral norm (G is PSD).
    v0 = jnp.ones((b, f), jnp.float32) / jnp.sqrt(jnp.float32(f))

    def pow_body(_, v):
        gv = jnp.einsum("bij,bj->bi", g, v)
        nrm = jnp.linalg.norm(gv, axis=1, keepdims=True)
        return gv / jnp.maximum(nrm, 1e-30)

    v = lax.fori_loop(0, 30, pow_body, v0)
    gv = jnp.einsum("bij,bj->bi", g, v)
    lip = jnp.einsum("bi,bi->b", v, gv)              # Rayleigh quotient
    step = (1.0 / jnp.maximum(lip, 1e-12))[:, None]  # (B,1)

    zeros = jnp.zeros((b, f), jnp.float32)

    def fista_body(_, carry):
        theta, vel, t = carry
        grad = jnp.einsum("bij,bj->bi", g, vel) - c
        theta_new = jnp.maximum(vel - step * grad, 0.0)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        vel_new = theta_new + ((t - 1.0) / t_new) * (theta_new - theta)
        return theta_new, vel_new, t_new

    theta, _, _ = lax.fori_loop(
        0, NNLS_ITERS, fista_body, (zeros, zeros, jnp.float32(1.0))
    )
    # Momentum can leave vel slightly infeasible; theta itself is feasible.
    preds = batched_predict(x, theta)
    return theta, preds


def predict_grid(theta, xq):
    """Configurator sweep: theta:(B,F), xq:(Q,F) -> (B,Q)."""
    return batched_predict(xq, theta)


# ---------------------------------------------------------------------------
# Entry points with the canonical AOT shapes, used by aot.py and pytest.
# Each returns a tuple (lowered with return_tuple=True) — the Rust side
# unwraps with to_tuple{1,2}().

def ols_entry(x, y, w, lam):
    theta, preds = ols_batch(x, y, w, lam)
    return theta, preds


def nnls_entry(x, y, w, lam):
    theta, preds = nnls_batch(x, y, w, lam)
    return theta, preds


def predict_entry(theta, xq):
    return (predict_grid(theta, xq),)


def entry_specs():
    """(fn, name, arg ShapeDtypeStructs) for every AOT module."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return [
        (ols_entry, "ols_batch",
         (s((N, F), f32), s((N,), f32), s((B, N), f32), s((), f32))),
        (nnls_entry, "nnls_batch",
         (s((N, F), f32), s((N,), f32), s((B, N), f32), s((), f32))),
        (predict_entry, "predict_grid",
         (s((B, F), f32), s((Q, F), f32))),
    ]
