"""Masked-Gram Pallas kernel (L1).

Computes, for a batch of B cross-validation masks at once:

    G[b] = X^T @ diag(w_b) @ X + lam * I        (B, F, F)
    c[b] = X^T @ (w_b * y)                      (B, F)

This is the normal-equation assembly behind every OLS/NNLS fit in the C3O
runtime predictor.  Leave-one-out cross-validation over N training points
means N fits that differ only in one mask entry; batching them turns the
model-selection phase (which the paper reports at 10-30 s) into a single
device launch.

TPU mapping (see DESIGN.md "Hardware adaptation"): the grid iterates over
B-tiles; X (N x F) stays resident in VMEM across the whole grid (it does not
depend on b), each grid step streams one (BT, N) tile of W from HBM, and the
contraction (F, N) @ (N, F) lands on the MXU with f32 accumulation.  Under
``interpret=True`` (CPU PJRT) the same schedule runs as numpy — structure,
not wallclock, is what we optimize here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT shapes (must match python/compile/model.py and the Rust
# runtime engine's padding contract in rust/src/runtime/shapes.rs).
N = 128  # max training points per fit
F = 8    # max feature columns
B = 128  # max simultaneous CV masks

# B-tile: how many masks one grid step processes. Swept 8/16/32 in the
# §Perf pass (EXPERIMENTS.md): 16 minimizes per-step dispatch overhead
# under interpret mode while keeping the W tile at 16*128*4 = 8 KiB —
# comfortably VMEM-resident on a real TPU as well.
BT = 16


def _gram_kernel(x_ref, y_ref, w_ref, lam_ref, g_ref, c_ref):
    """One grid step: BT masks.

    x_ref: (N, F) VMEM     w_ref: (BT, N) VMEM     y_ref: (N, 1) VMEM
    g_ref: (BT, F, F)      c_ref: (BT, F)          lam_ref: (1, 1) SMEM-like
    """
    x = x_ref[...]                      # (N, F)
    y = y_ref[...][:, 0]                # (N,)
    w = w_ref[...]                      # (BT, N)
    lam = lam_ref[0, 0]

    # Weighted design: (BT, N, F) = w[b, n] * x[n, f].  The contraction
    # below is einsum('bnf,ng->bfg') -> one MXU pass per b-tile.
    xw = w[:, :, None] * x[None, :, :]              # (BT, N, F)
    g = jnp.einsum("bnf,ng->bfg", xw, x,
                   preferred_element_type=jnp.float32)  # (BT, F, F)
    eye = jnp.eye(x.shape[1], dtype=jnp.float32)
    g_ref[...] = g + lam * eye[None, :, :]

    wy = w * y[None, :]                              # (BT, N)
    c_ref[...] = jnp.einsum("bn,nf->bf", wy, x,
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_gram(x, y, w, lam, *, interpret=True):
    """Batched masked Gram matrices via Pallas.

    Args:
      x:   (N, F) f32 design matrix (shared across masks).
      y:   (N,)   f32 targets.
      w:   (B, N) f32 mask/sample weights (0/1 for CV, arbitrary >= 0 ok).
      lam: scalar f32 ridge term added to the diagonal.
      interpret: run the kernel in interpret mode (required on CPU PJRT).

    Returns:
      (G, c): (B, F, F) and (B, F).
    """
    n, f = x.shape
    b = w.shape[0]
    # Pad the mask batch to a BT multiple (zero masks yield lam*I, sliced
    # away below), so callers are free to pass any B.
    pad = (-b) % BT
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad, n), w.dtype)], axis=0)
    bp = b + pad
    y2 = y.reshape(n, 1)
    lam2 = jnp.asarray(lam, jnp.float32).reshape(1, 1)

    grid = (bp // BT,)
    g, c = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, f), lambda i: (0, 0)),     # X: replicated
            pl.BlockSpec((n, 1), lambda i: (0, 0)),     # y: replicated
            pl.BlockSpec((BT, n), lambda i: (i, 0)),    # W: streamed by tile
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # lam
        ],
        out_specs=[
            pl.BlockSpec((BT, f, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((BT, f), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, f, f), jnp.float32),
            jax.ShapeDtypeStruct((bp, f), jnp.float32),
        ],
        interpret=interpret,
    )(x, y2, w, lam2)
    return g[:b], c[:b]
