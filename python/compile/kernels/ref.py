"""Pure-jnp correctness oracles for the L1 Pallas kernels and L2 models.

Everything here is the "obviously correct" formulation; pytest asserts the
Pallas kernels and the lowered HLO modules match these to within f32
tolerance.  The Rust native backend (rust/src/linalg) is additionally
cross-checked against the artifacts in rust/tests/runtime_parity.rs.
"""

import jax.numpy as jnp
import numpy as np


def masked_gram_ref(x, y, w, lam):
    """G[b] = X^T diag(w_b) X + lam I ; c[b] = X^T (w_b * y)."""
    xw = w[:, :, None] * x[None, :, :]                  # (B, N, F)
    g = jnp.einsum("bnf,ng->bfg", xw, x)                # (B, F, F)
    g = g + lam * jnp.eye(x.shape[1], dtype=x.dtype)[None]
    c = jnp.einsum("bn,nf->bf", w * y[None, :], x)      # (B, F)
    return g, c


def batched_predict_ref(xq, theta):
    """P[b] = Xq @ theta[b]."""
    return jnp.einsum("qf,bf->bq", xq, theta)


def ols_batch_ref(x, y, w, lam):
    """Reference batched ridge OLS via numpy's exact solver (f64)."""
    x64 = np.asarray(x, np.float64)
    y64 = np.asarray(y, np.float64)
    w64 = np.asarray(w, np.float64)
    b, f = w64.shape[0], x64.shape[1]
    thetas = np.zeros((b, f))
    for i in range(b):
        xw = x64 * w64[i][:, None]
        g = xw.T @ x64 + lam * np.eye(f)
        c = xw.T @ y64
        thetas[i] = np.linalg.solve(g, c)
    preds = thetas @ x64.T                              # (B, N)
    return thetas, preds


def nnls_batch_ref(x, y, w, lam):
    """Reference batched NNLS via scipy-free active projection (f64).

    Projected gradient with exact Lipschitz step, run to tight tolerance —
    the same algorithm as the L2 module but in f64 and until convergence,
    so it is a valid oracle for the K-iteration f32 version.
    """
    x64 = np.asarray(x, np.float64)
    y64 = np.asarray(y, np.float64)
    w64 = np.asarray(w, np.float64)
    b, f = w64.shape[0], x64.shape[1]
    thetas = np.zeros((b, f))
    for i in range(b):
        xw = x64 * w64[i][:, None]
        g = xw.T @ x64 + lam * np.eye(f)
        c = xw.T @ y64
        lip = np.linalg.eigvalsh(g).max()
        step = 1.0 / max(lip, 1e-12)
        th = np.zeros(f)
        for _ in range(20000):
            grad = g @ th - c
            nxt = np.maximum(th - step * grad, 0.0)
            if np.max(np.abs(nxt - th)) < 1e-12:
                th = nxt
                break
            th = nxt
        thetas[i] = th
    preds = thetas @ x64.T
    return thetas, preds
