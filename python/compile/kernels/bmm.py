"""Batched-prediction Pallas kernel (L1).

    P[b] = Xq @ theta[b]          (B, Q)

Used twice in the C3O runtime predictor:
  * inside cross-validation, to score every mask's model on the full
    training set in one launch (the held-out entries are picked out by the
    Rust side), and
  * in the configurator's scale-out sweep, where Xq is the feature matrix of
    every candidate scale-out and theta is the fitted model batch.

Grid iterates over B-tiles; Xq is replicated in VMEM, theta streamed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BT = 8  # masks per grid step (see gram.py)


def _predict_kernel(xq_ref, th_ref, p_ref):
    """xq_ref: (Q, F), th_ref: (BT, F), p_ref: (BT, Q)."""
    xq = xq_ref[...]                    # (Q, F)
    th = th_ref[...]                    # (BT, F)
    # (BT, F) @ (F, Q) on the MXU, f32 accumulation.
    p_ref[...] = jnp.dot(th, xq.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_predict(xq, theta, *, interpret=True):
    """P[b] = Xq @ theta[b].

    Args:
      xq:    (Q, F) f32 query design matrix.
      theta: (B, F) f32 fitted parameter batch.

    Returns:
      (B, Q) f32 predictions.
    """
    q, f = xq.shape
    b = theta.shape[0]
    # Pad the batch to a BT multiple; padded thetas are zero and their
    # rows are sliced away below.
    pad = (-b) % BT
    if pad:
        theta = jnp.concatenate([theta, jnp.zeros((pad, f), theta.dtype)], axis=0)
    bp = b + pad

    grid = (bp // BT,)
    out = pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, f), lambda i: (0, 0)),    # Xq: replicated
            pl.BlockSpec((BT, f), lambda i: (i, 0)),   # theta: streamed
        ],
        out_specs=pl.BlockSpec((BT, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, q), jnp.float32),
        interpret=interpret,
    )(xq, theta)
    return out[:b]
