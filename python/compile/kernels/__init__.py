"""L1 Pallas kernels for the C3O batched cross-validation hot path.

Exports:
    masked_gram      -- G[b] = X^T diag(w_b) X + lam*I,  c[b] = X^T diag(w_b) y
    batched_predict  -- P[b] = X @ theta[b]
    ref              -- pure-jnp oracles (correctness ground truth)
"""

from .gram import masked_gram
from .bmm import batched_predict
from . import ref

__all__ = ["masked_gram", "batched_predict", "ref"]
