//! Cross-validation: split generation and scoring (paper §V-C, §VI-C).
//!
//! The serial scorers here are the *reference semantics*; the fit-path
//! execution engine in [`parallel`] fans the same splits out across a
//! worker pool and must reproduce these scores bit-for-bit (asserted in
//! `parallel::tests` and `models::c3o::tests`).

pub mod parallel;

pub use parallel::{CvMethod, FitEngine, SampleStrategy, SelectionBudget, SelectionPlan};

use crate::models::{RuntimeModel, TrainData};
use crate::util::prng::Pcg;
use crate::util::stats;

/// Residual summary of a cross-validated model — feeds both dynamic model
/// selection (§V-C) and the configurator's Gaussian error model (§IV-B).
#[derive(Debug, Clone)]
pub struct CvScore {
    /// Mean absolute percentage error over held-out points.
    pub mape: f64,
    /// Mean of signed residuals (pred − actual), seconds: the paper's μ.
    pub resid_mean: f64,
    /// Std-dev of signed residuals, seconds: the paper's σ.
    pub resid_std: f64,
    /// Number of held-out evaluations.
    pub n: usize,
}

/// Leave-one-out CV of `model` over `data` (retrains per split unless the
/// model overrides `loo_predictions` with a batched path).
///
/// The model selection phase the paper caps at 10–30 s; E4 benches this.
pub fn loo_score(model: &dyn RuntimeModel, data: &TrainData) -> crate::Result<CvScore> {
    let preds = model.loo_predictions(data)?;
    Ok(score_from_preds(&preds, &data.y))
}

/// One fold's `(train, test)` index lists.
pub type FoldSplit = (Vec<usize>, Vec<usize>);

/// Seeded fold assignment for `n` points: shuffle once, fold `f` tests
/// every k-th point of the shuffled order. Shared by the serial scorer and
/// the parallel engine so both fit on byte-identical subsets.
pub fn kfold_splits(n: usize, k: usize, seed: u64) -> Vec<FoldSplit> {
    let mut order: Vec<usize> = (0..n).collect();
    Pcg::new(seed, 0xF0).shuffle(&mut order);
    // Membership bitmap instead of a `test.contains(i)` scan per training
    // point: the train list builds in O(n) per fold, not O(n²/k).
    let mut is_test = vec![false; n];
    let mut out = Vec::with_capacity(k);
    for fold in 0..k {
        let test: Vec<usize> =
            order.iter().copied().skip(fold).step_by(k).collect();
        for &i in &test {
            is_test[i] = true;
        }
        let train: Vec<usize> =
            order.iter().copied().filter(|&i| !is_test[i]).collect();
        for &i in &test {
            is_test[i] = false;
        }
        out.push((train, test));
    }
    out
}

/// K-fold CV (used when the training set outgrows the LOO budget, §VI-C:
/// "the model selection phase needs to be capped").
pub fn kfold_score(
    model: &dyn RuntimeModel,
    data: &TrainData,
    k: usize,
    seed: u64,
) -> crate::Result<CvScore> {
    let n = data.len();
    anyhow::ensure!(k >= 2 && n >= k, "kfold: need 2 <= k <= n");
    let mut preds = vec![0.0; n];
    let mut scratch = model.clone_unfitted();
    for (train, test) in kfold_splits(n, k, seed) {
        scratch.fit(&data.subset(&train))?;
        for &i in &test {
            preds[i] = scratch.predict_one(data.x.row(i))?;
        }
    }
    Ok(score_from_preds(&preds, &data.y))
}

/// Score pre-computed held-out predictions.
pub fn score_from_preds(preds: &[f64], actual: &[f64]) -> CvScore {
    let resid: Vec<f64> =
        preds.iter().zip(actual).map(|(p, a)| p - a).collect();
    CvScore {
        mape: stats::mape(preds, actual),
        resid_mean: stats::mean(&resid),
        resid_std: stats::std_dev(&resid),
        n: preds.len(),
    }
}

/// One train/test index split of `n` records with `n_train` training
/// points, drawn uniformly (the paper's 300-splits protocol).
pub fn train_test_split(n: usize, n_train: usize, rng: &mut Pcg) -> (Vec<usize>, Vec<usize>) {
    assert!(n_train < n, "need at least one test point");
    let idx = rng.sample_indices(n, n);
    let train = idx[..n_train].to_vec();
    let test = idx[n_train..].to_vec();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::models::{Gbm, GbmParams};

    fn linear_world(n: usize, seed: u64) -> TrainData {
        let mut rng = Pcg::seed(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.range(2, 13) as f64, rng.range_f64(10.0, 30.0)])
            .collect();
        let y = rows.iter().map(|r| 5.0 + 2.0 * r[1] + 30.0 / r[0]).collect();
        TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    #[test]
    fn loo_score_reasonable_for_gbm() {
        let data = linear_world(40, 1);
        let mut m = Gbm::new(GbmParams { n_estimators: 60, ..Default::default() });
        m.fit(&data).unwrap();
        let s = loo_score(&m, &data).unwrap();
        assert_eq!(s.n, 40);
        assert!(s.mape < 20.0, "mape={}", s.mape);
        assert!(s.resid_std > 0.0);
    }

    #[test]
    fn kfold_covers_every_point_once() {
        let data = linear_world(23, 2);
        let m = Gbm::with_defaults();
        let s = kfold_score(&m, &data, 5, 7).unwrap();
        assert_eq!(s.n, 23);
    }

    #[test]
    fn split_partitions() {
        let mut rng = Pcg::seed(3);
        let (train, test) = train_test_split(20, 6, &mut rng);
        assert_eq!(train.len(), 6);
        assert_eq!(test.len(), 14);
        let mut all: Vec<usize> = train.iter().chain(&test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn perfect_predictions_zero_error() {
        let s = score_from_preds(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        assert_eq!(s.mape, 0.0);
        assert_eq!(s.resid_mean, 0.0);
        assert_eq!(s.resid_std, 0.0);
    }

    #[test]
    fn biased_predictions_have_nonzero_mu() {
        // Constant +10s over-prediction: mu = 10, sigma = 0.
        let s = score_from_preds(&[110.0, 210.0], &[100.0, 200.0]);
        assert!((s.resid_mean - 10.0).abs() < 1e-12);
        assert!(s.resid_std < 1e-12);
    }

    #[test]
    fn kfold_splits_partition_every_fold() {
        let n = 23;
        let k = 5;
        let splits = kfold_splits(n, k, 7);
        assert_eq!(splits.len(), k);
        let mut tested: Vec<usize> = Vec::new();
        for (train, test) in &splits {
            assert_eq!(train.len() + test.len(), n);
            let mut all: Vec<usize> = train.iter().chain(test).copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>());
            tested.extend_from_slice(test);
        }
        // Every point is held out exactly once across folds.
        tested.sort_unstable();
        assert_eq!(tested, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn kfold_rejects_bad_k() {
        let data = linear_world(5, 4);
        let m = Gbm::with_defaults();
        assert!(kfold_score(&m, &data, 1, 0).is_err());
        assert!(kfold_score(&m, &data, 6, 0).is_err());
    }
}
