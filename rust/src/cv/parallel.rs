//! The fit-path execution engine (DESIGN.md §8).
//!
//! Model selection is the hub's cold-fit latency cliff: LOO retrains every
//! candidate once per training point, serially — the phase the paper caps
//! at 10–30 s (§VI-C). [`FitEngine`] fans the (candidate × split) work out
//! over a scoped worker pool ([`crate::util::par`]) while keeping the
//! exact split definitions, fit inputs and reduction order of the serial
//! scorers in [`crate::cv`] — scores are bit-identical and the same model
//! wins, whatever the thread count. Only candidates that declare
//! [`RuntimeModel::loo_splits_independent`] (the default per-row refit
//! loop: GBM, BOM, OGB) have their LOO rows fanned out; everything else —
//! Ernest's batched backend launch, any custom `loo_predictions`
//! override — runs as one whole-LOO task calling the model's own
//! implementation, so overrides keep their exact semantics.
//!
//! On top sits the **selection budget**: a wall-clock and/or point cap
//! that degrades the plan LOO → k-fold → reduced training set (uniform or
//! stratified-by-scale-out sampling, after arXiv 2111.07904's training
//! data reduction) instead of blowing the paper's envelope. Point caps are
//! fully deterministic; the wall-clock cap times one probe fit per
//! candidate on this machine and is therefore an estimate, not a
//! guarantee.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use crate::models::{RuntimeModel, TrainData};
use crate::util::par::par_map;
use crate::util::prng::Pcg;

use super::{kfold_splits, score_from_preds, CvScore};

/// Training points a budget reduction never goes below (keeps k-fold
/// meaningful and the optimistic models fittable).
const MIN_CV_POINTS: usize = 12;

/// Probe-subset size for wall-clock cost calibration.
const PROBE_POINTS: usize = 32;

/// How the CV training set is thinned when the budget demands reduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SampleStrategy {
    /// Seeded uniform subsample.
    Uniform,
    /// Keep the scale-out mix: sample proportionally within each scale-out
    /// group (arXiv 2111.07904's stratified reduction), so the optimistic
    /// models still see every cluster size after thinning.
    #[default]
    StratifiedByScaleOut,
}

/// Cost cap for one selection pass. `Default` is unlimited.
#[derive(Debug, Clone, Copy, Default)]
pub struct SelectionBudget {
    /// Wall-clock target (seconds) for the whole selection phase. Enforced
    /// by *planning*, not interruption: a timed probe fit per candidate
    /// estimates each plan's cost and the highest-fidelity plan that fits
    /// is chosen (LOO → k-fold → reduced set).
    pub max_seconds: Option<f64>,
    /// Hard cap on training points cross-validated; beyond it the CV set
    /// is sampled down with `strategy`. Deterministic given the seed.
    pub max_points: Option<usize>,
    /// How a reduced CV set is drawn.
    pub strategy: SampleStrategy,
}

impl SelectionBudget {
    pub fn is_unlimited(&self) -> bool {
        self.max_seconds.is_none() && self.max_points.is_none()
    }
}

/// CV scheme chosen by the planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CvMethod {
    /// Leave-one-out: one fit per training point per candidate.
    Loo,
    /// K-fold with the given k: k fits per candidate.
    KFold(usize),
}

/// What one selection pass actually did — recorded in the
/// [`crate::models::SelectionReport`] so budget degradation is observable.
#[derive(Debug, Clone)]
pub struct SelectionPlan {
    pub method: CvMethod,
    /// Rows (ascending) the CV ran on; `None` = the full training set.
    pub sample: Option<Vec<usize>>,
    /// Training points available.
    pub n_total: usize,
    /// Training points cross-validated.
    pub n_used: usize,
    /// Worker threads the engine resolved to.
    pub threads: usize,
}

impl SelectionPlan {
    /// True when the budget forced a training-set reduction.
    pub fn reduced(&self) -> bool {
        self.n_used < self.n_total
    }
}

/// The fit-path execution engine: a worker-thread count plus a selection
/// budget. `Default` is all cores, unlimited budget; [`FitEngine::serial`]
/// is the bit-identical single-threaded reference.
#[derive(Debug, Clone, Default)]
pub struct FitEngine {
    /// Worker threads for the candidate × split fan-out. 0 ⇒ available
    /// parallelism; 1 ⇒ fully serial.
    pub threads: usize,
    pub budget: SelectionBudget,
}

impl FitEngine {
    /// The serial reference engine (1 worker, no budget).
    pub fn serial() -> Self {
        FitEngine { threads: 1, budget: SelectionBudget::default() }
    }

    /// Parallel engine with no budget.
    pub fn with_threads(threads: usize) -> Self {
        FitEngine { threads, budget: SelectionBudget::default() }
    }

    fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        } else {
            self.threads
        }
    }

    /// Cross-validate every candidate on `data` under the engine's budget.
    ///
    /// Returns the executed plan plus one `Result<CvScore>` per candidate,
    /// in candidate order. A candidate whose fit or prediction fails on any
    /// split is an `Err` (callers disqualify it); the pass itself only
    /// fails on structural misuse (k < 2).
    pub fn score_candidates(
        &self,
        candidates: &[Box<dyn RuntimeModel>],
        data: &TrainData,
        loo_cap: usize,
        kfold_k: usize,
        seed: u64,
    ) -> crate::Result<(SelectionPlan, Vec<crate::Result<CvScore>>)> {
        anyhow::ensure!(kfold_k >= 2, "kfold: need k >= 2");
        anyhow::ensure!(!data.is_empty(), "no training data");
        let plan = self.plan(candidates, data, loo_cap, kfold_k, seed);
        let reduced;
        let cv_data = match &plan.sample {
            Some(idx) => {
                reduced = data.subset(idx);
                &reduced
            }
            None => data,
        };
        let score_start = crate::obs::now_us();
        let scores = match plan.method {
            CvMethod::Loo => self.loo_scores(candidates, cv_data),
            CvMethod::KFold(k) => self.kfold_scores(candidates, cv_data, k, seed),
        };
        crate::obs::metrics().record_since(crate::obs::Stage::CvScore, score_start);
        Ok((plan, scores))
    }

    /// Decide the CV method and training subset for this pass.
    ///
    /// Without `max_seconds` the plan is a pure function of `(n, loo_cap,
    /// kfold_k, budget, seed)`. Sets too small for k-fold fall back to LOO
    /// rather than erroring.
    fn plan(
        &self,
        candidates: &[Box<dyn RuntimeModel>],
        data: &TrainData,
        loo_cap: usize,
        kfold_k: usize,
        seed: u64,
    ) -> SelectionPlan {
        let n_total = data.len();
        let mut sample: Option<Vec<usize>> = None;
        let mut n_used = n_total;

        // Hard point cap first: it bounds the CV set regardless of speed.
        if let Some(cap) = self.budget.max_points {
            let target = cap.max(3).min(n_total);
            if target < n_total {
                sample = Some(sample_cv_indices(data, target, self.budget.strategy, seed));
                n_used = target;
            }
        }

        let mut method =
            if n_used <= loo_cap { CvMethod::Loo } else { CvMethod::KFold(kfold_k) };

        if let Some(t_max) = self.budget.max_seconds {
            let rates = self.probe_rates(candidates, data, seed);
            let rate_sum: f64 = rates.iter().sum();
            let w = self.resolved_threads() as f64;
            if method == CvMethod::Loo {
                // LOO ≈ n fits of ≈ n points (r·m²) per candidate. Row
                // tasks spread over the pool; a whole-LOO task is one
                // unsplittable unit, so the wall-clock floor is the
                // largest such task (planning charges overridden
                // implementations the full r·m² — a batched backend may
                // be cheaper, but a budget must not assume so: the
                // native NNLS "batch" is a per-mask solve loop).
                let m = n_used as f64;
                let whole_max: f64 = rates
                    .iter()
                    .zip(candidates)
                    .map(|(r, c)| if c.loo_splits_independent() { 0.0 } else { r * m * m })
                    .fold(0.0, f64::max);
                let total: f64 = rates.iter().map(|r| r * m * m).sum();
                let est_loo = (total / w).max(whole_max);
                if est_loo > t_max {
                    method = CvMethod::KFold(kfold_k);
                }
            }
            if let CvMethod::KFold(k) = method {
                // K-fold ≈ k fits of ≈ n points per candidate.
                let est_kfold = rate_sum * k as f64 * n_used as f64 / w;
                if est_kfold > t_max {
                    let floor = MIN_CV_POINTS.max(k).min(n_used);
                    let affordable =
                        (t_max * w / (rate_sum * k as f64).max(1e-12)) as usize;
                    let target = affordable.clamp(floor, n_used);
                    if target < n_used {
                        // Resample from the original data: deterministic
                        // given the target size.
                        sample = Some(sample_cv_indices(
                            data,
                            target,
                            self.budget.strategy,
                            seed,
                        ));
                        n_used = target;
                    }
                }
            }
        }

        // K-fold needs at least k points; tiny (possibly reduced) sets use
        // LOO, which is affordable there by construction.
        if let CvMethod::KFold(k) = method {
            if n_used < k {
                method = CvMethod::Loo;
            }
        }

        SelectionPlan {
            method,
            sample,
            n_total,
            n_used,
            threads: self.resolved_threads(),
        }
    }

    /// Time one fit per candidate on a small stratified probe subset and
    /// return per-(point·fit) cost estimates. Only runs when a wall-clock
    /// budget is set; a candidate whose probe fit errors rates as cheap
    /// and is disqualified during CV anyway.
    fn probe_rates(
        &self,
        candidates: &[Box<dyn RuntimeModel>],
        data: &TrainData,
        seed: u64,
    ) -> Vec<f64> {
        let m = PROBE_POINTS.min(data.len());
        let probe = if m < data.len() {
            data.subset(&sample_cv_indices(
                data,
                m,
                SampleStrategy::StratifiedByScaleOut,
                seed ^ 0x9E37,
            ))
        } else {
            data.clone()
        };
        par_map(candidates, self.threads, |_, c| {
            let mut scratch = c.clone_unfitted();
            let t0 = Instant::now();
            let _ = scratch.fit(&probe);
            (t0.elapsed().as_secs_f64() / m as f64).max(1e-9)
        })
    }

    /// LOO every candidate over one flat task pool. Row-loop candidates
    /// (`loo_splits_independent`) fan out one task per held-out row;
    /// everything else contributes a single whole-LOO task running its own
    /// `loo_predictions`. Reduction walks tasks in submission order;
    /// successful candidates score bit-identically to the serial loop,
    /// while a failing candidate short-circuits its remaining rows (it is
    /// disqualified either way — only the error text may differ).
    fn loo_scores(
        &self,
        candidates: &[Box<dyn RuntimeModel>],
        data: &TrainData,
    ) -> Vec<crate::Result<CvScore>> {
        let n = data.len();

        #[derive(Clone, Copy)]
        enum Task {
            Whole { cand: usize },
            Row { cand: usize, row: usize },
        }
        enum Out {
            Whole(crate::Result<Vec<f64>>),
            Row(crate::Result<f64>),
        }

        let mut tasks: Vec<Task> = Vec::new();
        for (cand, c) in candidates.iter().enumerate() {
            if c.loo_splits_independent() {
                for row in 0..n {
                    tasks.push(Task::Row { cand, row });
                }
            } else {
                tasks.push(Task::Whole { cand });
            }
        }

        // One flag per candidate: once any split fails, that candidate's
        // remaining row tasks short-circuit — it is disqualified either
        // way, so n-1 further doomed refits would be pure waste. Only the
        // reported error message can differ from the serial first-error.
        let failed: Vec<AtomicBool> =
            candidates.iter().map(|_| AtomicBool::new(false)).collect();

        let outs = par_map(&tasks, self.threads, |_, t| match *t {
            Task::Whole { cand } => Out::Whole(candidates[cand].loo_predictions(data)),
            Task::Row { cand, row } => {
                if failed[cand].load(Ordering::Relaxed) {
                    return Out::Row(Err(anyhow::anyhow!(
                        "skipped: candidate already failed an earlier split"
                    )));
                }
                let mut scratch = candidates[cand].clone_unfitted();
                let pred = match scratch.fit(&data.subset_excluding(row)) {
                    Ok(()) => scratch.predict_one(data.x.row(row)),
                    Err(e) => Err(e),
                };
                if pred.is_err() {
                    failed[cand].store(true, Ordering::Relaxed);
                }
                Out::Row(pred)
            }
        });

        let mut scores = Vec::with_capacity(candidates.len());
        let mut it = outs.into_iter();
        for c in candidates {
            if !c.loo_splits_independent() {
                let score = match it.next().expect("one whole-LOO task per candidate") {
                    Out::Whole(Ok(preds)) => Ok(score_from_preds(&preds, &data.y)),
                    Out::Whole(Err(e)) => Err(e),
                    Out::Row(..) => unreachable!("task shape mismatch"),
                };
                scores.push(score);
            } else {
                // Row tasks were scheduled in row order, so the walk
                // position is the held-out row.
                let mut preds = vec![0.0; n];
                let mut err: Option<anyhow::Error> = None;
                for (row, slot) in preds.iter_mut().enumerate() {
                    match it.next().expect("one LOO task per row") {
                        Out::Row(Ok(p)) => *slot = p,
                        Out::Row(Err(e)) => {
                            if err.is_none() {
                                err = Some(e);
                            }
                        }
                        Out::Whole(..) => unreachable!("task shape mismatch at row {row}"),
                    }
                }
                scores.push(match err {
                    None => Ok(score_from_preds(&preds, &data.y)),
                    Some(e) => Err(e),
                });
            }
        }
        scores
    }

    /// K-fold every candidate over one flat (candidate × fold) task pool,
    /// on the exact fold assignment of [`kfold_splits`].
    fn kfold_scores(
        &self,
        candidates: &[Box<dyn RuntimeModel>],
        data: &TrainData,
        k: usize,
        seed: u64,
    ) -> Vec<crate::Result<CvScore>> {
        let n = data.len();
        let splits = kfold_splits(n, k, seed);

        #[derive(Clone, Copy)]
        struct Task {
            cand: usize,
            fold: usize,
        }
        let tasks: Vec<Task> = (0..candidates.len())
            .flat_map(|cand| (0..k).map(move |fold| Task { cand, fold }))
            .collect();

        let outs = par_map(&tasks, self.threads, |_, t| -> crate::Result<Vec<f64>> {
            let (train, test) = &splits[t.fold];
            let mut scratch = candidates[t.cand].clone_unfitted();
            scratch.fit(&data.subset(train))?;
            test.iter().map(|&i| scratch.predict_one(data.x.row(i))).collect()
        });

        let mut scores = Vec::with_capacity(candidates.len());
        let mut it = outs.into_iter();
        for _ in candidates {
            let mut preds = vec![0.0; n];
            let mut err: Option<anyhow::Error> = None;
            for (_, test) in splits.iter().take(k) {
                match it.next().expect("one task per fold") {
                    Ok(fold_preds) => {
                        for (&i, p) in test.iter().zip(fold_preds) {
                            preds[i] = p;
                        }
                    }
                    Err(e) => {
                        if err.is_none() {
                            err = Some(e);
                        }
                    }
                }
            }
            scores.push(match err {
                None => Ok(score_from_preds(&preds, &data.y)),
                Some(e) => Err(e),
            });
        }
        scores
    }
}

/// Draw a deterministic `target`-row CV subset (ascending indices).
pub fn sample_cv_indices(
    data: &TrainData,
    target: usize,
    strategy: SampleStrategy,
    seed: u64,
) -> Vec<usize> {
    let n = data.len();
    if target >= n {
        return (0..n).collect();
    }
    let mut rng = Pcg::new(seed, 0x5A11);
    let mut picked = match strategy {
        SampleStrategy::Uniform => rng.sample_indices(n, target),
        SampleStrategy::StratifiedByScaleOut => {
            // Group rows by scale-out (feature 0). HashMap order is not
            // deterministic, so groups are sorted by value before use.
            let mut by_key: std::collections::HashMap<u64, Vec<usize>> =
                std::collections::HashMap::new();
            for i in 0..n {
                by_key.entry(data.x.row(i)[0].to_bits()).or_default().push(i);
            }
            let mut groups: Vec<(u64, Vec<usize>)> = by_key.into_iter().collect();
            groups.sort_by(|a, b| f64::from_bits(a.0).total_cmp(&f64::from_bits(b.0)));

            // Largest-remainder proportional allocation per group.
            let mut quotas: Vec<usize> = Vec::with_capacity(groups.len());
            let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(groups.len());
            let mut assigned = 0usize;
            for (gi, (_, idx)) in groups.iter().enumerate() {
                let exact = target as f64 * idx.len() as f64 / n as f64;
                let q = (exact.floor() as usize).min(idx.len());
                quotas.push(q);
                assigned += q;
                fracs.push((exact - q as f64, gi));
            }
            // Ties break toward smaller scale-outs for determinism. Total
            // group capacity is n ≥ target, so the cycle terminates.
            fracs.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            let mut remaining = target - assigned;
            let mut at = 0usize;
            while remaining > 0 {
                let gi = fracs[at % fracs.len()].1;
                if quotas[gi] < groups[gi].1.len() {
                    quotas[gi] += 1;
                    remaining -= 1;
                }
                at += 1;
            }

            let mut picked = Vec::with_capacity(target);
            for (gi, (_, idx)) in groups.iter().enumerate() {
                let mut pool = idx.clone();
                rng.shuffle(&mut pool);
                picked.extend_from_slice(&pool[..quotas[gi]]);
            }
            picked
        }
    };
    picked.sort_unstable();
    picked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::{kfold_score, loo_score};
    use crate::linalg::Matrix;
    use crate::models::{Gbm, GbmParams};

    fn linear_world(n: usize, seed: u64) -> TrainData {
        let mut rng = Pcg::seed(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.range(2, 13) as f64, rng.range_f64(10.0, 30.0)])
            .collect();
        let y = rows.iter().map(|r| 5.0 + 2.0 * r[1] + 30.0 / r[0]).collect();
        TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    fn gbm_candidates() -> Vec<Box<dyn RuntimeModel>> {
        vec![
            Box::new(Gbm::with_defaults()),
            Box::new(Gbm::new(GbmParams { n_estimators: 40, ..Default::default() })),
        ]
    }

    fn assert_score_bits(a: &CvScore, b: &CvScore) {
        assert_eq!(a.mape.to_bits(), b.mape.to_bits());
        assert_eq!(a.resid_mean.to_bits(), b.resid_mean.to_bits());
        assert_eq!(a.resid_std.to_bits(), b.resid_std.to_bits());
        assert_eq!(a.n, b.n);
    }

    #[test]
    fn engine_loo_matches_serial_scorer_bitwise() {
        let data = linear_world(24, 1);
        let candidates = gbm_candidates();
        let engine = FitEngine::with_threads(4);
        let (plan, scores) = engine
            .score_candidates(&candidates, &data, 120, 10, 0xC30)
            .unwrap();
        assert_eq!(plan.method, CvMethod::Loo);
        assert!(!plan.reduced());
        for (c, s) in candidates.iter().zip(&scores) {
            let reference = loo_score(c.as_ref(), &data).unwrap();
            assert_score_bits(s.as_ref().unwrap(), &reference);
        }
    }

    #[test]
    fn engine_kfold_matches_serial_scorer_bitwise() {
        let data = linear_world(37, 2);
        let candidates = gbm_candidates();
        let engine = FitEngine::with_threads(4);
        // loo_cap 0 forces the k-fold branch.
        let (plan, scores) =
            engine.score_candidates(&candidates, &data, 0, 5, 7).unwrap();
        assert_eq!(plan.method, CvMethod::KFold(5));
        for (c, s) in candidates.iter().zip(&scores) {
            let reference = kfold_score(c.as_ref(), &data, 5, 7).unwrap();
            assert_score_bits(s.as_ref().unwrap(), &reference);
        }
    }

    #[test]
    fn serial_and_parallel_engines_agree_bitwise() {
        let data = linear_world(40, 3);
        let candidates = gbm_candidates();
        let (_, serial) = FitEngine::serial()
            .score_candidates(&candidates, &data, 20, 8, 11)
            .unwrap();
        let (_, parallel) = FitEngine::with_threads(8)
            .score_candidates(&candidates, &data, 20, 8, 11)
            .unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_score_bits(a.as_ref().unwrap(), b.as_ref().unwrap());
        }
    }

    #[test]
    fn failing_candidate_is_an_err_not_a_crash() {
        struct Broken;
        impl RuntimeModel for Broken {
            fn name(&self) -> &'static str {
                "Broken"
            }
            fn fit(&mut self, _d: &TrainData) -> crate::Result<()> {
                anyhow::bail!("nope")
            }
            fn predict_one(&self, _f: &[f64]) -> crate::Result<f64> {
                anyhow::bail!("nope")
            }
            fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
                Box::new(Broken)
            }
        }
        let data = linear_world(12, 4);
        let candidates: Vec<Box<dyn RuntimeModel>> =
            vec![Box::new(Broken), Box::new(Gbm::with_defaults())];
        let (_, scores) = FitEngine::with_threads(4)
            .score_candidates(&candidates, &data, 120, 10, 0)
            .unwrap();
        assert!(scores[0].is_err());
        assert!(scores[1].is_ok());
    }

    #[test]
    fn custom_loo_override_runs_whole_not_per_row() {
        // A model that overrides `loo_predictions` (without opting into
        // row fan-out) must be scored through its own override — the
        // engine may not silently substitute per-row refits.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;

        struct CountingLoo {
            calls: Arc<AtomicUsize>,
        }
        impl RuntimeModel for CountingLoo {
            fn name(&self) -> &'static str {
                "CountingLoo"
            }
            fn fit(&mut self, _d: &TrainData) -> crate::Result<()> {
                Ok(())
            }
            fn predict_one(&self, _f: &[f64]) -> crate::Result<f64> {
                Ok(1.0)
            }
            fn loo_predictions(&self, data: &TrainData) -> crate::Result<Vec<f64>> {
                self.calls.fetch_add(1, Ordering::SeqCst);
                // A shortcut whose numbers differ from per-row refits.
                Ok(vec![7.0; data.len()])
            }
            fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
                Box::new(CountingLoo { calls: self.calls.clone() })
            }
        }

        let data = linear_world(10, 9);
        let calls = Arc::new(AtomicUsize::new(0));
        let candidates: Vec<Box<dyn RuntimeModel>> =
            vec![Box::new(CountingLoo { calls: calls.clone() })];
        let (_, scores) = FitEngine::with_threads(4)
            .score_candidates(&candidates, &data, 120, 10, 0)
            .unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 1, "override called exactly once");
        let s = scores[0].as_ref().unwrap();
        // score_from_preds over the override's constant 7.0 predictions.
        let expected = crate::cv::score_from_preds(&[7.0; 10], &data.y);
        assert_eq!(s.mape.to_bits(), expected.mape.to_bits());
    }

    #[test]
    fn point_budget_reduces_deterministically() {
        let data = linear_world(90, 5);
        let budget = SelectionBudget {
            max_points: Some(30),
            ..SelectionBudget::default()
        };
        let engine = FitEngine { threads: 2, budget };
        let (plan_a, scores_a) =
            engine.score_candidates(&gbm_candidates(), &data, 120, 10, 1).unwrap();
        let (plan_b, scores_b) =
            engine.score_candidates(&gbm_candidates(), &data, 120, 10, 1).unwrap();
        assert_eq!(plan_a.n_used, 30);
        assert!(plan_a.reduced());
        assert_eq!(plan_a.sample, plan_b.sample);
        for (a, b) in scores_a.iter().zip(&scores_b) {
            assert_score_bits(a.as_ref().unwrap(), b.as_ref().unwrap());
        }
    }

    #[test]
    fn wall_clock_budget_degrades_to_reduced_kfold() {
        let data = linear_world(200, 6);
        let budget = SelectionBudget {
            max_seconds: Some(1e-9),
            ..SelectionBudget::default()
        };
        let engine = FitEngine { threads: 2, budget };
        let (plan, scores) =
            engine.score_candidates(&gbm_candidates(), &data, 120, 10, 2).unwrap();
        // An impossibly tight budget bottoms out at the reduction floor.
        assert!(plan.reduced(), "plan must reduce: {plan:?}");
        assert_eq!(plan.n_used, 12);
        assert_eq!(plan.method, CvMethod::KFold(10));
        for s in &scores {
            assert!(s.as_ref().unwrap().mape.is_finite());
        }
    }

    #[test]
    fn stratified_sample_preserves_scaleout_mix() {
        // 3 scale-out groups of 30 each; a 15-point sample keeps 5 of each.
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for g in 0..3 {
            for i in 0..30 {
                rows.push(vec![(2 + g * 4) as f64, 10.0 + i as f64]);
                y.push(100.0 / (2 + g * 4) as f64 + i as f64);
            }
        }
        let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();
        let idx =
            sample_cv_indices(&data, 15, SampleStrategy::StratifiedByScaleOut, 3);
        assert_eq!(idx.len(), 15);
        for g in 0..3usize {
            let lo = g * 30;
            let hi = lo + 30;
            let in_group = idx.iter().filter(|&&i| i >= lo && i < hi).count();
            assert_eq!(in_group, 5, "group {g}: {in_group} of 5");
        }
        // Ascending and duplicate-free.
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn uniform_sample_is_sorted_and_distinct() {
        let data = linear_world(50, 7);
        let idx = sample_cv_indices(&data, 20, SampleStrategy::Uniform, 9);
        assert_eq!(idx.len(), 20);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn tiny_sets_fall_back_to_loo_instead_of_erroring() {
        let data = linear_world(5, 8);
        // loo_cap 0 would pick k-fold, but n < k: the guard falls back.
        let (plan, scores) = FitEngine::serial()
            .score_candidates(&gbm_candidates(), &data, 0, 10, 0)
            .unwrap();
        assert_eq!(plan.method, CvMethod::Loo);
        assert!(scores[0].is_ok());
    }
}
