//! Hub wire protocol **v1**: typed request/response frames.
//!
//! Every frame is one newline-delimited JSON object. Requests carry an
//! explicit protocol version `v`, a client-chosen correlation `id`, an op
//! name and the op's fields; responses echo `v` and `id` and carry either
//! a `payload` object (`ok: true`) or a structured `error{code, message}`
//! (`ok: false`). All serialization funnels through this module — neither
//! [`crate::hub::server`] nor [`crate::hub::client`] builds raw
//! [`Json`] frames.
//!
//! See `DESIGN.md` §4 for the full specification with one example frame
//! per op.

use anyhow::Context;

use crate::configurator::{
    CatalogSearch, ConfigChoice, FrontierEntry, ScaleOutOption, TypeOutcome, TypeReport,
};
use crate::data::JobKind;
use crate::util::json::Json;

/// The wire version this build speaks. Bump on breaking frame changes;
/// servers reject other versions with [`ErrorCode::VersionMismatch`].
pub const PROTOCOL_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Machine-readable error categories carried in `error.code`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Frame is not a JSON object / not parseable at all.
    BadRequest,
    /// Missing or unsupported protocol version `v`.
    VersionMismatch,
    /// A required field is absent or has the wrong type.
    MissingField,
    /// The op name is not part of this protocol version.
    UnknownOp,
    /// The referenced entity (repository, machine type) does not exist.
    NotFound,
    /// The request parsed but its content is invalid (bad TSV, wrong
    /// feature arity, out-of-range confidence, ...).
    InvalidData,
    /// The hub cannot serve this yet (e.g. not enough runtime data to fit).
    Unavailable,
    /// The hub is a read-only follower; writes must go to the leader
    /// named in the error message (DESIGN.md §11).
    NotLeader,
    /// Unexpected server-side failure.
    Internal,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::VersionMismatch => "version_mismatch",
            ErrorCode::MissingField => "missing_field",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::NotFound => "not_found",
            ErrorCode::InvalidData => "invalid_data",
            ErrorCode::Unavailable => "unavailable",
            ErrorCode::NotLeader => "not_leader",
            ErrorCode::Internal => "internal",
        }
    }

    /// Decode a wire code; unknown codes (from a newer server) degrade to
    /// [`ErrorCode::Internal`] rather than failing the whole reply.
    pub fn from_wire(s: &str) -> ErrorCode {
        match s {
            "bad_request" => ErrorCode::BadRequest,
            "version_mismatch" => ErrorCode::VersionMismatch,
            "missing_field" => ErrorCode::MissingField,
            "unknown_op" => ErrorCode::UnknownOp,
            "not_found" => ErrorCode::NotFound,
            "invalid_data" => ErrorCode::InvalidData,
            "unavailable" => ErrorCode::Unavailable,
            "not_leader" => ErrorCode::NotLeader,
            _ => ErrorCode::Internal,
        }
    }
}

/// A structured protocol error: what went wrong, machine- and
/// human-readable.
#[derive(Debug, Clone)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
}

impl WireError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        WireError { code, message: message.into() }
    }

    /// Wrap an internal error chain.
    pub fn internal(e: &anyhow::Error) -> Self {
        WireError::new(ErrorCode::Internal, format!("{e:#}"))
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::Str(self.code.as_str().to_string())),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// Incremental frame decoding (reactor read path)
// ---------------------------------------------------------------------------

/// Hard cap on a single wire frame. Far above any legitimate request
/// (the largest — a full-corpus `submit_runs` — is a few MiB) yet small
/// enough that one misbehaving peer cannot buffer the hub into the
/// ground.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Incremental newline-frame assembler for the non-blocking read path.
///
/// The reactor hands this whatever `read(2)` produced — frames split at
/// arbitrary byte boundaries, several frames per chunk, interleaved
/// arrival across connections (one decoder per connection) — and pulls
/// out complete lines via [`FrameDecoder::next_frame`].
///
/// The length cap is enforced **before** buffering: a segment that would
/// push the current partial frame past `max_frame` is rejected without
/// copying it in, and the decoder poisons itself (the connection is
/// protocol-broken — resynchronizing on the next newline would mis-frame
/// whatever follows).
#[derive(Debug)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Consumed prefix of `buf`; compacted opportunistically.
    start: usize,
    /// Bytes of the trailing partial frame (after the last newline).
    tail_len: usize,
    max_frame: usize,
    poisoned: bool,
}

impl FrameDecoder {
    pub fn new(max_frame: usize) -> FrameDecoder {
        FrameDecoder { buf: Vec::new(), start: 0, tail_len: 0, max_frame, poisoned: false }
    }

    /// Append raw bytes from the socket. `Err` means the peer sent a
    /// frame longer than `max_frame`; the oversized bytes were *not*
    /// buffered and the decoder yields no further frames.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<(), WireError> {
        if self.poisoned {
            return Err(self.overflow());
        }
        let mut rest = chunk;
        while !rest.is_empty() {
            match rest.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if self.tail_len + pos > self.max_frame {
                        self.poisoned = true;
                        return Err(self.overflow());
                    }
                    // `pos < rest.len()` from `position`, so the split
                    // point is in range.
                    let (frame, after) = rest.split_at(pos + 1);
                    self.buf.extend_from_slice(frame);
                    self.tail_len = 0;
                    rest = after;
                }
                None => {
                    if self.tail_len + rest.len() > self.max_frame {
                        self.poisoned = true;
                        return Err(self.overflow());
                    }
                    self.buf.extend_from_slice(rest);
                    self.tail_len += rest.len();
                    rest = &[];
                }
            }
        }
        Ok(())
    }

    /// The next complete frame, if one is buffered. Strips the trailing
    /// `\n` (and one `\r` before it, for telnet-style peers). Returns
    /// `None` once poisoned — even for frames completed before the
    /// overflow — because the connection is being torn down anyway.
    pub fn next_frame(&mut self) -> Option<String> {
        if self.poisoned {
            return None;
        }
        let pending = self.buf.get(self.start..)?;
        let pos = pending.iter().position(|&b| b == b'\n')?;
        let mut frame = pending.get(..pos)?;
        if let Some((&b'\r', head)) = frame.split_last() {
            frame = head;
        }
        let line = String::from_utf8_lossy(frame).into_owned();
        self.start += pos + 1;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > 64 * 1024 {
            // Keep the consumed prefix from growing unboundedly under a
            // firehose of small frames.
            self.buf.drain(..self.start);
            self.start = 0;
        }
        Some(line)
    }

    /// Bytes buffered but not yet returned (bounded by `max_frame` plus
    /// completed-but-unpulled frames).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    fn overflow(&self) -> WireError {
        WireError::new(
            ErrorCode::BadRequest,
            format!("frame exceeds {} bytes", self.max_frame),
        )
    }
}

impl Default for FrameDecoder {
    fn default() -> FrameDecoder {
        FrameDecoder::new(MAX_FRAME_BYTES)
    }
}

// ---------------------------------------------------------------------------
// Field helpers (server-side decode -> WireError)
// ---------------------------------------------------------------------------

fn need_str<'a>(frame: &'a Json, key: &str) -> Result<&'a str, WireError> {
    frame.get(key).and_then(Json::as_str).ok_or_else(|| {
        WireError::new(
            ErrorCode::MissingField,
            format!("missing or non-string field `{key}`"),
        )
    })
}

fn need_f64(frame: &Json, key: &str) -> Result<f64, WireError> {
    frame.get(key).and_then(Json::as_f64).ok_or_else(|| {
        WireError::new(
            ErrorCode::MissingField,
            format!("missing or non-numeric field `{key}`"),
        )
    })
}

fn opt_str(frame: &Json, key: &str) -> Option<String> {
    frame.get(key).and_then(Json::as_str).map(|s| s.to_string())
}

fn opt_f64(frame: &Json, key: &str) -> Option<f64> {
    frame.get(key).and_then(Json::as_f64)
}

fn need_u64(frame: &Json, key: &str) -> Result<u64, WireError> {
    frame.get(key).and_then(Json::as_u64).ok_or_else(|| {
        WireError::new(
            ErrorCode::MissingField,
            format!("missing or non-integer field `{key}`"),
        )
    })
}

fn need_job(frame: &Json) -> Result<JobKind, WireError> {
    need_str(frame, "job")?
        .parse::<JobKind>()
        .map_err(|e| WireError::new(ErrorCode::InvalidData, format!("{e:#}")))
}

fn f64_array(j: &Json, key: &str) -> Result<Vec<f64>, WireError> {
    let arr = j.get(key).and_then(Json::as_arr).ok_or_else(|| {
        WireError::new(
            ErrorCode::MissingField,
            format!("missing or non-array field `{key}`"),
        )
    })?;
    arr.iter()
        .map(|x| {
            x.as_f64().ok_or_else(|| {
                WireError::new(
                    ErrorCode::InvalidData,
                    format!("field `{key}` must contain only numbers"),
                )
            })
        })
        .collect()
}

fn opt_f64_array(j: &Json, key: &str) -> Result<Vec<f64>, WireError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(Vec::new()),
        Some(_) => f64_array(j, key),
    }
}

fn rows_array(j: &Json, key: &str) -> Result<Vec<Vec<f64>>, WireError> {
    let arr = j.get(key).and_then(Json::as_arr).ok_or_else(|| {
        WireError::new(
            ErrorCode::MissingField,
            format!("missing or non-array field `{key}`"),
        )
    })?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, row) in arr.iter().enumerate() {
        let cells = row.as_arr().ok_or_else(|| {
            WireError::new(
                ErrorCode::InvalidData,
                format!("`{key}[{i}]` must be an array of numbers"),
            )
        })?;
        out.push(
            cells
                .iter()
                .map(|x| {
                    x.as_f64().ok_or_else(|| {
                        WireError::new(
                            ErrorCode::InvalidData,
                            format!("`{key}[{i}]` must contain only numbers"),
                        )
                    })
                })
                .collect::<Result<Vec<f64>, WireError>>()?,
        );
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Field helpers (client-side decode -> anyhow)
// ---------------------------------------------------------------------------

fn jstr(j: &Json, key: &str) -> crate::Result<String> {
    Ok(j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("payload missing string `{key}`"))?
        .to_string())
}

fn jf64(j: &Json, key: &str) -> crate::Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("payload missing number `{key}`"))
}

fn ju64(j: &Json, key: &str) -> crate::Result<u64> {
    j.get(key)
        .and_then(Json::as_u64)
        .with_context(|| format!("payload missing integer `{key}`"))
}

fn jbool(j: &Json, key: &str) -> crate::Result<bool> {
    j.get(key)
        .and_then(Json::as_bool)
        .with_context(|| format!("payload missing bool `{key}`"))
}

fn jf64_arr(j: &Json, key: &str) -> crate::Result<Vec<f64>> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("payload missing array `{key}`"))?
        .iter()
        .map(|x| x.as_f64().with_context(|| format!("`{key}`: non-numeric element")))
        .collect()
}

fn opt_string(j: &Json, key: &str) -> Option<String> {
    j.get(key).and_then(Json::as_str).map(|s| s.to_string())
}

fn f64s_to_json(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

// ---------------------------------------------------------------------------
// Ops
// ---------------------------------------------------------------------------

/// The v1 operation set.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Browse available repositories (Fig. 4 step 1).
    ListRepos,
    /// Download a repository's metadata + runtime data (Fig. 4 step 2).
    GetRepo { job: JobKind },
    /// Contribute runtime data; goes through the §III-C-b gate.
    SubmitRuns { job: JobKind, data_tsv: String },
    /// The hub's machine-type catalog.
    Catalog,
    /// Hub + prediction-service counters.
    Stats,
    /// Full telemetry snapshot (DESIGN.md §13): per-stage latency
    /// histograms, counters, and gauges. Additive within v1.
    Metrics,
    /// Server-side prediction for one feature row.
    Predict {
        job: JobKind,
        machine_type: Option<String>,
        features: Vec<f64>,
    },
    /// Server-side prediction for many rows against ONE fitted model (the
    /// E4 hot path, answered from the fitted-model cache).
    PredictBatch {
        job: JobKind,
        machine_type: Option<String>,
        rows: Vec<Vec<f64>>,
    },
    /// Full §IV configuration (machine type + scale-out) on the hub.
    Configure {
        job: JobKind,
        data_size_gb: f64,
        context: Vec<f64>,
        deadline_s: Option<f64>,
        confidence: f64,
        machine_type: Option<String>,
    },
    /// Catalog-wide configuration search on the hub: the full
    /// (machine type × scale-out) grid, one fitted model per type out of
    /// the revision-keyed cache, returning the cost-optimal admissible
    /// configuration plus the ranked frontier and per-type outcomes.
    ConfigureSearch {
        job: JobKind,
        data_size_gb: f64,
        context: Vec<f64>,
        deadline_s: Option<f64>,
        confidence: f64,
    },
    /// Replication handshake (DESIGN.md §11): a follower announces its
    /// revision watermark for `job` and learns the leader's revision and
    /// whether the watermark fell behind the leader's compaction horizon
    /// (⇒ snapshot bootstrap required before tailing).
    ReplSubscribe { job: JobKind, from_revision: u64 },
    /// Ship up to `max` WAL records with `revision > from_revision` for
    /// `job`, in append order — the log-shipping read.
    ReplFetch { job: JobKind, from_revision: u64, max: u64 },
    /// Cold-bootstrap transfer: every repository's current corpus image
    /// (a superset of the latest compacted snapshot), serialized with the
    /// same TSV codec the disk snapshots use.
    ReplSnapshot,
    /// Ask the server to stop accepting connections and quiesce.
    Shutdown,
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::ListRepos => "list_repos",
            Op::GetRepo { .. } => "get_repo",
            Op::SubmitRuns { .. } => "submit_runs",
            Op::Catalog => "catalog",
            Op::Stats => "stats",
            Op::Metrics => "metrics",
            Op::Predict { .. } => "predict",
            Op::PredictBatch { .. } => "predict_batch",
            Op::Configure { .. } => "configure",
            Op::ConfigureSearch { .. } => "configure_search",
            Op::ReplSubscribe { .. } => "repl_subscribe",
            Op::ReplFetch { .. } => "repl_fetch",
            Op::ReplSnapshot => "repl_snapshot",
            Op::Shutdown => "shutdown",
        }
    }

    fn encode_fields(&self, pairs: &mut Vec<(&'static str, Json)>) {
        match self {
            Op::ListRepos
            | Op::Catalog
            | Op::Stats
            | Op::Metrics
            | Op::ReplSnapshot
            | Op::Shutdown => {}
            Op::ReplSubscribe { job, from_revision } => {
                pairs.push(("job", Json::Str(job.to_string())));
                pairs.push(("from_revision", Json::Num(*from_revision as f64)));
            }
            Op::ReplFetch { job, from_revision, max } => {
                pairs.push(("job", Json::Str(job.to_string())));
                pairs.push(("from_revision", Json::Num(*from_revision as f64)));
                pairs.push(("max", Json::Num(*max as f64)));
            }
            Op::GetRepo { job } => pairs.push(("job", Json::Str(job.to_string()))),
            Op::SubmitRuns { job, data_tsv } => {
                pairs.push(("job", Json::Str(job.to_string())));
                pairs.push(("data_tsv", Json::Str(data_tsv.clone())));
            }
            Op::Predict { job, machine_type, features } => {
                pairs.push(("job", Json::Str(job.to_string())));
                if let Some(m) = machine_type {
                    pairs.push(("machine_type", Json::Str(m.clone())));
                }
                pairs.push(("features", f64s_to_json(features)));
            }
            Op::PredictBatch { job, machine_type, rows } => {
                pairs.push(("job", Json::Str(job.to_string())));
                if let Some(m) = machine_type {
                    pairs.push(("machine_type", Json::Str(m.clone())));
                }
                pairs.push((
                    "rows",
                    Json::Arr(rows.iter().map(|r| f64s_to_json(r)).collect()),
                ));
            }
            Op::Configure {
                job,
                data_size_gb,
                context,
                deadline_s,
                confidence,
                machine_type,
            } => {
                pairs.push(("job", Json::Str(job.to_string())));
                pairs.push(("data_size_gb", Json::Num(*data_size_gb)));
                pairs.push(("context", f64s_to_json(context)));
                if let Some(d) = deadline_s {
                    pairs.push(("deadline_s", Json::Num(*d)));
                }
                pairs.push(("confidence", Json::Num(*confidence)));
                if let Some(m) = machine_type {
                    pairs.push(("machine_type", Json::Str(m.clone())));
                }
            }
            Op::ConfigureSearch { job, data_size_gb, context, deadline_s, confidence } => {
                pairs.push(("job", Json::Str(job.to_string())));
                pairs.push(("data_size_gb", Json::Num(*data_size_gb)));
                pairs.push(("context", f64s_to_json(context)));
                if let Some(d) = deadline_s {
                    pairs.push(("deadline_s", Json::Num(*d)));
                }
                pairs.push(("confidence", Json::Num(*confidence)));
            }
        }
    }

    fn decode(name: &str, frame: &Json) -> Result<Op, WireError> {
        Ok(match name {
            "list_repos" => Op::ListRepos,
            "get_repo" => Op::GetRepo { job: need_job(frame)? },
            "submit_runs" => Op::SubmitRuns {
                job: need_job(frame)?,
                data_tsv: need_str(frame, "data_tsv")?.to_string(),
            },
            "catalog" => Op::Catalog,
            "stats" => Op::Stats,
            "metrics" => Op::Metrics,
            "predict" => Op::Predict {
                job: need_job(frame)?,
                machine_type: opt_str(frame, "machine_type"),
                features: f64_array(frame, "features")?,
            },
            "predict_batch" => Op::PredictBatch {
                job: need_job(frame)?,
                machine_type: opt_str(frame, "machine_type"),
                rows: rows_array(frame, "rows")?,
            },
            "configure" => Op::Configure {
                job: need_job(frame)?,
                data_size_gb: need_f64(frame, "data_size_gb")?,
                context: opt_f64_array(frame, "context")?,
                deadline_s: opt_f64(frame, "deadline_s"),
                confidence: opt_f64(frame, "confidence").unwrap_or(0.95),
                machine_type: opt_str(frame, "machine_type"),
            },
            "configure_search" => Op::ConfigureSearch {
                job: need_job(frame)?,
                data_size_gb: need_f64(frame, "data_size_gb")?,
                context: opt_f64_array(frame, "context")?,
                deadline_s: opt_f64(frame, "deadline_s"),
                confidence: opt_f64(frame, "confidence").unwrap_or(0.95),
            },
            "repl_subscribe" => Op::ReplSubscribe {
                job: need_job(frame)?,
                from_revision: need_u64(frame, "from_revision")?,
            },
            "repl_fetch" => Op::ReplFetch {
                job: need_job(frame)?,
                from_revision: need_u64(frame, "from_revision")?,
                max: need_u64(frame, "max")?,
            },
            "repl_snapshot" => Op::ReplSnapshot,
            "shutdown" => Op::Shutdown,
            other => {
                return Err(WireError::new(
                    ErrorCode::UnknownOp,
                    format!("unknown op: {other}"),
                ))
            }
        })
    }
}

// ---------------------------------------------------------------------------
// Request
// ---------------------------------------------------------------------------

/// One request frame: `{v, id, op, ...op fields}`.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub v: u64,
    pub id: u64,
    pub op: Op,
}

/// Why a request line could not be turned into a [`Request`]. Carries the
/// best-effort `id` recovered from the frame so the error response can
/// still be correlated (0 when the frame was unreadable).
#[derive(Debug, Clone)]
pub struct RequestParseError {
    pub id: u64,
    pub error: WireError,
}

impl Request {
    pub fn new(id: u64, op: Op) -> Self {
        Request { v: PROTOCOL_VERSION, id, op }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("v", Json::Num(self.v as f64)),
            ("id", Json::Num(self.id as f64)),
            ("op", Json::Str(self.op.name().to_string())),
        ];
        self.op.encode_fields(&mut pairs);
        Json::obj(pairs)
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse + validate one request line (server side).
    pub fn parse(line: &str) -> Result<Request, RequestParseError> {
        let fail = |id: u64, code: ErrorCode, msg: String| RequestParseError {
            id,
            error: WireError::new(code, msg),
        };
        let frame = Json::parse(line.trim()).map_err(|e| {
            fail(0, ErrorCode::BadRequest, format!("malformed JSON: {e:#}"))
        })?;
        if !matches!(frame, Json::Obj(_)) {
            return Err(fail(
                0,
                ErrorCode::BadRequest,
                "request frame must be a JSON object".to_string(),
            ));
        }
        let id = frame.get("id").and_then(Json::as_u64).unwrap_or(0);
        let v = match frame.get("v").and_then(Json::as_u64) {
            Some(v) => v,
            None => {
                return Err(fail(
                    id,
                    ErrorCode::VersionMismatch,
                    "missing protocol version field `v`".to_string(),
                ))
            }
        };
        if v != PROTOCOL_VERSION {
            return Err(fail(
                id,
                ErrorCode::VersionMismatch,
                format!("unsupported protocol version {v} (server speaks v{PROTOCOL_VERSION})"),
            ));
        }
        if frame.get("id").and_then(Json::as_u64).is_none() {
            return Err(fail(
                0,
                ErrorCode::MissingField,
                "missing or non-integer request field `id`".to_string(),
            ));
        }
        let name = need_str(&frame, "op").map_err(|error| RequestParseError { id, error })?;
        let op = Op::decode(name, &frame).map_err(|error| RequestParseError { id, error })?;
        Ok(Request { v, id, op })
    }
}

// ---------------------------------------------------------------------------
// Response
// ---------------------------------------------------------------------------

/// One response frame: `{v, id, ok, payload}` or `{v, id, ok, error}`.
#[derive(Debug, Clone)]
pub struct Response {
    pub v: u64,
    pub id: u64,
    pub result: Result<Json, WireError>,
}

impl Response {
    pub fn ok(id: u64, payload: Json) -> Self {
        Response { v: PROTOCOL_VERSION, id, result: Ok(payload) }
    }

    pub fn err(id: u64, error: WireError) -> Self {
        Response { v: PROTOCOL_VERSION, id, result: Err(error) }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("v", Json::Num(self.v as f64)),
            ("id", Json::Num(self.id as f64)),
            ("ok", Json::Bool(self.result.is_ok())),
        ];
        match &self.result {
            Ok(payload) => pairs.push(("payload", payload.clone())),
            Err(e) => pairs.push(("error", e.to_json())),
        }
        Json::obj(pairs)
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse one response line (client side).
    pub fn parse(line: &str) -> crate::Result<Response> {
        let frame = Json::parse(line.trim()).context("malformed hub response")?;
        let v = frame
            .get("v")
            .and_then(Json::as_u64)
            .context("hub response missing `v`")?;
        let id = frame
            .get("id")
            .and_then(Json::as_u64)
            .context("hub response missing `id`")?;
        let ok = frame
            .get("ok")
            .and_then(Json::as_bool)
            .context("hub response missing `ok`")?;
        let result = if ok {
            Ok(frame.get("payload").cloned().unwrap_or(Json::Null))
        } else {
            let err = frame.get("error").context("error response missing `error`")?;
            Err(WireError::new(
                ErrorCode::from_wire(err.get("code").and_then(Json::as_str).unwrap_or("")),
                err.get("message")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown hub error")
                    .to_string(),
            ))
        };
        Ok(Response { v, id, result })
    }

    /// Client-side envelope check: version, id correlation, ok flag.
    /// Returns the payload on success.
    pub fn payload(self, expect_id: u64) -> crate::Result<Json> {
        anyhow::ensure!(
            self.v == PROTOCOL_VERSION,
            "protocol version mismatch: hub replied v{} (client speaks v{PROTOCOL_VERSION})",
            self.v
        );
        // `id` 0 is the server's connection-scoped error channel — frames
        // it could not correlate to a request (unparseable input, or a
        // refusal sent before any request was read, e.g. flood control).
        // Surface that error instead of calling it a correlation failure.
        if self.id == 0 && expect_id != 0 {
            if let Err(e) = &self.result {
                anyhow::bail!("hub error {e}");
            }
        }
        anyhow::ensure!(
            self.id == expect_id,
            "response id mismatch: sent {expect_id}, got {}",
            self.id
        );
        match self.result {
            Ok(payload) => Ok(payload),
            Err(e) => anyhow::bail!("hub error {e}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Payloads
// ---------------------------------------------------------------------------

/// One repository in a `list_repos` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct RepoSummary {
    pub job: JobKind,
    pub description: String,
    pub records: usize,
    pub maintainer_machine: Option<String>,
    /// Monotonic dataset revision; bumps on every accepted contribution.
    pub revision: u64,
}

impl RepoSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::Str(self.job.to_string())),
            ("description", Json::Str(self.description.clone())),
            ("records", Json::Num(self.records as f64)),
            (
                "maintainer_machine",
                match &self.maintainer_machine {
                    Some(m) => Json::Str(m.clone()),
                    None => Json::Null,
                },
            ),
            ("revision", Json::Num(self.revision as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(RepoSummary {
            job: jstr(j, "job")?.parse()?,
            description: jstr(j, "description")?,
            records: ju64(j, "records")? as usize,
            maintainer_machine: opt_string(j, "maintainer_machine"),
            revision: ju64(j, "revision")?,
        })
    }
}

/// `list_repos` payload.
#[derive(Debug, Clone, PartialEq)]
pub struct RepoList {
    pub repos: Vec<RepoSummary>,
}

impl RepoList {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "repos",
            Json::Arr(self.repos.iter().map(|r| r.to_json()).collect()),
        )])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let repos = j
            .get("repos")
            .and_then(Json::as_arr)
            .context("payload missing array `repos`")?
            .iter()
            .map(RepoSummary::from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(RepoList { repos })
    }
}

/// `get_repo` payload: metadata + the full runtime dataset as TSV.
#[derive(Debug, Clone, PartialEq)]
pub struct RepoPayload {
    pub job: JobKind,
    pub description: String,
    pub maintainer_machine: Option<String>,
    pub revision: u64,
    pub data_tsv: String,
}

impl RepoPayload {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::Str(self.job.to_string())),
            ("description", Json::Str(self.description.clone())),
            (
                "maintainer_machine",
                match &self.maintainer_machine {
                    Some(m) => Json::Str(m.clone()),
                    None => Json::Null,
                },
            ),
            ("revision", Json::Num(self.revision as f64)),
            ("data_tsv", Json::Str(self.data_tsv.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(RepoPayload {
            job: jstr(j, "job")?.parse()?,
            description: jstr(j, "description")?,
            maintainer_machine: opt_string(j, "maintainer_machine"),
            revision: ju64(j, "revision")?,
            data_tsv: jstr(j, "data_tsv")?,
        })
    }
}

/// `submit_runs` payload: the §III-C-b gate's verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitOutcome {
    pub accepted: bool,
    pub reason: String,
    /// Repository revision after the submission (bumped iff accepted).
    pub revision: u64,
}

impl SubmitOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accepted", Json::Bool(self.accepted)),
            ("reason", Json::Str(self.reason.clone())),
            ("revision", Json::Num(self.revision as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(SubmitOutcome {
            accepted: jbool(j, "accepted")?,
            reason: jstr(j, "reason")?,
            revision: ju64(j, "revision")?,
        })
    }
}

/// One machine type in a `catalog` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineTypeInfo {
    pub name: String,
    pub vcpus: u32,
    pub memory_gb: f64,
    pub price_per_hour: f64,
    pub family: String,
}

impl MachineTypeInfo {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("vcpus", Json::Num(self.vcpus as f64)),
            ("memory_gb", Json::Num(self.memory_gb)),
            ("price_per_hour", Json::Num(self.price_per_hour)),
            ("family", Json::Str(self.family.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(MachineTypeInfo {
            name: jstr(j, "name")?,
            vcpus: ju64(j, "vcpus")? as u32,
            memory_gb: jf64(j, "memory_gb")?,
            price_per_hour: jf64(j, "price_per_hour")?,
            family: jstr(j, "family")?,
        })
    }
}

/// `catalog` payload.
#[derive(Debug, Clone, PartialEq)]
pub struct CatalogPayload {
    pub types: Vec<MachineTypeInfo>,
    pub provisioning_delay_s: f64,
}

impl CatalogPayload {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "types",
                Json::Arr(self.types.iter().map(|t| t.to_json()).collect()),
            ),
            ("provisioning_delay_s", Json::Num(self.provisioning_delay_s)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let types = j
            .get("types")
            .and_then(Json::as_arr)
            .context("payload missing array `types`")?
            .iter()
            .map(MachineTypeInfo::from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(CatalogPayload { types, provisioning_delay_s: jf64(j, "provisioning_delay_s")? })
    }
}

/// One repository's replication-relevant state in a `stats` reply:
/// comparing a follower's entry against the leader's gives the lag in
/// revisions (and records).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RepoStats {
    pub job: JobKind,
    pub revision: u64,
    pub records: u64,
}

impl RepoStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::Str(self.job.to_string())),
            ("revision", Json::Num(self.revision as f64)),
            ("records", Json::Num(self.records as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(RepoStats {
            job: jstr(j, "job")?.parse()?,
            revision: ju64(j, "revision")?,
            records: ju64(j, "records")?,
        })
    }
}

/// One repository's replication lag as seen by a follower: the
/// leader's revision watermark from the last sync versus the revision
/// the follower has applied locally. Revisions advance by one per
/// accepted contribution, so the difference is the lag in records.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplLagStats {
    pub job: JobKind,
    pub leader_revision: u64,
    pub applied_revision: u64,
}

impl ReplLagStats {
    /// Lag in records (0 when caught up; saturates if the leader answer
    /// raced an apply).
    pub fn lag(&self) -> u64 {
        self.leader_revision.saturating_sub(self.applied_revision)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::Str(self.job.to_string())),
            ("leader_revision", Json::Num(self.leader_revision as f64)),
            ("applied_revision", Json::Num(self.applied_revision as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(ReplLagStats {
            job: jstr(j, "job")?.parse()?,
            leader_revision: ju64(j, "leader_revision")?,
            applied_revision: ju64(j, "applied_revision")?,
        })
    }
}

/// `stats` payload: hub counters + prediction-service cache counters +
/// durability counters (zero when the hub runs without a data dir) +
/// per-repo revision watermarks for replication-lag observability.
#[derive(Debug, Clone, PartialEq)]
pub struct HubStats {
    pub accepted: u64,
    pub rejected: u64,
    pub repos: u64,
    /// Cold fits performed by the prediction service since start.
    pub fits: u64,
    /// Requests answered from the fitted-model cache.
    pub cache_hits: u64,
    /// Live entries in the fitted-model cache.
    pub cache_entries: u64,
    /// Whether a durable store (WAL + snapshots) is attached.
    pub durable: bool,
    /// Accepted contributions appended to the WAL since start.
    pub wal_appends: u64,
    /// Compacted snapshots written since start.
    pub snapshots: u64,
    /// WAL backlog: appends not yet covered by a snapshot.
    pub appends_since_snapshot: u64,
    /// Transport: currently open connections (0 when the service is
    /// driven in-process without the event-loop transport).
    pub open_connections: u64,
    /// Transport: deepest per-connection request pipeline observed.
    pub peak_pipeline_depth: u64,
    /// Predicts answered through a coalesced `predict_batch` instead of
    /// individually (0 when the coalescing window is disabled).
    pub coalesced_predicts: u64,
    /// Per-repository `{revision, records}` watermarks.
    pub per_repo: Vec<RepoStats>,
    /// Follower-only: per-repo replication lag from the last tail sync.
    /// Empty on leaders and on hubs that predate this field.
    pub repl_lag: Vec<ReplLagStats>,
    /// Follower-only: milliseconds since the last successful tail sync
    /// (`None` on leaders, or before the first sync completes — a
    /// wedged tailer shows up as this value growing without bound).
    pub repl_tail_age_ms: Option<u64>,
}

impl HubStats {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("accepted", Json::Num(self.accepted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("repos", Json::Num(self.repos as f64)),
            ("fits", Json::Num(self.fits as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_entries", Json::Num(self.cache_entries as f64)),
            ("durable", Json::Bool(self.durable)),
            ("wal_appends", Json::Num(self.wal_appends as f64)),
            ("snapshots", Json::Num(self.snapshots as f64)),
            (
                "appends_since_snapshot",
                Json::Num(self.appends_since_snapshot as f64),
            ),
            ("open_connections", Json::Num(self.open_connections as f64)),
            (
                "peak_pipeline_depth",
                Json::Num(self.peak_pipeline_depth as f64),
            ),
            (
                "coalesced_predicts",
                Json::Num(self.coalesced_predicts as f64),
            ),
            (
                "per_repo",
                Json::Arr(self.per_repo.iter().map(|r| r.to_json()).collect()),
            ),
        ];
        // Follower-only fields stay off leader payloads entirely so a
        // leader's stats line is byte-identical to pre-telemetry hubs.
        if !self.repl_lag.is_empty() {
            pairs.push((
                "repl_lag",
                Json::Arr(self.repl_lag.iter().map(|r| r.to_json()).collect()),
            ));
        }
        if let Some(age) = self.repl_tail_age_ms {
            pairs.push(("repl_tail_age_ms", Json::Num(age as f64)));
        }
        Json::obj(pairs)
    }

    /// Decode, routing any field-level decode warnings through the
    /// structured logger. See [`HubStats::from_json_with_warnings`].
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let (stats, warnings) = Self::from_json_with_warnings(j)?;
        for w in &warnings {
            crate::obs::log::warn(
                "api.proto",
                "stats payload decode warning",
                &[("detail", w.clone())],
            );
        }
        Ok(stats)
    }

    /// Decode a `stats` payload. Fields that are additive within v1 may
    /// be *absent* (older hub) and silently default — but a field that
    /// is *present with the wrong type* (e.g. a string-encoded counter)
    /// is data being lost, so it produces a warning instead of being
    /// silently zeroed.
    pub fn from_json_with_warnings(j: &Json) -> crate::Result<(Self, Vec<String>)> {
        let mut warnings = Vec::new();
        // The per-repo array is additive within v1, like the durability
        // counters: absent on older hubs ⇒ empty, not an error.
        let per_repo = match j.get("per_repo").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(RepoStats::from_json)
                .collect::<crate::Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        let repl_lag = match j.get("repl_lag").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(ReplLagStats::from_json)
                .collect::<crate::Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        let stats = HubStats {
            accepted: ju64(j, "accepted")?,
            rejected: ju64(j, "rejected")?,
            repos: ju64(j, "repos")?,
            fits: ju64(j, "fits")?,
            cache_hits: ju64(j, "cache_hits")?,
            cache_entries: ju64(j, "cache_entries")?,
            // Additive within protocol v1: absent on pre-durability hubs,
            // so default instead of erroring (old hub ⇒ not durable).
            durable: lenient_bool(j, "durable", &mut warnings),
            wal_appends: lenient_u64(j, "wal_appends", &mut warnings),
            snapshots: lenient_u64(j, "snapshots", &mut warnings),
            appends_since_snapshot: lenient_u64(j, "appends_since_snapshot", &mut warnings),
            // Transport counters are additive too: absent from hubs that
            // predate the event-loop transport.
            open_connections: lenient_u64(j, "open_connections", &mut warnings),
            peak_pipeline_depth: lenient_u64(j, "peak_pipeline_depth", &mut warnings),
            coalesced_predicts: lenient_u64(j, "coalesced_predicts", &mut warnings),
            per_repo,
            repl_lag,
            repl_tail_age_ms: match j.get("repl_tail_age_ms") {
                None => None,
                Some(v) => {
                    let parsed = v.as_u64();
                    if parsed.is_none() {
                        warnings.push(mistyped("repl_tail_age_ms", v));
                    }
                    parsed
                }
            },
        };
        Ok((stats, warnings))
    }
}

/// v1-additive u64 field: absent ⇒ 0 silently, present-but-mistyped ⇒
/// 0 plus a decode warning (the value was on the wire and got lost).
fn lenient_u64(j: &Json, key: &str, warnings: &mut Vec<String>) -> u64 {
    match j.get(key) {
        None => 0,
        Some(v) => match v.as_u64() {
            Some(n) => n,
            None => {
                warnings.push(mistyped(key, v));
                0
            }
        },
    }
}

/// v1-additive bool field, with the same absent/mistyped split.
fn lenient_bool(j: &Json, key: &str, warnings: &mut Vec<String>) -> bool {
    match j.get(key) {
        None => false,
        Some(v) => match v.as_bool() {
            Some(b) => b,
            None => {
                warnings.push(mistyped(key, v));
                false
            }
        },
    }
}

fn mistyped(key: &str, got: &Json) -> String {
    format!("field `{key}` present but mistyped (got {got}); value dropped")
}

/// One histogram's summary in a `metrics` payload: total count/sum,
/// the exact observed max, and bucket-resolution percentiles
/// (microseconds, ≤ 6.25% relative error — DESIGN.md §13).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSummary {
    pub name: String,
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
}

impl HistogramSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("count", Json::Num(self.count as f64)),
            ("sum_us", Json::Num(self.sum_us as f64)),
            ("max_us", Json::Num(self.max_us as f64)),
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p95_us", Json::Num(self.p95_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(HistogramSummary {
            name: jstr(j, "name")?,
            count: ju64(j, "count")?,
            sum_us: ju64(j, "sum_us")?,
            max_us: ju64(j, "max_us")?,
            p50_us: ju64(j, "p50_us")?,
            p95_us: ju64(j, "p95_us")?,
            p99_us: ju64(j, "p99_us")?,
        })
    }
}

/// `metrics` payload (DESIGN.md §13): the full telemetry snapshot.
/// Deliberately generic — histograms, counters and gauges are named
/// lists, so new instruments are additive without protocol changes.
/// Gauge/counter names may carry Prometheus-style labels
/// (`repl_lag_records{repo="sort"}`).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct MetricsPayload {
    pub histograms: Vec<HistogramSummary>,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
}

impl MetricsPayload {
    /// Find one histogram by name.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSummary> {
        self.histograms.iter().find(|h| h.name == name)
    }

    /// Find one counter value by exact name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// Find one gauge value by exact name.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn to_json(&self) -> Json {
        let named = |xs: &[(String, u64)]| {
            Json::Arr(
                xs.iter()
                    .map(|(name, value)| {
                        Json::obj(vec![
                            ("name", Json::Str(name.clone())),
                            ("value", Json::Num(*value as f64)),
                        ])
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            (
                "histograms",
                Json::Arr(self.histograms.iter().map(|h| h.to_json()).collect()),
            ),
            ("counters", named(&self.counters)),
            ("gauges", named(&self.gauges)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let named = |key: &'static str| -> crate::Result<Vec<(String, u64)>> {
            j.get(key)
                .and_then(Json::as_arr)
                .with_context(|| format!("payload missing array `{key}`"))?
                .iter()
                .map(|x| Ok((jstr(x, "name")?, ju64(x, "value")?)))
                .collect()
        };
        let histograms = j
            .get("histograms")
            .and_then(Json::as_arr)
            .context("payload missing array `histograms`")?
            .iter()
            .map(HistogramSummary::from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(MetricsPayload {
            histograms,
            counters: named("counters")?,
            gauges: named("gauges")?,
        })
    }

    /// Render as Prometheus-style text exposition: each histogram is a
    /// `summary` named `c3o_<name>_us` (quantile labels plus
    /// `_sum`/`_count`/`_max`); counters and gauges keep their names
    /// (labels included) under a `c3o_` prefix.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for h in &self.histograms {
            let n = format!("c3o_{}_us", h.name);
            out.push_str(&format!("# TYPE {n} summary\n"));
            for (q, v) in [("0.5", h.p50_us), ("0.95", h.p95_us), ("0.99", h.p99_us)] {
                out.push_str(&format!("{n}{{quantile=\"{q}\"}} {v}\n"));
            }
            out.push_str(&format!("{n}_sum {}\n", h.sum_us));
            out.push_str(&format!("{n}_count {}\n", h.count));
            out.push_str(&format!("{n}_max {}\n", h.max_us));
        }
        let mut render_named = |xs: &[(String, u64)], kind: &str| {
            let mut last_base = String::new();
            for (name, value) in xs {
                let base = name.split('{').next().unwrap_or(name);
                if base != last_base {
                    out.push_str(&format!("# TYPE c3o_{base} {kind}\n"));
                    last_base = base.to_string();
                }
                out.push_str(&format!("c3o_{name} {value}\n"));
            }
        };
        render_named(&self.counters, "counter");
        render_named(&self.gauges, "gauge");
        out
    }
}

/// `repl_subscribe` payload: the leader's answer to a follower's
/// watermark announcement (DESIGN.md §11).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplHandshake {
    pub job: JobKind,
    /// The leader's current revision for `job`.
    pub leader_revision: u64,
    /// The follower's watermark predates the leader's compaction horizon:
    /// tailing cannot be gap-free, bootstrap from `repl_snapshot` first.
    pub compacted: bool,
}

impl ReplHandshake {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::Str(self.job.to_string())),
            ("leader_revision", Json::Num(self.leader_revision as f64)),
            ("compacted", Json::Bool(self.compacted)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(ReplHandshake {
            job: jstr(j, "job")?.parse()?,
            leader_revision: ju64(j, "leader_revision")?,
            compacted: jbool(j, "compacted")?,
        })
    }
}

/// One shipped WAL record in a `repl_fetch` page.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplRecordPayload {
    /// The repository revision this contribution committed as.
    pub revision: u64,
    /// The contribution, TSV-encoded exactly as the leader's WAL holds it.
    pub data_tsv: String,
}

impl ReplRecordPayload {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("revision", Json::Num(self.revision as f64)),
            ("data_tsv", Json::Str(self.data_tsv.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(ReplRecordPayload { revision: ju64(j, "revision")?, data_tsv: jstr(j, "data_tsv")? })
    }
}

/// `repl_fetch` payload: one page of WAL records above the follower's
/// watermark, in append order, plus the leader-side context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplPage {
    pub job: JobKind,
    pub leader_revision: u64,
    /// See [`ReplHandshake::compacted`]: when set, `records` is not
    /// contiguous with the requested watermark and must not be applied.
    pub compacted: bool,
    pub records: Vec<ReplRecordPayload>,
}

impl ReplPage {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::Str(self.job.to_string())),
            ("leader_revision", Json::Num(self.leader_revision as f64)),
            ("compacted", Json::Bool(self.compacted)),
            (
                "records",
                Json::Arr(self.records.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let records = j
            .get("records")
            .and_then(Json::as_arr)
            .context("payload missing array `records`")?
            .iter()
            .map(ReplRecordPayload::from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(ReplPage {
            job: jstr(j, "job")?.parse()?,
            leader_revision: ju64(j, "leader_revision")?,
            compacted: jbool(j, "compacted")?,
            records,
        })
    }
}

/// One repository's full corpus image in a `repl_snapshot` reply — the
/// same TSV serialization the disk snapshots use, so a bootstrap lands
/// bit-identical state.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplRepoImage {
    pub job: JobKind,
    pub revision: u64,
    pub description: String,
    pub maintainer_machine: Option<String>,
    pub data_tsv: String,
}

impl ReplRepoImage {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::Str(self.job.to_string())),
            ("revision", Json::Num(self.revision as f64)),
            ("description", Json::Str(self.description.clone())),
            (
                "maintainer_machine",
                match &self.maintainer_machine {
                    Some(m) => Json::Str(m.clone()),
                    None => Json::Null,
                },
            ),
            ("data_tsv", Json::Str(self.data_tsv.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(ReplRepoImage {
            job: jstr(j, "job")?.parse()?,
            revision: ju64(j, "revision")?,
            description: jstr(j, "description")?,
            maintainer_machine: opt_string(j, "maintainer_machine"),
            data_tsv: jstr(j, "data_tsv")?,
        })
    }
}

/// `repl_snapshot` payload: every repository's current image.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplSnapshotPayload {
    pub repos: Vec<ReplRepoImage>,
}

impl ReplSnapshotPayload {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "repos",
            Json::Arr(self.repos.iter().map(|r| r.to_json()).collect()),
        )])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let repos = j
            .get("repos")
            .and_then(Json::as_arr)
            .context("payload missing array `repos`")?
            .iter()
            .map(ReplRepoImage::from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(ReplSnapshotPayload { repos })
    }
}

/// `predict` payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    pub machine_type: String,
    /// Name of the model dynamic selection chose (GBM | BOM | OGB | ...).
    pub model: String,
    /// Whether the fitted model came from the cache.
    pub cached: bool,
    pub runtime_s: f64,
}

impl Prediction {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("machine_type", Json::Str(self.machine_type.clone())),
            ("model", Json::Str(self.model.clone())),
            ("cached", Json::Bool(self.cached)),
            ("runtime_s", Json::Num(self.runtime_s)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(Prediction {
            machine_type: jstr(j, "machine_type")?,
            model: jstr(j, "model")?,
            cached: jbool(j, "cached")?,
            runtime_s: jf64(j, "runtime_s")?,
        })
    }
}

/// `predict_batch` payload: one fitted model, many rows.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchPrediction {
    pub machine_type: String,
    pub model: String,
    pub cached: bool,
    pub runtimes: Vec<f64>,
}

impl BatchPrediction {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("machine_type", Json::Str(self.machine_type.clone())),
            ("model", Json::Str(self.model.clone())),
            ("cached", Json::Bool(self.cached)),
            ("runtimes", f64s_to_json(&self.runtimes)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(BatchPrediction {
            machine_type: jstr(j, "machine_type")?,
            model: jstr(j, "model")?,
            cached: jbool(j, "cached")?,
            runtimes: jf64_arr(j, "runtimes")?,
        })
    }
}

fn scale_out_option_to_json(o: &ScaleOutOption) -> Json {
    Json::obj(vec![
        ("scale_out", Json::Num(o.scale_out as f64)),
        ("predicted_runtime_s", Json::Num(o.predicted_runtime_s)),
        ("runtime_ucb_s", Json::Num(o.runtime_ucb_s)),
        ("cost_usd", Json::Num(o.cost_usd)),
        ("bottleneck", Json::Bool(o.bottleneck)),
        (
            "admissible",
            match o.admissible {
                Some(b) => Json::Bool(b),
                None => Json::Null,
            },
        ),
    ])
}

fn scale_out_option_from_json(o: &Json) -> crate::Result<ScaleOutOption> {
    Ok(ScaleOutOption {
        scale_out: ju64(o, "scale_out")? as u32,
        predicted_runtime_s: jf64(o, "predicted_runtime_s")?,
        runtime_ucb_s: jf64(o, "runtime_ucb_s")?,
        cost_usd: jf64(o, "cost_usd")?,
        bottleneck: jbool(o, "bottleneck")?,
        admissible: o.get("admissible").and_then(Json::as_bool),
    })
}

/// Encode a configurator decision as a `configure` payload.
pub fn config_choice_to_json(c: &ConfigChoice) -> Json {
    Json::obj(vec![
        ("machine_type", Json::Str(c.machine_type.clone())),
        ("scale_out", Json::Num(c.scale_out as f64)),
        ("predicted_runtime_s", Json::Num(c.predicted_runtime_s)),
        ("runtime_ucb_s", Json::Num(c.runtime_ucb_s)),
        ("est_cost_usd", Json::Num(c.est_cost_usd)),
        (
            "options",
            Json::Arr(c.options.iter().map(scale_out_option_to_json).collect()),
        ),
    ])
}

/// Decode a `configure` payload back into the configurator's native type,
/// so hub mode hands callers the same [`ConfigChoice`] local mode does.
pub fn config_choice_from_json(j: &Json) -> crate::Result<ConfigChoice> {
    let options = j
        .get("options")
        .and_then(Json::as_arr)
        .context("payload missing array `options`")?
        .iter()
        .map(scale_out_option_from_json)
        .collect::<crate::Result<Vec<_>>>()?;
    Ok(ConfigChoice {
        machine_type: jstr(j, "machine_type")?,
        scale_out: ju64(j, "scale_out")? as u32,
        predicted_runtime_s: jf64(j, "predicted_runtime_s")?,
        runtime_ucb_s: jf64(j, "runtime_ucb_s")?,
        est_cost_usd: jf64(j, "est_cost_usd")?,
        options,
    })
}

fn type_report_to_json(t: &TypeReport) -> Json {
    let mut pairs = vec![
        ("machine_type", Json::Str(t.machine_type.clone())),
        ("runs", Json::Num(t.runs as f64)),
    ];
    match &t.outcome {
        TypeOutcome::Evaluated { model, options, pick } => {
            pairs.push(("status", Json::Str("evaluated".to_string())));
            pairs.push(("model", Json::Str(model.clone())));
            pairs.push((
                "pick",
                match pick {
                    Some(s) => Json::Num(*s as f64),
                    None => Json::Null,
                },
            ));
            pairs.push((
                "options",
                Json::Arr(options.iter().map(scale_out_option_to_json).collect()),
            ));
        }
        TypeOutcome::InsufficientData { required } => {
            pairs.push(("status", Json::Str("insufficient_data".to_string())));
            pairs.push(("required", Json::Num(*required as f64)));
        }
        TypeOutcome::Failed { error } => {
            pairs.push(("status", Json::Str("failed".to_string())));
            pairs.push(("error", Json::Str(error.clone())));
        }
    }
    Json::obj(pairs)
}

fn type_report_from_json(j: &Json) -> crate::Result<TypeReport> {
    let status = jstr(j, "status")?;
    let outcome = match status.as_str() {
        "evaluated" => TypeOutcome::Evaluated {
            model: jstr(j, "model")?,
            options: j
                .get("options")
                .and_then(Json::as_arr)
                .context("evaluated type missing array `options`")?
                .iter()
                .map(scale_out_option_from_json)
                .collect::<crate::Result<Vec<_>>>()?,
            pick: j.get("pick").and_then(Json::as_u64).map(|s| s as u32),
        },
        "insufficient_data" => {
            TypeOutcome::InsufficientData { required: ju64(j, "required")? as usize }
        }
        "failed" => TypeOutcome::Failed { error: jstr(j, "error")? },
        other => anyhow::bail!("unknown per-type status: {other}"),
    };
    Ok(TypeReport {
        machine_type: jstr(j, "machine_type")?,
        runs: ju64(j, "runs")? as usize,
        outcome,
    })
}

/// Encode a catalog-wide search result as a `configure_search` payload.
pub fn catalog_search_to_json(s: &CatalogSearch) -> Json {
    Json::obj(vec![
        ("choice", config_choice_to_json(&s.choice)),
        (
            "frontier",
            Json::Arr(
                s.frontier
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("machine_type", Json::Str(f.machine_type.clone())),
                            ("scale_out", Json::Num(f.scale_out as f64)),
                            ("predicted_runtime_s", Json::Num(f.predicted_runtime_s)),
                            ("runtime_ucb_s", Json::Num(f.runtime_ucb_s)),
                            ("cost_usd", Json::Num(f.cost_usd)),
                            ("bottleneck", Json::Bool(f.bottleneck)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("types", Json::Arr(s.types.iter().map(type_report_to_json).collect())),
    ])
}

/// Decode a `configure_search` payload back into the configurator's
/// native [`CatalogSearch`], so hub mode hands callers exactly what local
/// mode computes.
pub fn catalog_search_from_json(j: &Json) -> crate::Result<CatalogSearch> {
    let choice = config_choice_from_json(j.get("choice").context("payload missing `choice`")?)?;
    let frontier = j
        .get("frontier")
        .and_then(Json::as_arr)
        .context("payload missing array `frontier`")?
        .iter()
        .map(|f| {
            Ok(FrontierEntry {
                machine_type: jstr(f, "machine_type")?,
                scale_out: ju64(f, "scale_out")? as u32,
                predicted_runtime_s: jf64(f, "predicted_runtime_s")?,
                runtime_ucb_s: jf64(f, "runtime_ucb_s")?,
                cost_usd: jf64(f, "cost_usd")?,
                bottleneck: jbool(f, "bottleneck")?,
            })
        })
        .collect::<crate::Result<Vec<_>>>()?;
    let types = j
        .get("types")
        .and_then(Json::as_arr)
        .context("payload missing array `types`")?
        .iter()
        .map(type_report_from_json)
        .collect::<crate::Result<Vec<_>>>()?;
    Ok(CatalogSearch { choice, frontier, types })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(op: Op) {
        let req = Request::new(42, op);
        let back = Request::parse(&req.to_line()).expect("parse");
        assert_eq!(req, back);
    }

    #[test]
    fn request_round_trips_every_op() {
        round_trip(Op::ListRepos);
        round_trip(Op::GetRepo { job: JobKind::Sort });
        round_trip(Op::SubmitRuns {
            job: JobKind::Grep,
            data_tsv: "a\tb\n1\t2\n".to_string(),
        });
        round_trip(Op::Catalog);
        round_trip(Op::Stats);
        round_trip(Op::Metrics);
        round_trip(Op::Predict {
            job: JobKind::KMeans,
            machine_type: Some("m5.xlarge".into()),
            features: vec![4.0, 15.0, 8.0, 0.001],
        });
        round_trip(Op::PredictBatch {
            job: JobKind::Sort,
            machine_type: None,
            rows: vec![vec![2.0, 10.0], vec![4.0, 10.0]],
        });
        round_trip(Op::Configure {
            job: JobKind::PageRank,
            data_size_gb: 0.25,
            context: vec![0.1, 0.001],
            deadline_s: Some(900.0),
            confidence: 0.95,
            machine_type: None,
        });
        round_trip(Op::ConfigureSearch {
            job: JobKind::KMeans,
            data_size_gb: 15.0,
            context: vec![5.0, 0.001],
            deadline_s: None,
            confidence: 0.9,
        });
        round_trip(Op::ReplSubscribe { job: JobKind::Sort, from_revision: 7 });
        round_trip(Op::ReplFetch { job: JobKind::Grep, from_revision: 0, max: 64 });
        round_trip(Op::ReplSnapshot);
        round_trip(Op::Shutdown);
    }

    #[test]
    fn repl_fetch_requires_integer_fields() {
        let e = Request::parse(r#"{"v":1,"id":3,"op":"repl_fetch","job":"sort"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::MissingField);
        assert!(e.error.message.contains("from_revision"), "{}", e.error.message);
    }

    #[test]
    fn not_leader_code_round_trips_on_the_wire() {
        let r = Response::err(
            4,
            WireError::new(ErrorCode::NotLeader, "submit to the leader at 10.0.0.1:7033"),
        );
        let line = r.to_line();
        assert!(line.contains(r#""code":"not_leader""#), "{line}");
        let back = Response::parse(&line).unwrap();
        match &back.result {
            Err(e) => {
                assert_eq!(e.code, ErrorCode::NotLeader);
                assert!(e.message.contains("10.0.0.1:7033"), "{}", e.message);
            }
            Ok(_) => panic!("expected error result"),
        }
        assert_eq!(ErrorCode::from_wire("not_leader"), ErrorCode::NotLeader);
    }

    #[test]
    fn repl_payloads_round_trip() {
        let h = ReplHandshake { job: JobKind::Sort, leader_revision: 12, compacted: true };
        assert_eq!(ReplHandshake::from_json(&h.to_json()).unwrap(), h);

        let p = ReplPage {
            job: JobKind::Grep,
            leader_revision: 3,
            compacted: false,
            records: vec![
                ReplRecordPayload { revision: 2, data_tsv: "h\t1\nr\t2\n".into() },
                ReplRecordPayload { revision: 3, data_tsv: "h\t1\nr\t3\n".into() },
            ],
        };
        assert_eq!(ReplPage::from_json(&p.to_json()).unwrap(), p);

        let s = ReplSnapshotPayload {
            repos: vec![ReplRepoImage {
                job: JobKind::KMeans,
                revision: 5,
                description: "spark kmeans".into(),
                maintainer_machine: Some("m5.xlarge".into()),
                data_tsv: "h\t1\nr\t2\n".into(),
            }],
        };
        let back = ReplSnapshotPayload::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn malformed_json_is_bad_request_with_id_zero() {
        let e = Request::parse("this is not json").unwrap_err();
        assert_eq!(e.id, 0);
        assert_eq!(e.error.code, ErrorCode::BadRequest);
        let e = Request::parse("[1,2,3]").unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest);
    }

    #[test]
    fn missing_version_is_version_mismatch() {
        let e = Request::parse(r#"{"id":7,"op":"stats"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::VersionMismatch);
        assert_eq!(e.id, 7, "id still recovered for correlation");
    }

    #[test]
    fn wrong_version_is_version_mismatch() {
        let e = Request::parse(r#"{"v":2,"id":7,"op":"stats"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::VersionMismatch);
        assert!(e.error.message.contains("version 2"), "{}", e.error.message);
    }

    #[test]
    fn missing_id_is_missing_field() {
        let e = Request::parse(r#"{"v":1,"op":"stats"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::MissingField);
        assert_eq!(e.id, 0);
    }

    #[test]
    fn unknown_op_keeps_request_id() {
        let e = Request::parse(r#"{"v":1,"id":9,"op":"frobnicate"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::UnknownOp);
        assert_eq!(e.id, 9);
    }

    #[test]
    fn missing_op_field_is_missing_field() {
        let e = Request::parse(r#"{"v":1,"id":3}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::MissingField);
        let e = Request::parse(r#"{"v":1,"id":3,"op":"get_repo"}"#).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::MissingField);
        assert!(e.error.message.contains("job"), "{}", e.error.message);
    }

    #[test]
    fn bad_job_value_is_invalid_data() {
        let e = Request::parse(r#"{"v":1,"id":3,"op":"get_repo","job":"mapreduce"}"#)
            .unwrap_err();
        assert_eq!(e.error.code, ErrorCode::InvalidData);
    }

    #[test]
    fn response_ok_round_trip_and_payload_check() {
        let r = Response::ok(5, Json::obj(vec![("x", Json::Num(1.0))]));
        let back = Response::parse(&r.to_line()).unwrap();
        assert_eq!(back.id, 5);
        let payload = back.payload(5).unwrap();
        assert_eq!(payload.get("x").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn response_error_round_trip() {
        let r = Response::err(6, WireError::new(ErrorCode::NotFound, "no repository for sort"));
        let line = r.to_line();
        assert!(line.contains(r#""ok":false"#), "{line}");
        assert!(line.contains(r#""code":"not_found""#), "{line}");
        let back = Response::parse(&line).unwrap();
        let err = back.payload(6).unwrap_err();
        assert!(err.to_string().contains("not_found"), "{err:#}");
        assert!(err.to_string().contains("no repository"), "{err:#}");
    }

    #[test]
    fn mismatched_response_id_rejected() {
        let r = Response::ok(999, Json::Null);
        let err = r.payload(5).unwrap_err();
        assert!(err.to_string().contains("id mismatch"), "{err:#}");
    }

    #[test]
    fn mismatched_response_version_rejected() {
        let mut r = Response::ok(5, Json::Null);
        r.v = 2;
        let err = r.payload(5).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err:#}");
    }

    #[test]
    fn config_choice_round_trips() {
        let c = ConfigChoice {
            machine_type: "m5.xlarge".into(),
            scale_out: 6,
            predicted_runtime_s: 123.25,
            runtime_ucb_s: 150.5,
            est_cost_usd: 0.32,
            options: vec![ScaleOutOption {
                scale_out: 6,
                predicted_runtime_s: 123.25,
                runtime_ucb_s: 150.5,
                cost_usd: 0.32,
                bottleneck: false,
                admissible: Some(true),
            }],
        };
        let back = config_choice_from_json(&config_choice_to_json(&c)).unwrap();
        assert_eq!(back.machine_type, c.machine_type);
        assert_eq!(back.scale_out, c.scale_out);
        assert_eq!(back.predicted_runtime_s, c.predicted_runtime_s);
        assert_eq!(back.options.len(), 1);
        assert_eq!(back.options[0].admissible, Some(true));
    }

    #[test]
    fn catalog_search_payload_round_trips() {
        let option = ScaleOutOption {
            scale_out: 4,
            predicted_runtime_s: 200.0,
            runtime_ucb_s: 240.0,
            cost_usd: 0.12,
            bottleneck: false,
            admissible: Some(true),
        };
        let s = CatalogSearch {
            choice: ConfigChoice {
                machine_type: "c5.xlarge".into(),
                scale_out: 4,
                predicted_runtime_s: 200.0,
                runtime_ucb_s: 240.0,
                est_cost_usd: 0.12,
                options: vec![option.clone()],
            },
            frontier: vec![FrontierEntry {
                machine_type: "c5.xlarge".into(),
                scale_out: 4,
                predicted_runtime_s: 200.0,
                runtime_ucb_s: 240.0,
                cost_usd: 0.12,
                bottleneck: false,
            }],
            types: vec![
                TypeReport {
                    machine_type: "c5.xlarge".into(),
                    runs: 63,
                    outcome: TypeOutcome::Evaluated {
                        model: "GBM".into(),
                        options: vec![option],
                        pick: Some(4),
                    },
                },
                TypeReport {
                    machine_type: "r5.xlarge".into(),
                    runs: 1,
                    outcome: TypeOutcome::InsufficientData { required: 4 },
                },
                TypeReport {
                    machine_type: "i3.xlarge".into(),
                    runs: 9,
                    outcome: TypeOutcome::Failed { error: "fit exploded".into() },
                },
            ],
        };
        let back = catalog_search_from_json(&catalog_search_to_json(&s)).unwrap();
        assert_eq!(back.choice.machine_type, "c5.xlarge");
        assert_eq!(back.choice.scale_out, 4);
        assert_eq!(back.frontier.len(), 1);
        assert_eq!(back.frontier[0].cost_usd, 0.12);
        assert_eq!(back.types.len(), 3);
        match &back.types[0].outcome {
            TypeOutcome::Evaluated { model, options, pick } => {
                assert_eq!(model, "GBM");
                assert_eq!(options.len(), 1);
                assert_eq!(*pick, Some(4));
            }
            other => panic!("expected Evaluated, got {other:?}"),
        }
        match &back.types[1].outcome {
            TypeOutcome::InsufficientData { required } => assert_eq!(*required, 4),
            other => panic!("expected InsufficientData, got {other:?}"),
        }
        match &back.types[2].outcome {
            TypeOutcome::Failed { error } => assert_eq!(error, "fit exploded"),
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(back.types[1].runs, 1);
    }

    #[test]
    fn stats_payload_round_trips() {
        let s = HubStats {
            accepted: 3,
            rejected: 1,
            repos: 5,
            fits: 2,
            cache_hits: 7,
            cache_entries: 2,
            durable: true,
            wal_appends: 3,
            snapshots: 1,
            appends_since_snapshot: 2,
            open_connections: 9,
            peak_pipeline_depth: 32,
            coalesced_predicts: 17,
            per_repo: vec![
                RepoStats { job: JobKind::Sort, revision: 2, records: 132 },
                RepoStats { job: JobKind::Grep, revision: 1, records: 129 },
            ],
            repl_lag: vec![ReplLagStats {
                job: JobKind::Sort,
                leader_revision: 9,
                applied_revision: 2,
            }],
            repl_tail_age_ms: Some(120),
        };
        assert_eq!(s.repl_lag[0].lag(), 7);
        let (back, warnings) = HubStats::from_json_with_warnings(&s.to_json()).unwrap();
        assert_eq!(back, s);
        assert!(warnings.is_empty(), "clean payload must not warn: {warnings:?}");
    }

    #[test]
    fn stats_decode_warns_on_mistyped_additive_fields() {
        // A string-encoded counter is data on the wire being lost: the
        // decode still succeeds (additive-field tolerance) but surfaces
        // a warning instead of silently zeroing the value.
        let j = Json::parse(
            r#"{"accepted":1,"rejected":0,"repos":2,"fits":1,"cache_hits":3,
                "cache_entries":1,"wal_appends":"17","durable":"yes"}"#,
        )
        .unwrap();
        let (s, warnings) = HubStats::from_json_with_warnings(&j).unwrap();
        assert_eq!(s.wal_appends, 0);
        assert!(!s.durable);
        assert_eq!(warnings.len(), 2, "{warnings:?}");
        assert!(warnings.iter().any(|w| w.contains("`wal_appends`")), "{warnings:?}");
        assert!(warnings.iter().any(|w| w.contains("`durable`")), "{warnings:?}");
        // The logging front door still decodes.
        assert_eq!(HubStats::from_json(&j).unwrap(), s);
    }

    #[test]
    fn stats_payload_tolerates_pre_durability_hubs() {
        // The durability counters are additive within v1: a payload from
        // an older hub (no such fields) must still parse.
        let j = Json::parse(
            r#"{"accepted":1,"rejected":0,"repos":2,"fits":1,"cache_hits":3,"cache_entries":1}"#,
        )
        .unwrap();
        let s = HubStats::from_json(&j).unwrap();
        assert!(!s.durable);
        assert_eq!((s.wal_appends, s.snapshots), (0, 0));
        assert_eq!(s.appends_since_snapshot, 0);
        assert!(s.per_repo.is_empty(), "pre-replication hubs ship no per-repo stats");
        let transport =
            (s.open_connections, s.peak_pipeline_depth, s.coalesced_predicts);
        assert_eq!(transport, (0, 0, 0), "transport counters are additive in v1");
        assert!(s.repl_lag.is_empty());
        assert_eq!(s.repl_tail_age_ms, None);
    }

    #[test]
    fn metrics_payload_round_trips_and_renders() {
        let m = MetricsPayload {
            histograms: vec![HistogramSummary {
                name: "stage_queue_wait".into(),
                count: 10,
                sum_us: 1000,
                max_us: 400,
                p50_us: 90,
                p95_us: 380,
                p99_us: 400,
            }],
            counters: vec![("cache_hits".into(), 7), ("refused_connections".into(), 1)],
            gauges: vec![
                ("open_connections".into(), 3),
                ("repl_lag_records{repo=\"grep\"}".into(), 4),
                ("repl_lag_records{repo=\"sort\"}".into(), 0),
            ],
        };
        assert_eq!(MetricsPayload::from_json(&m.to_json()).unwrap(), m);
        assert_eq!(m.histogram("stage_queue_wait").map(|h| h.count), Some(10));
        assert_eq!(m.counter("cache_hits"), Some(7));
        assert_eq!(m.gauge("open_connections"), Some(3));

        let text = m.render_prometheus();
        assert!(text.contains("# TYPE c3o_stage_queue_wait_us summary\n"), "{text}");
        assert!(text.contains("c3o_stage_queue_wait_us{quantile=\"0.99\"} 400\n"), "{text}");
        assert!(text.contains("c3o_stage_queue_wait_us_count 10\n"), "{text}");
        assert!(text.contains("# TYPE c3o_cache_hits counter\n"), "{text}");
        assert!(text.contains("c3o_repl_lag_records{repo=\"sort\"} 0\n"), "{text}");
        // One TYPE line covers both labeled repl_lag_records gauges.
        let type_lines = text.matches("# TYPE c3o_repl_lag_records gauge").count();
        assert_eq!(type_lines, 1, "{text}");
    }

    #[test]
    fn frame_decoder_reassembles_split_frames() {
        let mut d = FrameDecoder::default();
        d.feed(b"{\"a\":1}\n{\"b\"").unwrap();
        assert_eq!(d.next_frame().as_deref(), Some("{\"a\":1}"));
        assert_eq!(d.next_frame(), None, "second frame still partial");
        d.feed(b":2}\n").unwrap();
        assert_eq!(d.next_frame().as_deref(), Some("{\"b\":2}"));
        assert_eq!(d.next_frame(), None);
        assert_eq!(d.buffered(), 0);
    }

    #[test]
    fn frame_decoder_strips_crlf_and_keeps_empty_lines() {
        let mut d = FrameDecoder::default();
        d.feed(b"hello\r\n\nworld\n").unwrap();
        assert_eq!(d.next_frame().as_deref(), Some("hello"));
        assert_eq!(d.next_frame().as_deref(), Some(""));
        assert_eq!(d.next_frame().as_deref(), Some("world"));
        assert_eq!(d.next_frame(), None);
    }

    #[test]
    fn frame_decoder_rejects_oversized_frames_without_buffering() {
        let mut d = FrameDecoder::new(8);
        // A complete small frame in the same chunk still doesn't save the
        // oversized one that follows.
        let err = d.feed(b"ok\nthis frame is way past eight bytes").unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("exceeds 8 bytes"), "{}", err.message);
        assert!(d.is_poisoned());
        assert_eq!(d.next_frame(), None, "poisoned decoders yield nothing");
        assert!(d.buffered() <= 8 + 1, "oversized bytes were not buffered");
        // Drip-fed oversize (no newline ever) is caught at the cap too.
        let mut d = FrameDecoder::new(8);
        for _ in 0..4 {
            if d.feed(b"abc").is_err() {
                break;
            }
        }
        assert!(d.is_poisoned());
    }
}
