//! Versioned Hub API (the typed protocol layer).
//!
//! * [`proto`] — v1 wire protocol: [`proto::Request`] / [`proto::Response`]
//!   envelopes with explicit versioning (`v`), correlation ids (`id`), a
//!   typed [`proto::Op`] set, structured [`proto::WireError`]s, and typed
//!   payload structs. The single serialize/deserialize path for all hub
//!   traffic.
//! * [`service`] — [`service::PredictionService`]: the server-side engine
//!   that answers every op, owning a fitted-model cache keyed by
//!   `(job, machine_type)` and invalidated by repository revisions, so
//!   `predict_batch` on a warm cache performs zero refits.
//!
//! Future hub endpoints (auth, quotas, sharding) land here: add an
//! [`proto::Op`] variant + payload type, then a `dispatch` arm in the
//! service.

pub mod proto;
pub mod service;

pub use proto::{
    BatchPrediction, CatalogPayload, ErrorCode, HubStats, Op, Prediction, RepoList,
    RepoPayload, RepoStats, RepoSummary, ReplHandshake, ReplPage, ReplRecordPayload,
    ReplRepoImage, ReplSnapshotPayload, Request, Response, SubmitOutcome, WireError,
    PROTOCOL_VERSION,
};
pub use service::PredictionService;
