//! Server-side prediction service: the hub answers `predict` /
//! `predict_batch` / `configure` itself from the shared corpus, instead of
//! every user downloading the runtime data and fitting locally.
//!
//! The service owns a cache of fitted [`C3oPredictor`]s keyed by
//! `(job, machine_type)` and stamped with the repository's dataset
//! *revision* at fit time. [`crate::hub::HubState`] bumps a repository's
//! revision on every accepted contribution, so a stale cache entry is
//! detected by a simple revision comparison — and an accepted
//! `submit_runs` additionally drops exactly that job's entries so they do
//! not pin memory. Entries for other jobs are untouched.
//!
//! The cache is *sharded* into [`CACHE_STRIPES`] fixed stripes, each its
//! own `RwLock`ed map, so concurrent warm `predict`/`predict_batch` hits
//! take only a read lock on one stripe (DESIGN.md §7). Cold fits remain
//! single-flight per key: N concurrent cold requests pay for one fit.
//!
//! All ops of the v1 protocol dispatch through [`PredictionService::handle_line`];
//! the TCP layer in [`crate::hub::server`] only frames lines.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::cloud::Catalog;
use crate::configurator::{
    fit_prepared_with, search_catalog, select_machine_type, select_scale_out, CatalogSearch,
    ConfigChoice, GridPrediction, GridSource, MIN_RUNS_PER_TYPE, NoTypesEvaluated, UserGoals,
};
use crate::cv::parallel::FitEngine;
use crate::data::{Dataset, FeatureMatrix, JobKind};
use crate::hub::transport::TransportStats;
use crate::hub::{HubState, ValidationPolicy};
use crate::models::C3oPredictor;
use crate::runtime::FitBackend;
use crate::sim::JobInput;
use crate::util::json::Json;
use crate::util::tsv::Table;

use crate::obs::{self, Stage};

use super::proto::{
    self, BatchPrediction, CatalogPayload, ErrorCode, HistogramSummary, HubStats,
    MachineTypeInfo, MetricsPayload, Op, Prediction, RepoList, RepoPayload, RepoStats,
    RepoSummary, ReplHandshake, ReplLagStats, ReplPage, ReplRecordPayload, ReplRepoImage,
    ReplSnapshotPayload, Request, Response, SubmitOutcome, WireError,
};

/// A fitted predictor plus everything the configurator needs to reuse it.
pub struct FittedModel {
    pub machine_type: String,
    /// Winner of dynamic model selection (GBM | BOM | OGB | ...).
    pub chosen: String,
    /// CV residual mean μ (§IV-B).
    pub resid_mu: f64,
    /// CV residual std σ (§IV-B).
    pub resid_sigma: f64,
    /// Dataset revision this model was fitted on.
    pub revision: u64,
    pub predictor: C3oPredictor,
}

struct CacheSlot {
    revision: u64,
    model: Arc<FittedModel>,
}

/// Cache key: one fitted model per `(job, machine_type)`.
type CacheKey = (JobKind, String);

/// Fixed stripe count for the fitted-model cache. Contention is per
/// stripe, so unrelated keys proceed in parallel; 16 stripes comfortably
/// exceed jobs × machine types in practice while keeping invalidation a
/// short walk.
const CACHE_STRIPES: usize = 16;

/// Coalescing groups key on the *request's* `(job, machine_type)` pair —
/// before maintainer-default resolution — so grouping never changes which
/// model a request resolves to.
type CoalesceKey = (JobKind, Option<String>);

/// One open micro-batch of concurrent `predict` requests (DESIGN.md §7).
/// The first arrival becomes the *leader*: it sleeps out the coalescing
/// window, closes the group, runs one batched prediction over every
/// gathered row and publishes the result; *followers* append their row
/// and park on the condvar. The leader never waits on followers, so the
/// scheme cannot deadlock.
struct CoalesceGroup {
    state: Mutex<GroupState>,
    done: Condvar,
}

struct GroupState {
    rows: Vec<Vec<f64>>,
    /// Set by the leader when it departs with the rows; guarded by the
    /// group-map lock, so joiners never see a closed group in the map.
    closed: bool,
    result: Option<Result<GroupResult, WireError>>,
}

/// The leader's batched outcome, fanned back out by row index. Holds
/// the resolved model by `Arc` so the hot `predict_rows` path moves a
/// pointer instead of cloning the name strings; the owned copies are
/// made only at the wire boundary ([`GroupResult::prediction`] /
/// `predict_batch`).
struct GroupResult {
    model: Arc<FittedModel>,
    cached: bool,
    runtimes: Vec<f64>,
}

impl GroupResult {
    fn prediction(&self, index: usize) -> Prediction {
        Prediction {
            machine_type: self.model.machine_type.clone(),
            model: self.model.chosen.clone(),
            cached: self.cached,
            runtime_s: self.runtimes[index],
        }
    }
}

/// Follower-side replication progress (DESIGN.md §13): the leader's
/// revision watermark per repo from the most recent sync that touched
/// it, plus when the last fully successful tail pass completed. Lag is
/// computed at report time against the *current* local revision, so an
/// applying tailer drives it back to zero without another sync.
#[derive(Default)]
struct ReplProgress {
    leader_watermarks: HashMap<JobKind, u64>,
    last_tail: Option<Instant>,
}

/// The hub's stateful prediction engine.
pub struct PredictionService {
    state: Arc<HubState>,
    catalog: Catalog,
    policy: ValidationPolicy,
    backend: Arc<dyn FitBackend>,
    /// Sharded fitted-model cache: `CACHE_STRIPES` independent maps, each
    /// behind its own `RwLock`. Warm hits take one read lock on one
    /// stripe; inserts and invalidations take that stripe's write lock.
    cache: Vec<RwLock<HashMap<CacheKey, CacheSlot>>>,
    /// Per-key single-flight gates: concurrent cold requests for the same
    /// `(job, machine_type)` serialize here, and all but the first reuse
    /// the first's fit (bounded by jobs x machine types).
    fit_gates: Mutex<HashMap<CacheKey, Arc<Mutex<()>>>>,
    /// Fit-path execution engine for cold fits: CV worker threads plus the
    /// selection budget. Default: all cores, unlimited budget. Behind a
    /// leaf `RwLock` (read once per cold fit, never held across one) so
    /// `HubServer::start_with` can install `ServerConfig::fit_engine()`
    /// on the already-shared service.
    engine: RwLock<FitEngine>,
    /// Set on follower hubs (DESIGN.md §11): the leader's address. A
    /// follower refuses `submit_runs` with a typed `not_leader` error
    /// naming this address; all read ops serve normally from the
    /// replicated state.
    follower_of: RwLock<Option<String>>,
    fits: AtomicU64,
    cache_hits: AtomicU64,
    /// Lookups that missed the fitted-model cache (cold or stale entry).
    cache_misses: AtomicU64,
    /// Cold requests that parked on another request's in-flight fit and
    /// reused its result instead of fitting themselves.
    single_flight_waits: AtomicU64,
    /// Follower-side replication progress, fed by the tailer
    /// ([`Self::note_repl_progress`]) so `stats`/`metrics` can report
    /// lag and a wedged tailer is observable.
    repl_progress: Mutex<ReplProgress>,
    /// How long the first `predict` of a micro-batch waits for company
    /// before fitting alone. Zero (the default) disables coalescing:
    /// every predict takes the direct path.
    coalesce_window: RwLock<Duration>,
    /// Open coalescing groups by request key. Entries live only for the
    /// duration of one window; the leader removes its group under this
    /// lock before closing it.
    coalesce_groups: Mutex<HashMap<CoalesceKey, Arc<CoalesceGroup>>>,
    /// Predicts answered through a coalesced batch (counted only when a
    /// group actually merged ≥ 2 requests).
    coalesced_predicts: AtomicU64,
    /// Transport-layer counters, installed by [`crate::hub::HubServer`]
    /// so the `stats` op can report them. `None` for embedded
    /// (service-only) uses.
    transport: RwLock<Option<Arc<TransportStats>>>,
}

impl PredictionService {
    pub fn new(
        state: Arc<HubState>,
        catalog: Catalog,
        policy: ValidationPolicy,
        backend: Arc<dyn FitBackend>,
    ) -> Self {
        PredictionService {
            state,
            catalog,
            policy,
            backend,
            cache: (0..CACHE_STRIPES).map(|_| RwLock::new(HashMap::new())).collect(),
            fit_gates: Mutex::new(HashMap::new()),
            engine: RwLock::new(FitEngine::default()),
            follower_of: RwLock::new(None),
            fits: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            single_flight_waits: AtomicU64::new(0),
            repl_progress: Mutex::new(ReplProgress::default()),
            coalesce_window: RwLock::new(Duration::ZERO),
            coalesce_groups: Mutex::new(HashMap::new()),
            coalesced_predicts: AtomicU64::new(0),
            transport: RwLock::new(None),
        }
    }

    /// Set the predict-coalescing window. Zero disables coalescing.
    pub fn set_coalesce_window(&self, window: Duration) {
        *self.coalesce_window.write().unwrap() = window;
    }

    /// Install the transport counters reported by the `stats` op.
    pub fn set_transport_stats(&self, stats: Arc<TransportStats>) {
        *self.transport.write().unwrap() = Some(stats);
    }

    /// Mark this hub a read-only follower of `leader` (DESIGN.md §11):
    /// `submit_runs` is refused with `not_leader` naming that address,
    /// while reads keep serving from the replicated state.
    pub fn set_follower_of(&self, leader: impl Into<String>) {
        *self.follower_of.write().unwrap() = Some(leader.into());
    }

    /// The leader this hub follows, if it is a follower.
    pub fn follower_of(&self) -> Option<String> {
        self.follower_of.read().unwrap().clone()
    }

    /// Record the leader's revision watermark for `job` as seen by the
    /// follower's tailer. Called once per synced repo per tail pass.
    pub fn note_repl_progress(&self, job: JobKind, leader_revision: u64) {
        let mut progress = self.repl_progress.lock().unwrap();
        progress.leader_watermarks.insert(job, leader_revision);
    }

    /// Record a fully successful tail pass (every repo synced without
    /// error). `stats`/`metrics` report the age of this timestamp; a
    /// wedged tailer shows up as the age growing without bound.
    pub fn note_tail_success(&self) {
        self.repl_progress.lock().unwrap().last_tail = Some(Instant::now());
    }

    /// Follower lag view for `stats`/`metrics`: per-repo lag entries
    /// (leader watermark from the last sync vs the revision applied
    /// locally right now) and the age of the last successful tail pass.
    /// Empty/`None` on leaders.
    fn repl_status(&self) -> (Vec<ReplLagStats>, Option<u64>) {
        if self.follower_of.read().unwrap().is_none() {
            return (Vec::new(), None);
        }
        let progress = self.repl_progress.lock().unwrap();
        let mut lag: Vec<ReplLagStats> = progress
            .leader_watermarks
            .iter()
            .map(|(&job, &leader_revision)| ReplLagStats {
                job,
                leader_revision,
                applied_revision: self.state.get(job).map(|r| r.revision).unwrap_or(0),
            })
            .collect();
        lag.sort_by_key(|r| r.job.to_string());
        let age_ms = progress.last_tail.map(|t| t.elapsed().as_millis() as u64);
        (lag, age_ms)
    }

    /// Replace the cold-fit execution engine (builder style). Note that
    /// serving over TCP makes the `ServerConfig` authoritative: **both**
    /// `HubServer::start` and `start_with` install the config's
    /// `fit_engine()` over this (for `start`, the default config's). The
    /// builder matters for embedded (service-only) uses.
    pub fn with_engine(self, engine: FitEngine) -> Self {
        self.set_engine(engine);
        self
    }

    /// Install a new cold-fit execution engine. In-flight fits keep the
    /// engine they already resolved; subsequent cold fits use the new one.
    pub fn set_engine(&self, engine: FitEngine) {
        *self.engine.write().unwrap() = engine;
    }

    pub fn state(&self) -> &Arc<HubState> {
        &self.state
    }

    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// `(cold fits, cache hits, live cache entries)` since start.
    pub fn fit_stats(&self) -> (u64, u64, u64) {
        let entries: u64 = self.cache.iter().map(|s| s.read().unwrap().len() as u64).sum();
        (
            self.fits.load(Ordering::Relaxed),
            self.cache_hits.load(Ordering::Relaxed),
            entries,
        )
    }

    // -- fitted-model cache -------------------------------------------------

    /// The stripe a key lives in (stable for the service's lifetime).
    fn stripe(&self, key: &CacheKey) -> &RwLock<HashMap<CacheKey, CacheSlot>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.cache[h.finish() as usize % CACHE_STRIPES]
    }

    /// Warm-path lookup: one read lock on one stripe. Returns the model
    /// only if it was fitted on exactly `revision`.
    fn lookup(&self, key: &CacheKey, revision: u64) -> Option<Arc<FittedModel>> {
        let stripe = self.stripe(key).read().unwrap();
        match stripe.get(key) {
            Some(slot) if slot.revision == revision => {
                self.cache_hits.fetch_add(1, Ordering::Relaxed);
                Some(slot.model.clone())
            }
            _ => None,
        }
    }

    /// Fetch (or fit) the predictor for `(job, machine_type)`. Returns the
    /// model and whether it came from the cache.
    fn fitted(
        &self,
        job: JobKind,
        machine_type: Option<&str>,
    ) -> Result<(Arc<FittedModel>, bool), WireError> {
        let repo = self.state.get(job).ok_or_else(|| {
            WireError::new(ErrorCode::NotFound, format!("no repository for {job}"))
        })?;
        // §IV-A machine choice: explicit request > maintainer designation >
        // general-purpose fallback — identical to local mode, but answered
        // from the revision-cached columnar view, so the per-request path
        // never scans (or clones) the record list.
        let machine = select_machine_type(
            &self.catalog,
            repo.view(),
            machine_type.or(repo.maintainer_machine.as_deref()),
        )
        .map_err(|e| WireError::new(ErrorCode::Unavailable, format!("{e:#}")))?;

        let key = (job, machine.clone());
        if let Some(model) = self.lookup(&key, repo.revision) {
            return Ok((model, true));
        }
        self.cache_misses.fetch_add(1, Ordering::Relaxed);

        // Cold or stale. Single-flight: serialize fits per key so N
        // concurrent cold requests pay for one fit, not N.
        let gate = self
            .fit_gates
            .lock()
            .unwrap()
            .entry(key.clone())
            .or_insert_with(|| Arc::new(Mutex::new(())))
            .clone();
        let _fitting = gate.lock().unwrap();

        // Fresh snapshot under the gate: while we waited, the previous
        // holder may have fitted — possibly on a newer revision than our
        // pre-gate snapshot — so both the re-check and the fit must use
        // current data.
        let repo = self.state.get(job).ok_or_else(|| {
            WireError::new(ErrorCode::NotFound, format!("no repository for {job}"))
        })?;
        if let Some(model) = self.lookup(&key, repo.revision) {
            self.single_flight_waits.fetch_add(1, Ordering::Relaxed);
            return Ok((model, true));
        }

        // Fit outside the cache lock (fits are slow), from the snapshot's
        // columnar view — built once per revision, shared by every fit.
        // The engine fans CV work across cores; thread count and point
        // caps are bit-deterministic, while a wall-clock budget
        // (`max_seconds`) plans from timed probes and may legitimately
        // pick different plans under different machine load.
        let engine = self.engine.read().unwrap().clone();
        let fit_start = obs::now_us();
        let (predictor, report) =
            fit_prepared_with(repo.view(), &machine, self.backend.clone(), &engine)
                .map_err(|e| WireError::new(ErrorCode::Unavailable, format!("{e:#}")))?;
        obs::metrics().record_since(Stage::Fit, fit_start);
        self.fits.fetch_add(1, Ordering::Relaxed);
        let model = Arc::new(FittedModel {
            machine_type: machine.clone(),
            chosen: report.chosen.clone(),
            resid_mu: report.chosen_score.resid_mean,
            resid_sigma: report.chosen_score.resid_std,
            revision: repo.revision,
            predictor,
        });
        self.stripe(&key)
            .write()
            .unwrap()
            .insert(key, CacheSlot { revision: repo.revision, model: model.clone() });
        Ok((model, false))
    }

    fn check_arity(&self, job: JobKind, width: usize, what: &str) -> Result<(), WireError> {
        let want = 2 + job.context_features();
        if width != want {
            return Err(WireError::new(
                ErrorCode::InvalidData,
                format!(
                    "{job}: expected {want} {what} [scale_out, data_size_gb, context...], got {width}"
                ),
            ));
        }
        Ok(())
    }

    // -- typed op implementations -------------------------------------------

    pub fn list_repos(&self) -> RepoList {
        let repos = self
            .state
            .jobs()
            .into_iter()
            .filter_map(|job| self.state.get(job))
            .map(|r| RepoSummary {
                job: r.job,
                description: r.description.clone(),
                records: r.data.len(),
                maintainer_machine: r.maintainer_machine.clone(),
                revision: r.revision,
            })
            .collect();
        RepoList { repos }
    }

    pub fn get_repo(&self, job: JobKind) -> Result<RepoPayload, WireError> {
        let repo = self.state.get(job).ok_or_else(|| {
            WireError::new(ErrorCode::NotFound, format!("no repository for {job}"))
        })?;
        let data_tsv = repo
            .data
            .to_table()
            .and_then(|t| t.to_text())
            .map_err(|e| WireError::internal(&e))?;
        Ok(RepoPayload {
            job: repo.job,
            description: repo.description.clone(),
            maintainer_machine: repo.maintainer_machine.clone(),
            revision: repo.revision,
            data_tsv,
        })
    }

    pub fn submit_tsv(&self, job: JobKind, data_tsv: &str) -> Result<SubmitOutcome, WireError> {
        if self.state.get(job).is_none() {
            return Err(WireError::new(
                ErrorCode::NotFound,
                format!("no repository for {job}"),
            ));
        }
        let contribution = Table::parse(data_tsv)
            .and_then(|t| Dataset::from_table(job, &t))
            .map_err(|e| WireError::new(ErrorCode::InvalidData, format!("{e:#}")))?;
        // Atomic validate+merge — see HubState::submit for the race this
        // prevents. The returned revision is read inside the same critical
        // section, so it is exactly this submission's revision. With a
        // durable store attached, the accepted contribution is WAL-logged
        // before the publish: an `accepted` reply implies the data
        // survives a hub crash (DESIGN.md §9).
        let (verdict, revision) = self
            .state
            .submit(contribution, &self.policy)
            .map_err(|e| WireError::internal(&e))?;
        if verdict.accepted {
            // The revision stamp already makes stale entries unreachable;
            // drop them eagerly so exactly this job's slots free up. One
            // short write-locked walk per stripe; other stripes' readers
            // are unaffected.
            for stripe in &self.cache {
                stripe.write().unwrap().retain(|(j, _), _| *j != job);
            }
        }
        Ok(SubmitOutcome { accepted: verdict.accepted, reason: verdict.reason, revision })
    }

    pub fn catalog_payload(&self) -> CatalogPayload {
        CatalogPayload {
            types: self
                .catalog
                .types()
                .iter()
                .map(|t| MachineTypeInfo {
                    name: t.name.clone(),
                    vcpus: t.vcpus,
                    memory_gb: t.memory_gb,
                    price_per_hour: t.price_per_hour,
                    family: t.family.to_string(),
                })
                .collect(),
            provisioning_delay_s: self.catalog.provisioning_delay_s,
        }
    }

    pub fn stats_payload(&self) -> HubStats {
        let (accepted, rejected) = self.state.counters();
        let (fits, cache_hits, cache_entries) = self.fit_stats();
        let storage = self.state.storage();
        let sstats = storage.as_ref().map(|s| s.stats()).unwrap_or_default();
        // Per-repo revision/record watermarks: what a follower (or an
        // operator watching replication lag) compares against the leader.
        let per_repo = self
            .state
            .jobs()
            .into_iter()
            .filter_map(|job| self.state.get(job))
            .map(|r| RepoStats {
                job: r.job,
                revision: r.revision,
                records: r.data.len() as u64,
            })
            .collect();
        let (open_connections, peak_pipeline_depth) = self
            .transport
            .read()
            .unwrap()
            .as_ref()
            .map(|t| {
                (
                    t.open_connections.load(Ordering::Relaxed),
                    t.peak_pipeline_depth.load(Ordering::Relaxed),
                )
            })
            .unwrap_or((0, 0));
        let (repl_lag, repl_tail_age_ms) = self.repl_status();
        HubStats {
            accepted,
            rejected,
            repos: self.state.jobs().len() as u64,
            fits,
            cache_hits,
            cache_entries,
            durable: storage.is_some(),
            wal_appends: sstats.wal_appends,
            snapshots: sstats.snapshots,
            appends_since_snapshot: sstats.pending,
            open_connections,
            peak_pipeline_depth,
            coalesced_predicts: self.coalesced_predicts.load(Ordering::Relaxed),
            per_repo,
            repl_lag,
            repl_tail_age_ms,
        }
    }

    /// The `metrics` op (DESIGN.md §13): every stage histogram from the
    /// global telemetry registry plus the service, transport, storage
    /// and replication counters/gauges, in one generic payload.
    pub fn metrics_payload(&self) -> MetricsPayload {
        let reg = obs::metrics();
        let histograms = Stage::ALL
            .iter()
            .map(|&stage| {
                let snap = reg.stage(stage).snapshot();
                HistogramSummary {
                    name: format!("stage_{}", stage.name()),
                    count: snap.count,
                    sum_us: snap.sum,
                    max_us: snap.max,
                    p50_us: snap.p50(),
                    p95_us: snap.p95(),
                    p99_us: snap.p99(),
                }
            })
            .collect();

        let stats = self.stats_payload();
        let mut counters: Vec<(String, u64)> = vec![
            ("accepted_submits".into(), stats.accepted),
            ("rejected_submits".into(), stats.rejected),
            ("fits".into(), stats.fits),
            ("cache_hits".into(), stats.cache_hits),
            ("cache_misses".into(), self.cache_misses.load(Ordering::Relaxed)),
            (
                "single_flight_waits".into(),
                self.single_flight_waits.load(Ordering::Relaxed),
            ),
            ("coalesced_predicts".into(), stats.coalesced_predicts),
            ("wal_appends".into(), stats.wal_appends),
            ("snapshots".into(), stats.snapshots),
            ("traces_completed".into(), reg.traces.completed()),
            ("slow_requests".into(), reg.traces.slow()),
        ];
        if let Some(t) = self.transport.read().unwrap().as_ref() {
            counters.push((
                "refused_connections".into(),
                t.refused_connections.load(Ordering::Relaxed),
            ));
            counters.push((
                "refusal_write_failures".into(),
                t.refusal_write_failures.load(Ordering::Relaxed),
            ));
            counters.push((
                "slow_reader_disconnects".into(),
                t.slow_reader_disconnects.load(Ordering::Relaxed),
            ));
            counters.push((
                "idle_reaped_connections".into(),
                t.idle_reaped_connections.load(Ordering::Relaxed),
            ));
        }

        let mut gauges: Vec<(String, u64)> = vec![
            ("open_connections".into(), stats.open_connections),
            ("peak_pipeline_depth".into(), stats.peak_pipeline_depth),
            ("cache_entries".into(), stats.cache_entries),
            ("wal_backlog".into(), stats.appends_since_snapshot),
            ("busy_workers".into(), reg.busy_workers.load(Ordering::Relaxed)),
            ("workers_total".into(), reg.workers_total.load(Ordering::Relaxed)),
        ];
        for lag in &stats.repl_lag {
            gauges.push((format!("repl_lag_records{{repo=\"{}\"}}", lag.job), lag.lag()));
        }
        if let Some(age) = stats.repl_tail_age_ms {
            gauges.push(("repl_tail_age_ms".into(), age));
        }

        MetricsPayload { histograms, counters, gauges }
    }

    // -- replication (leader side, DESIGN.md §11) ---------------------------

    /// The durable store every repl op ships from; replication without one
    /// is a typed `unavailable`, not a panic.
    fn repl_store(&self) -> Result<Arc<crate::storage::DurableStore>, WireError> {
        self.state.storage().ok_or_else(|| {
            WireError::new(
                ErrorCode::Unavailable,
                "replication requires a durable store on the leader \
                 (start it with --data-dir)",
            )
        })
    }

    /// Lag probe: the leader's current revision for `job` plus whether the
    /// records right above `from_revision` are still in the WAL
    /// (`compacted: false`) or only reachable via [`Self::repl_snapshot_payload`].
    pub fn repl_subscribe(
        &self,
        job: JobKind,
        from_revision: u64,
    ) -> Result<ReplHandshake, WireError> {
        let page = self.repl_fetch(job, from_revision, 1)?;
        Ok(ReplHandshake {
            job,
            leader_revision: page.leader_revision,
            compacted: page.compacted,
        })
    }

    /// One page of WAL records with revisions strictly above
    /// `from_revision`, oldest first. `compacted: true` means the page
    /// does *not* start at `from_revision + 1` — the follower fell behind
    /// the compaction horizon and must bootstrap from a snapshot.
    pub fn repl_fetch(
        &self,
        job: JobKind,
        from_revision: u64,
        max: u64,
    ) -> Result<ReplPage, WireError> {
        let store = self.repl_store()?;
        if self.state.get(job).is_none() {
            return Err(WireError::new(
                ErrorCode::NotFound,
                format!("no repository for {job}"),
            ));
        }
        let page = store
            .tail(job, from_revision, max as usize)
            .map_err(|e| WireError::internal(&e))?;
        // The WAL watermark can momentarily trail the published state
        // (coverage advances after the append's lock drops); advertise
        // whichever is ahead so followers see monotone leader revisions.
        let leader_revision =
            self.state.revision(job).unwrap_or(0).max(page.durable_revision);
        Ok(ReplPage {
            job,
            leader_revision,
            compacted: page.compacted,
            records: page
                .records
                .into_iter()
                .map(|r| ReplRecordPayload { revision: r.revision, data_tsv: r.data_tsv })
                .collect(),
        })
    }

    /// Cold-bootstrap image: every repository's current corpus as TSV with
    /// its revision watermark — the same serialization as on-disk
    /// snapshots, so an installed image is bit-identical to the leader's
    /// state (a superset of the latest compacted snapshot).
    pub fn repl_snapshot_payload(&self) -> Result<ReplSnapshotPayload, WireError> {
        let _store = self.repl_store()?;
        let mut repos = Vec::new();
        for job in self.state.jobs() {
            let Some(repo) = self.state.get(job) else { continue };
            let data_tsv = repo
                .data
                .to_table()
                .and_then(|t| t.to_text())
                .map_err(|e| WireError::internal(&e))?;
            repos.push(ReplRepoImage {
                job: repo.job,
                revision: repo.revision,
                description: repo.description.clone(),
                maintainer_machine: repo.maintainer_machine.clone(),
                data_tsv,
            });
        }
        Ok(ReplSnapshotPayload { repos })
    }

    /// Follower-side apply (DESIGN.md §11): install one leader-committed
    /// record via [`HubState::apply_replicated`] — gap-free, bit-identical,
    /// WAL-logged locally before publish — then drop exactly this job's
    /// fitted-model cache entries, as an accepted local submit would.
    pub fn apply_replicated(
        &self,
        job: JobKind,
        revision: u64,
        data_tsv: &str,
    ) -> crate::Result<u64> {
        let applied = self.state.apply_replicated(job, revision, data_tsv)?;
        for stripe in &self.cache {
            stripe.write().unwrap().retain(|(j, _), _| *j != job);
        }
        Ok(applied)
    }

    pub fn predict(
        &self,
        job: JobKind,
        machine_type: Option<&str>,
        features: &[f64],
    ) -> Result<Prediction, WireError> {
        self.check_arity(job, features.len(), "features")?;
        let window = *self.coalesce_window.read().unwrap();
        if window.is_zero() {
            let res = self.predict_rows(job, machine_type, &[features.to_vec()])?;
            return Ok(res.prediction(0));
        }
        self.predict_coalesced(job, machine_type, features, window)
    }

    /// Micro-batching `predict` path: concurrent requests for the same
    /// `(job, machine_type)` within `window` are folded into one batched
    /// prediction against the cached model and fanned back out. Runtimes
    /// are **bit-identical** to the direct path — the batch resolves the
    /// same model through [`PredictionService::fitted`] and runs the same
    /// `predict_one` per row; coalescing only changes *when* rows are
    /// evaluated, never *how*.
    fn predict_coalesced(
        &self,
        job: JobKind,
        machine_type: Option<&str>,
        features: &[f64],
        window: Duration,
    ) -> Result<Prediction, WireError> {
        let key: CoalesceKey = (job, machine_type.map(str::to_string));
        // Join an open group or found one. Lock order is group map →
        // group state, everywhere, and the leader removes its group from
        // the map in the same critical section that closes it — so a
        // group found in the map is always still accepting rows.
        let (group, index) = {
            let mut groups = self.coalesce_groups.lock().unwrap();
            if let Some(g) = groups.get(&key) {
                let g = g.clone();
                let mut st = g.state.lock().unwrap();
                debug_assert!(!st.closed, "closed group left in the map");
                st.rows.push(features.to_vec());
                let index = st.rows.len() - 1;
                drop(st);
                (g, index)
            } else {
                let g = Arc::new(CoalesceGroup {
                    state: Mutex::new(GroupState {
                        rows: vec![features.to_vec()],
                        closed: false,
                        result: None,
                    }),
                    done: Condvar::new(),
                });
                groups.insert(key.clone(), g.clone());
                (g, 0)
            }
        };

        if index == 0 {
            // Leader: wait out the window on this worker thread (the
            // reactor is unaffected — only one worker idles, briefly),
            // then close the group and answer for everyone.
            std::thread::sleep(window);
            let rows = {
                let mut groups = self.coalesce_groups.lock().unwrap();
                let mut st = group.state.lock().unwrap();
                st.closed = true;
                groups.remove(&key);
                std::mem::take(&mut st.rows)
            };
            let merged = rows.len();
            let outcome = self.predict_rows(job, machine_type, &rows);
            if merged > 1 {
                self.coalesced_predicts.fetch_add(merged as u64, Ordering::Relaxed);
            }
            let mut st = group.state.lock().unwrap();
            st.result = Some(outcome);
            self.done_extract(st, &group.done, index)
        } else {
            // Follower: park until the leader publishes. The generous
            // timeout only guards against a leader dying mid-fit (worker
            // panic); falling back to the direct path keeps the request
            // correct either way.
            let deadline = Instant::now() + window + Duration::from_secs(60);
            let mut st = group.state.lock().unwrap();
            while st.result.is_none() {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    drop(st);
                    let res = self.predict_rows(job, machine_type, &[features.to_vec()])?;
                    return Ok(res.prediction(0));
                }
                st = group.done.wait_timeout(st, left).unwrap().0;
            }
            self.done_extract(st, &group.done, index)
        }
    }

    /// Pull row `index`'s prediction out of a finished group (the result
    /// is present by construction on both caller paths) and pass the
    /// wake-up along so every parked follower gets a turn.
    fn done_extract(
        &self,
        st: std::sync::MutexGuard<'_, GroupState>,
        done: &Condvar,
        index: usize,
    ) -> Result<Prediction, WireError> {
        let out = match st.result.as_ref().expect("group result published") {
            Ok(res) => Ok(res.prediction(index)),
            Err(e) => Err(e.clone()),
        };
        drop(st);
        done.notify_all();
        out
    }

    /// Shared model-resolution + per-row prediction core for `predict`,
    /// the coalescer and `predict_batch`.
    fn predict_rows(
        &self,
        job: JobKind,
        machine_type: Option<&str>,
        rows: &[Vec<f64>],
    ) -> Result<GroupResult, WireError> {
        let (fm, cached) = self.fitted(job, machine_type)?;
        let predict_start = obs::now_us();
        let runtimes = rows
            .iter()
            .map(|row| fm.predictor.predict_one(row))
            .collect::<crate::Result<Vec<f64>>>()
            .map_err(|e| WireError::internal(&e))?;
        obs::metrics().record_since(Stage::Predict, predict_start);
        Ok(GroupResult { model: fm, cached, runtimes })
    }

    pub fn predict_batch(
        &self,
        job: JobKind,
        machine_type: Option<&str>,
        rows: &[Vec<f64>],
    ) -> Result<BatchPrediction, WireError> {
        for row in rows {
            self.check_arity(job, row.len(), "features per row")?;
        }
        let res = self.predict_rows(job, machine_type, rows)?;
        Ok(BatchPrediction {
            // lint: allow(alloc_hot, reason = "wire-boundary copy into the owned reply struct; once per batch, not per row")
            machine_type: res.model.machine_type.clone(),
            // lint: allow(alloc_hot, reason = "wire-boundary copy into the owned reply struct; once per batch, not per row")
            model: res.model.chosen.clone(),
            cached: res.cached,
            runtimes: res.runtimes,
        })
    }

    pub fn configure(
        &self,
        job: JobKind,
        data_size_gb: f64,
        context: Vec<f64>,
        goals: &UserGoals,
        machine_type: Option<&str>,
    ) -> Result<ConfigChoice, WireError> {
        self.check_arity(job, 2 + context.len(), "features")?;
        let (fm, _) = self.fitted(job, machine_type)?;
        let input = JobInput::new(job, data_size_gb, context);
        select_scale_out(
            &self.catalog,
            &fm.machine_type,
            &fm.predictor,
            &input,
            goals,
            fm.resid_mu,
            fm.resid_sigma,
        )
        .map_err(|e| WireError::new(ErrorCode::InvalidData, format!("{e:#}")))
    }

    /// Catalog-wide configuration search: evaluate every machine type's
    /// scale-out grid — one fitted model per type, resolved through the
    /// revision-keyed cache, so a warm hub answers the whole grid with
    /// zero refits — and return the cost-optimal admissible configuration
    /// plus the ranked frontier. Types below the data floor are reported
    /// as `insufficient_data`, never silently skipped.
    pub fn configure_search(
        &self,
        job: JobKind,
        data_size_gb: f64,
        context: Vec<f64>,
        goals: &UserGoals,
    ) -> Result<CatalogSearch, WireError> {
        self.check_arity(job, 2 + context.len(), "features")?;
        let repo = self.state.get(job).ok_or_else(|| {
            WireError::new(ErrorCode::NotFound, format!("no repository for {job}"))
        })?;
        if self.catalog.types().is_empty() {
            return Err(WireError::new(
                ErrorCode::Unavailable,
                "catalog has no machine types to search",
            ));
        }
        // Data-starved repo: nothing to fit anywhere — a distinct typed
        // error from "deadline impossible on a fitted grid".
        let view = repo.view().clone();
        if !self.catalog.types().iter().any(|t| view.rows(&t.name) >= MIN_RUNS_PER_TYPE) {
            return Err(WireError::new(
                ErrorCode::Unavailable,
                format!(
                    "no machine type has >= {MIN_RUNS_PER_TYPE} runs for {job}; \
                     contribute runtime data first"
                ),
            ));
        }
        let input = JobInput::new(job, data_size_gb, context);
        let mut source = ServiceGridSource { svc: self, job, view };
        search_catalog(&self.catalog, &mut source, &input, goals).map_err(|e| {
            // Zero types evaluated (every covered type failed its fit) is
            // a hub-side condition like the data-starved case above — not
            // a bad request.
            let code = if e.downcast_ref::<NoTypesEvaluated>().is_some() {
                ErrorCode::Unavailable
            } else {
                ErrorCode::InvalidData
            };
            WireError::new(code, format!("{e:#}"))
        })
    }

    // -- protocol dispatch --------------------------------------------------

    /// Handle one wire line and produce the response frame. Never panics on
    /// untrusted input; every failure is a structured `error{code}`.
    pub fn handle_line(&self, line: &str, stop: &AtomicBool) -> Response {
        self.handle_line_traced(line, stop).0
    }

    /// [`Self::handle_line`] plus the decoded op name — the server's
    /// request tracing wants the label without re-parsing the line.
    /// Empty when the frame failed to parse.
    pub fn handle_line_traced(&self, line: &str, stop: &AtomicBool) -> (Response, &'static str) {
        match Request::parse(line) {
            Ok(req) => {
                let id = req.id;
                let op_name = req.op.name();
                let response = match self.dispatch(req.op, stop) {
                    Ok(payload) => Response::ok(id, payload),
                    Err(e) => Response::err(id, e),
                };
                (response, op_name)
            }
            Err(e) => (Response::err(e.id, e.error), ""),
        }
    }

    fn dispatch(&self, op: Op, stop: &AtomicBool) -> Result<Json, WireError> {
        match op {
            Op::ListRepos => Ok(self.list_repos().to_json()),
            Op::GetRepo { job } => Ok(self.get_repo(job)?.to_json()),
            Op::SubmitRuns { job, data_tsv } => {
                // Followers are read-only: route the writer to the leader
                // with a typed error instead of diverging the replica.
                if let Some(leader) = self.follower_of() {
                    return Err(WireError::new(
                        ErrorCode::NotLeader,
                        format!(
                            "this hub is a read-only follower; submit to the \
                             leader at {leader}"
                        ),
                    ));
                }
                Ok(self.submit_tsv(job, &data_tsv)?.to_json())
            }
            Op::Catalog => Ok(self.catalog_payload().to_json()),
            Op::Stats => Ok(self.stats_payload().to_json()),
            Op::Metrics => Ok(self.metrics_payload().to_json()),
            Op::Predict { job, machine_type, features } => {
                Ok(self.predict(job, machine_type.as_deref(), &features)?.to_json())
            }
            Op::PredictBatch { job, machine_type, rows } => {
                Ok(self.predict_batch(job, machine_type.as_deref(), &rows)?.to_json())
            }
            Op::Configure {
                job,
                data_size_gb,
                context,
                deadline_s,
                confidence,
                machine_type,
            } => {
                let goals = UserGoals { deadline_s, confidence };
                let choice =
                    self.configure(job, data_size_gb, context, &goals, machine_type.as_deref())?;
                Ok(proto::config_choice_to_json(&choice))
            }
            Op::ConfigureSearch { job, data_size_gb, context, deadline_s, confidence } => {
                let goals = UserGoals { deadline_s, confidence };
                let search = self.configure_search(job, data_size_gb, context, &goals)?;
                Ok(proto::catalog_search_to_json(&search))
            }
            Op::ReplSubscribe { job, from_revision } => {
                Ok(self.repl_subscribe(job, from_revision)?.to_json())
            }
            Op::ReplFetch { job, from_revision, max } => {
                Ok(self.repl_fetch(job, from_revision, max)?.to_json())
            }
            Op::ReplSnapshot => Ok(self.repl_snapshot_payload()?.to_json()),
            Op::Shutdown => {
                stop.store(true, Ordering::SeqCst);
                Ok(Json::obj(vec![("stopping", Json::Bool(true))]))
            }
        }
    }
}

/// [`GridSource`] over the service's fitted-model cache: one `fitted`
/// resolution + one batch prediction per machine type. Warm entries make
/// the whole grid zero-refit; cold types single-flight their fit on the
/// service's engine. The `view` is the repository snapshot resolved at
/// search start — per-type models may resolve a newer revision if a
/// contribution lands mid-search, exactly as N separate `predict_batch`
/// calls would.
struct ServiceGridSource<'a> {
    svc: &'a PredictionService,
    job: JobKind,
    view: Arc<FeatureMatrix>,
}

impl GridSource for ServiceGridSource<'_> {
    fn runs(&self, machine_type: &str) -> usize {
        self.view.rows(machine_type)
    }

    fn predict_grid(
        &mut self,
        machine_type: &str,
        rows: &[Vec<f64>],
    ) -> crate::Result<GridPrediction> {
        let (fm, _cached) = self
            .svc
            .fitted(self.job, Some(machine_type))
            .map_err(anyhow::Error::new)?;
        let runtimes = rows
            .iter()
            .map(|row| fm.predictor.predict_one(row))
            .collect::<crate::Result<Vec<f64>>>()?;
        Ok(GridPrediction {
            model: fm.chosen.clone(),
            resid_mu: fm.resid_mu,
            resid_sigma: fm.resid_sigma,
            runtimes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hub::Repository;
    use crate::runtime::NativeBackend;
    use crate::sim::{generate_job, GeneratorConfig, WorkloadModel};
    use crate::util::prng::Pcg;

    fn service_with_data() -> PredictionService {
        let catalog = Catalog::aws_like();
        let state = Arc::new(HubState::new());
        for job in [JobKind::Sort, JobKind::Grep] {
            let mut repo = Repository::new(job, &format!("spark {job}"));
            repo.maintainer_machine = Some("m5.xlarge".to_string());
            repo.data = generate_job(job, &GeneratorConfig::default(), &catalog).unwrap();
            state.insert(repo);
        }
        PredictionService::new(
            state,
            catalog,
            ValidationPolicy::default(),
            Arc::new(NativeBackend::new()),
        )
    }

    fn honest_tsv(job: JobKind, n: usize, seed: u64) -> String {
        let catalog = Catalog::aws_like();
        let model = WorkloadModel::default();
        let mt = catalog.get("m5.xlarge").unwrap();
        let mut rng = Pcg::seed(seed);
        let mut ds = Dataset::new(job);
        for _ in 0..n {
            let s = rng.range(2, 13) as u32;
            let ctx = match job {
                JobKind::Sort => vec![],
                JobKind::Grep => vec![0.01],
                _ => vec![5.0, 0.001],
            };
            let input = JobInput::new(job, rng.range_f64(10.0, 20.0), ctx);
            ds.push(model.observe(mt, s, &input, &mut rng)).unwrap();
        }
        ds.to_table().unwrap().to_text().unwrap()
    }

    #[test]
    fn warm_cache_performs_zero_refits() {
        let svc = service_with_data();
        let p = svc.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        assert!(!p.cached, "first call must be a cold fit");
        assert_eq!(svc.fit_stats().0, 1);

        let rows: Vec<Vec<f64>> = (2..=12).map(|s| vec![s as f64, 15.0]).collect();
        let b = svc.predict_batch(JobKind::Sort, None, &rows).unwrap();
        assert!(b.cached);
        assert_eq!(b.runtimes.len(), rows.len());
        let (fits, hits, entries) = svc.fit_stats();
        assert_eq!(fits, 1, "warm predict_batch must not refit");
        assert!(hits >= 1);
        assert_eq!(entries, 1);
    }

    #[test]
    fn concurrent_warm_predicts_share_one_fit() {
        let svc = Arc::new(service_with_data());
        // Prime the cache with the one cold fit.
        svc.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        let mut handles = Vec::new();
        for t in 0..4usize {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25usize {
                    let s = 2.0 + ((t + i) % 10) as f64;
                    let p = svc.predict(JobKind::Sort, None, &[s, 15.0]).unwrap();
                    assert!(p.cached, "warm path must hit the striped cache");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (fits, hits, entries) = svc.fit_stats();
        assert_eq!(fits, 1, "concurrent warm predicts must never refit");
        assert_eq!(entries, 1);
        assert!(hits >= 100);
    }

    #[test]
    fn accepted_submit_invalidates_only_that_job() {
        let svc = service_with_data();
        svc.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        svc.predict(JobKind::Grep, None, &[4.0, 15.0, 0.01]).unwrap();
        assert_eq!(svc.fit_stats().0, 2);

        let out = svc.submit_tsv(JobKind::Sort, &honest_tsv(JobKind::Sort, 8, 11)).unwrap();
        assert!(out.accepted, "{}", out.reason);
        assert_eq!(out.revision, 1, "accepted submit bumps the revision");

        // Grep is untouched: served from cache, no new fit.
        let g = svc.predict(JobKind::Grep, None, &[4.0, 15.0, 0.01]).unwrap();
        assert!(g.cached);
        assert_eq!(svc.fit_stats().0, 2);

        // Sort was invalidated: next predict refits on the new revision.
        let s = svc.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        assert!(!s.cached);
        assert_eq!(svc.fit_stats().0, 3);
    }

    #[test]
    fn rejected_submit_keeps_cache_and_revision() {
        let svc = service_with_data();
        svc.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        // Fabricated runtimes: the §III-C-b gate must bounce them.
        let mut poison = Dataset::new(JobKind::Sort);
        let mut rng = Pcg::seed(3);
        for _ in 0..25 {
            poison
                .push(crate::data::RunRecord {
                    machine_type: "m5.xlarge".into(),
                    scale_out: rng.range(2, 13) as u32,
                    data_size_gb: rng.range_f64(10.0, 20.0),
                    context: vec![],
                    runtime_s: 1e7,
                })
                .unwrap();
        }
        let tsv = poison.to_table().unwrap().to_text().unwrap();
        let out = svc.submit_tsv(JobKind::Sort, &tsv).unwrap();
        assert!(!out.accepted);
        assert_eq!(out.revision, 0);
        let p = svc.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        assert!(p.cached, "rejected submit must not invalidate the cache");
    }

    #[test]
    fn missing_repo_is_not_found() {
        let svc = service_with_data();
        let e = svc.predict(JobKind::PageRank, None, &[4.0, 0.25, 0.1, 0.001]).unwrap_err();
        assert_eq!(e.code, ErrorCode::NotFound);
        let e = svc.get_repo(JobKind::PageRank).unwrap_err();
        assert_eq!(e.code, ErrorCode::NotFound);
    }

    #[test]
    fn wrong_feature_arity_is_invalid_data() {
        let svc = service_with_data();
        let e = svc.predict(JobKind::Sort, None, &[4.0]).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidData);
        let e = svc
            .predict_batch(JobKind::Grep, None, &[vec![4.0, 15.0]])
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidData);
    }

    #[test]
    fn configure_matches_local_configurator() {
        let svc = service_with_data();
        let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
        let remote = svc
            .configure(JobKind::Sort, 15.0, vec![], &goals, Some("m5.xlarge"))
            .unwrap();
        let local = crate::configurator::configure(
            svc.catalog(),
            &svc.state().get(JobKind::Sort).unwrap().data,
            Some("m5.xlarge"),
            &JobInput::new(JobKind::Sort, 15.0, vec![]),
            &goals,
            Arc::new(NativeBackend::new()),
        )
        .unwrap();
        assert_eq!(remote.machine_type, local.machine_type);
        assert_eq!(remote.scale_out, local.scale_out);
        assert!((remote.predicted_runtime_s - local.predicted_runtime_s).abs() < 1e-9);
    }

    #[test]
    fn warm_configure_search_performs_zero_refits() {
        use crate::configurator::TypeOutcome;
        let svc = service_with_data();
        let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
        let s1 = svc.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap();
        let evaluated = s1
            .types
            .iter()
            .filter(|t| matches!(t.outcome, TypeOutcome::Evaluated { .. }))
            .count();
        assert_eq!(evaluated, 2, "the default corpus covers m5.xlarge and c5.xlarge");
        assert_eq!(svc.fit_stats().0 as usize, evaluated, "one cold fit per evaluated type");

        // Second full-grid search: answered entirely from the cache.
        let s2 = svc.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap();
        let (fits, hits, entries) = svc.fit_stats();
        assert_eq!(fits as usize, evaluated, "warm full-grid search must not refit");
        assert!(hits >= evaluated as u64);
        assert_eq!(entries as usize, evaluated);
        assert_eq!(s1.choice.machine_type, s2.choice.machine_type);
        assert_eq!(s1.choice.scale_out, s2.choice.scale_out);
        assert_eq!(s1.choice.est_cost_usd.to_bits(), s2.choice.est_cost_usd.to_bits());

        // The search shares the cache with plain predict/predict_batch.
        let p = svc.predict(JobKind::Sort, Some(&s1.choice.machine_type), &[4.0, 15.0]).unwrap();
        assert!(p.cached, "search-fitted models serve later predicts warm");
    }

    #[test]
    fn configure_search_error_paths_are_typed() {
        let svc = service_with_data();
        let goals = UserGoals::default();
        // Unknown repository.
        let e = svc
            .configure_search(JobKind::PageRank, 0.25, vec![0.1, 0.001], &goals)
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::NotFound);
        // Deadline-impossible grid: typed invalid_data, never an unwind.
        let goals = UserGoals { deadline_s: Some(1.0), confidence: 0.95 };
        let e = svc.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidData);
        assert!(e.message.contains("none admissible"), "{}", e.message);
    }

    #[test]
    fn data_starved_repo_search_is_unavailable() {
        let state = Arc::new(HubState::new());
        state.insert(Repository::new(JobKind::KMeans, "spark kmeans"));
        let svc = PredictionService::new(
            state,
            Catalog::aws_like(),
            ValidationPolicy::default(),
            Arc::new(NativeBackend::new()),
        );
        let e = svc
            .configure_search(JobKind::KMeans, 15.0, vec![5.0, 0.001], &UserGoals::default())
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::Unavailable);
        assert!(e.message.contains("runs"), "{}", e.message);
    }

    #[test]
    fn empty_catalog_search_is_unavailable() {
        let catalog = Catalog::aws_like();
        let state = Arc::new(HubState::new());
        let mut repo = Repository::new(JobKind::Sort, "spark sort");
        repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        state.insert(repo);
        let svc = PredictionService::new(
            state,
            Catalog::custom(vec![], 0.0, vec![]),
            ValidationPolicy::default(),
            Arc::new(NativeBackend::new()),
        );
        let e = svc
            .configure_search(JobKind::Sort, 15.0, vec![], &UserGoals::default())
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::Unavailable);
        assert!(e.message.contains("no machine types"), "{}", e.message);
    }

    #[test]
    fn degenerate_catalog_prices_yield_structured_error_not_panic() {
        use crate::cloud::MachineType;
        let catalog = Catalog::aws_like();
        let state = Arc::new(HubState::new());
        let mut repo = Repository::new(JobKind::Sort, "spark sort");
        repo.maintainer_machine = Some("m5.xlarge".to_string());
        repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        state.insert(repo);
        let nan_catalog = Catalog::custom(
            vec![MachineType {
                name: "m5.xlarge".into(),
                vcpus: 4,
                memory_gb: 16.0,
                cpu_factor: 1.0,
                io_factor: 1.0,
                price_per_hour: f64::NAN,
                family: "general",
            }],
            420.0,
            (2..=12).collect(),
        );
        let svc = PredictionService::new(
            state,
            nan_catalog,
            ValidationPolicy::default(),
            Arc::new(NativeBackend::new()),
        );
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        // Every option costs NaN: the old no-deadline pick panicked on
        // `partial_cmp().unwrap()` — a hub worker must answer an error
        // frame instead of unwinding.
        let e = svc.configure(JobKind::Sort, 15.0, vec![], &goals, None).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidData);
        assert!(e.message.contains("finite positive"), "{}", e.message);
        let e = svc.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidData);
        assert!(e.message.contains("finite positive"), "{}", e.message);
    }

    #[test]
    fn handle_line_never_drops_malformed_input() {
        let svc = service_with_data();
        let stop = AtomicBool::new(false);
        let r = svc.handle_line("not json at all", &stop);
        let line = r.to_line();
        assert!(line.contains(r#""ok":false"#), "{line}");
        assert!(line.contains("bad_request"), "{line}");

        let r = svc.handle_line(r#"{"v":1,"id":4,"op":"stats"}"#, &stop);
        assert!(r.to_line().contains(r#""ok":true"#));
        assert!(!stop.load(Ordering::SeqCst));

        let r = svc.handle_line(r#"{"v":1,"id":5,"op":"shutdown"}"#, &stop);
        assert!(r.to_line().contains(r#""ok":true"#));
        assert!(stop.load(Ordering::SeqCst), "shutdown op sets the stop flag");
    }

    #[test]
    fn follower_refuses_submit_with_not_leader_naming_the_leader() {
        let svc = service_with_data();
        svc.set_follower_of("10.1.2.3:7033");
        let stop = AtomicBool::new(false);
        let req = Request::new(
            8,
            Op::SubmitRuns {
                job: JobKind::Sort,
                data_tsv: honest_tsv(JobKind::Sort, 4, 21),
            },
        );
        let line = svc.handle_line(&req.to_line(), &stop).to_line();
        assert!(line.contains(r#""ok":false"#), "{line}");
        assert!(line.contains("not_leader"), "{line}");
        assert!(line.contains("10.1.2.3:7033"), "follower names its leader: {line}");
        assert_eq!(svc.state().revision(JobKind::Sort), Some(0), "nothing committed");

        // Reads keep serving from the replicated state.
        let p = svc.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        assert!(p.runtime_s.is_finite());
    }

    #[test]
    fn repl_ops_without_a_store_are_unavailable() {
        let svc = service_with_data();
        assert!(svc.follower_of().is_none());
        let e = svc.repl_fetch(JobKind::Sort, 0, 16).unwrap_err();
        assert_eq!(e.code, ErrorCode::Unavailable);
        assert!(e.message.contains("--data-dir"), "{}", e.message);
        let e = svc.repl_snapshot_payload().unwrap_err();
        assert_eq!(e.code, ErrorCode::Unavailable);
    }

    #[test]
    fn repl_fetch_ships_submits_and_follower_applies_bit_identical() {
        use crate::storage::{DurableStore, FsyncPolicy, StorageConfig};
        let dir = std::env::temp_dir()
            .join(format!("c3o_svc_repl_test_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let leader = service_with_data();
        let config = StorageConfig { fsync: FsyncPolicy::Never, snapshot_every: 0 };
        let (store, recovered) = DurableStore::open(&dir, config).unwrap();
        assert!(recovered.is_empty());
        let store = Arc::new(store);
        // Baseline snapshot so the store covers the generated corpus.
        leader.state().snapshot_to(&store).unwrap();
        leader.state().set_storage(store).unwrap();

        let out = leader.submit_tsv(JobKind::Sort, &honest_tsv(JobKind::Sort, 8, 11)).unwrap();
        assert!(out.accepted, "{}", out.reason);

        // Subscribe right at the follower's watermark: in reach of the WAL.
        let hs = leader.repl_subscribe(JobKind::Sort, 0).unwrap();
        assert_eq!(hs.leader_revision, 1);
        assert!(!hs.compacted);

        let page = leader.repl_fetch(JobKind::Sort, 0, 16).unwrap();
        assert_eq!(page.records.len(), 1);
        assert_eq!(page.records[0].revision, 1);

        // A fresh follower with the same seed corpus converges
        // bit-identically through the validation-free apply path.
        let follower = service_with_data();
        follower.set_follower_of("ignored:0");
        let rec = &page.records[0];
        assert_eq!(follower.apply_replicated(JobKind::Sort, rec.revision, &rec.data_tsv).unwrap(), 1);
        let l = leader.get_repo(JobKind::Sort).unwrap();
        let f = follower.get_repo(JobKind::Sort).unwrap();
        assert_eq!(l.revision, f.revision);
        assert_eq!(l.data_tsv, f.data_tsv, "replica corpus is byte-identical");

        // The snapshot image carries the same bytes for cold bootstrap.
        let snap = leader.repl_snapshot_payload().unwrap();
        let image = snap.repos.iter().find(|r| r.job == JobKind::Sort).unwrap();
        assert_eq!(image.revision, 1);
        assert_eq!(image.data_tsv, l.data_tsv);

        // Stats expose replication lag observables.
        let stats = leader.stats_payload();
        assert_eq!(stats.appends_since_snapshot, 1);
        let sort = stats.per_repo.iter().find(|r| r.job == JobKind::Sort).unwrap();
        assert_eq!(sort.revision, 1);
        assert_eq!(sort.records as usize, f.data_tsv.lines().count() - 1);

        drop(leader.state().detach_storage());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn coalesced_predicts_are_bit_identical_and_counted() {
        let svc = Arc::new(service_with_data());
        // Reference runtimes from the direct path (window disabled).
        let rows: Vec<Vec<f64>> = (2..=9).map(|s| vec![s as f64, 15.0]).collect();
        let direct: Vec<Prediction> =
            rows.iter().map(|r| svc.predict(JobKind::Sort, None, r).unwrap()).collect();
        // Re-run the same predicts coalesced: all threads release into
        // the same window together.
        svc.set_coalesce_window(Duration::from_millis(150));
        let barrier = Arc::new(std::sync::Barrier::new(rows.len()));
        let handles: Vec<_> = rows
            .iter()
            .cloned()
            .map(|row| {
                let svc = svc.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    svc.predict(JobKind::Sort, None, &row).unwrap()
                })
            })
            .collect();
        let coalesced: Vec<Prediction> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (d, c) in direct.iter().zip(&coalesced) {
            assert_eq!(
                d.runtime_s.to_bits(),
                c.runtime_s.to_bits(),
                "coalesced runtime must be bit-identical to the direct path"
            );
            assert_eq!(d.machine_type, c.machine_type);
            assert_eq!(d.model, c.model);
        }
        let stats = svc.stats_payload();
        assert!(
            stats.coalesced_predicts >= 2,
            "barrier-released predicts must merge at least one group, got {}",
            stats.coalesced_predicts
        );
        assert_eq!(svc.fit_stats().0, 1, "coalesced predicts never refit a warm model");
        assert!(
            svc.coalesce_groups.lock().unwrap().is_empty(),
            "departed groups must leave the map"
        );
    }

    #[test]
    fn zero_window_predicts_take_the_direct_path() {
        let svc = service_with_data();
        svc.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        let stats = svc.stats_payload();
        assert_eq!(stats.coalesced_predicts, 0);
        let transport = (stats.open_connections, stats.peak_pipeline_depth);
        assert_eq!(transport, (0, 0), "no transport attached in embedded use");
    }
}
