//! Structural pass over the token stream: function spans, test-only
//! regions, and the loaded per-file view ([`SourceFile`]) every rule
//! consumes.

use std::path::PathBuf;

use super::lexer::{self, Comment, TokKind, Token};

/// One `fn` item with the token range of its body.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: u32,
    /// Token index of the body `{`.
    pub body_start: usize,
    /// Token index of the matching `}`.
    pub body_end: usize,
    /// Inside a `#[cfg(test)]` / `#[test]` region — exempt from rules.
    pub is_test: bool,
}

/// A parsed source file plus everything the rules need: raw lines (for
/// marker / SAFETY adjacency), tokens, comments, fn spans, test spans.
#[derive(Debug)]
pub struct SourceFile {
    pub path: PathBuf,
    /// Path relative to the lint root, `/`-separated — rules gate on
    /// suffixes like `hub/server.rs`.
    pub rel: String,
    pub lines: Vec<String>,
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
    pub fns: Vec<FnSpan>,
    test_regions: Vec<(usize, usize)>,
}

impl SourceFile {
    pub fn parse(path: PathBuf, rel: String, src: &str) -> SourceFile {
        let (tokens, comments) = lexer::lex(src);
        let test_regions = test_regions(&tokens);
        let fns = functions(&tokens, &test_regions);
        SourceFile {
            path,
            rel,
            lines: src.lines().map(str::to_string).collect(),
            tokens,
            comments,
            fns,
            test_regions,
        }
    }

    /// True when token index `i` falls inside a test-only region.
    pub fn in_test(&self, i: usize) -> bool {
        self.test_regions.iter().any(|&(s, e)| i >= s && i <= e)
    }

    /// Raw text of 1-based line `n`, or `""` past EOF.
    pub fn line(&self, n: u32) -> &str {
        self.lines.get(n as usize - 1).map_or("", String::as_str)
    }
}

/// Find the token index of the `}` matching the `{` at `open`.
/// Returns the last token index when unbalanced (EOF recovery).
pub fn matching_brace(tokens: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    for (i, t) in tokens.iter().enumerate().skip(open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        return i;
                    }
                }
                _ => {}
            }
        }
    }
    tokens.len().saturating_sub(1)
}

/// Token ranges covered by `#[test]` / `#[cfg(test)]`-attributed items
/// (most importantly each file's `mod tests { ... }` block).
fn test_regions(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut i = 0usize;
    while i + 1 < tokens.len() {
        if !(tokens[i].is("#") && tokens[i + 1].is("[")) {
            i += 1;
            continue;
        }
        // Scan the attribute's bracket group.
        let mut j = i + 1;
        let mut depth = 0usize;
        let mut saw_test = false;
        let mut saw_not = false;
        while j < tokens.len() {
            let t = &tokens[j];
            if t.is("[") {
                depth += 1;
            } else if t.is("]") {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    break;
                }
            } else if t.kind == TokKind::Ident {
                match t.text.as_str() {
                    "test" => saw_test = true,
                    "not" => saw_not = true,
                    _ => {}
                }
            }
            j += 1;
        }
        if !(saw_test && !saw_not) {
            i = j + 1;
            continue;
        }
        // Attributed item: skip any further attributes, then the region
        // runs to the item's closing brace (or ends at `;`).
        let mut k = j + 1;
        while k + 1 < tokens.len() && tokens[k].is("#") && tokens[k + 1].is("[") {
            let mut d = 0usize;
            while k < tokens.len() {
                if tokens[k].is("[") {
                    d += 1;
                } else if tokens[k].is("]") {
                    d = d.saturating_sub(1);
                    if d == 0 {
                        break;
                    }
                }
                k += 1;
            }
            k += 1;
        }
        while k < tokens.len() && !tokens[k].is("{") && !tokens[k].is(";") {
            k += 1;
        }
        if k < tokens.len() && tokens[k].is("{") {
            let end = matching_brace(tokens, k);
            regions.push((i, end));
            i = end + 1;
        } else {
            i = k + 1;
        }
    }
    regions
}

/// All `fn` items (free fns, methods, nested fns — each gets its own
/// span; consumers mask inner spans when walking an outer body).
fn functions(tokens: &[Token], test_regions: &[(usize, usize)]) -> Vec<FnSpan> {
    let mut fns = Vec::new();
    for i in 0..tokens.len() {
        if !(tokens[i].kind == TokKind::Ident && tokens[i].is("fn")) {
            continue;
        }
        let Some(name_tok) = tokens.get(i + 1) else { continue };
        if name_tok.kind != TokKind::Ident {
            continue; // `fn(` in a fn-pointer type
        }
        // Body `{` or declaration `;` — whichever comes first.
        let mut j = i + 2;
        while j < tokens.len() && !tokens[j].is("{") && !tokens[j].is(";") {
            j += 1;
        }
        if j >= tokens.len() || tokens[j].is(";") {
            continue;
        }
        let end = matching_brace(tokens, j);
        let is_test = test_regions.iter().any(|&(s, e)| i >= s && i <= e);
        fns.push(FnSpan {
            name: name_tok.text.clone(),
            line: tokens[i].line,
            body_start: j,
            body_end: end,
            is_test,
        });
    }
    fns
}
