//! L8 — durability ordering (`ordering`, plus the L4 `durability`
//! rename check it generalizes).
//!
//! DESIGN.md §7's crash-safety argument is an *ordering*: an accepted
//! contribution is WAL-appended, the append is made durable (fsync,
//! under the configured policy), and only then does the copy-on-write
//! publish make it visible / the submit get acknowledged. This rule
//! runs a small automaton over each function's CFG in `storage/` and
//! the submit path (`hub/repo.rs`):
//!
//! - state per path: `(appended, synced-since-append)`, tracked as a
//!   *may*-set of configurations (both branches of an `if` survive);
//! - events: `append`, `append_durable` (append whose durability is
//!   policy-resolved internally — including the `Always` rollback on a
//!   failed fsync — so it counts as append+fsync), `sync`/`sync_all`/
//!   `sync_data` (fsync), `sync_dir`, `fs::rename`, `publish`/
//!   `commit_data`, `ack`/`acknowledge`;
//! - findings: a publish reachable while some path has an unsynced
//!   append (**publish-before-fsync**), an ack reachable before any
//!   append in a function that appends (**ack-before-append**), and —
//!   the old L4, now path-sensitive — an `fs::rename` from which no
//!   `sync_dir` is forward-reachable (rule id stays `durability`).
//!
//! Events are **interprocedural**: a call that resolves (via
//! [`dataflow::resolve_at`]) to a scanned function splices in that
//! function's event summary, so `store.append(..)` in `hub/repo.rs`
//! expands to the `append_durable` it performs and the submit path
//! checks end-to-end. Summaries are memoized, recursion-guarded, and
//! capped (depth 4, 32 events) — past the caps a call degrades to its
//! direct event name, which is the conservative direction.

use std::collections::{BTreeMap, BTreeSet};

use super::cfg::Cfg;
use super::dataflow;
use super::lexer::TokKind;
use super::scanner::SourceFile;
use super::Finding;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Append,
    AppendDurable,
    Fsync,
    DirSync,
    Rename,
    Publish,
    Ack,
}

fn in_scope(rel: &str) -> bool {
    rel.starts_with("storage/")
        || rel.contains("/storage/")
        || rel == "hub/repo.rs"
        || rel.ends_with("/hub/repo.rs")
}

/// The event named directly by the call at token `i` (an ident followed
/// by `(`), if any. `rename` only counts with an `fs::` path — plain
/// `rename` idents are too common to claim.
fn direct_event(sf: &SourceFile, i: usize) -> Option<Ev> {
    let t = &sf.tokens;
    match t[i].text.as_str() {
        "append" => Some(Ev::Append),
        "append_durable" => Some(Ev::AppendDurable),
        "sync" | "sync_all" | "sync_data" => Some(Ev::Fsync),
        "sync_dir" => Some(Ev::DirSync),
        "publish" | "commit_data" => Some(Ev::Publish),
        "ack" | "acknowledge" => Some(Ev::Ack),
        "rename"
            if i >= 3 && t[i - 1].is(":") && t[i - 2].is(":") && t[i - 3].is("fs") =>
        {
            Some(Ev::Rename)
        }
        _ => None,
    }
}

/// Memoized per-function event summaries for call-site splicing.
struct Summaries<'a> {
    files: &'a [SourceFile],
    memo: BTreeMap<(String, String), Vec<Ev>>,
    stack: BTreeSet<(String, String)>,
}

const MAX_DEPTH: usize = 4;
const MAX_EVENTS: usize = 32;

impl<'a> Summaries<'a> {
    fn new(files: &'a [SourceFile]) -> Summaries<'a> {
        Summaries { files, memo: BTreeMap::new(), stack: BTreeSet::new() }
    }

    /// Effective event summary of `(rel, name)`. `append_durable` is
    /// overridden to a single `AppendDurable`: its body's fsync is
    /// conditional on the fsync *policy* and it rolls back the frame
    /// when an `Always`-mode fsync fails, so from the caller's view the
    /// append and its durability are one atomic step.
    fn of(&mut self, rel: &str, name: &str, depth: usize) -> Vec<Ev> {
        if name == "append_durable" {
            return vec![Ev::AppendDurable];
        }
        let key = (rel.to_string(), name.to_string());
        if let Some(v) = self.memo.get(&key) {
            return v.clone();
        }
        if depth >= MAX_DEPTH || !self.stack.insert(key.clone()) {
            return Vec::new();
        }
        let mut evs = Vec::new();
        if let Some(sf) = self.files.iter().find(|f| f.rel == key.0) {
            if let Some(span) = sf.fns.iter().find(|f| !f.is_test && f.name == name) {
                let nested = dataflow::nested_fn_spans(sf, span);
                let mut i = span.body_start + 1;
                while i < span.body_end.min(sf.tokens.len()) {
                    if let Some(&(_, e)) = nested.iter().find(|&&(s, e)| i >= s && i <= e) {
                        i = e + 1;
                        continue;
                    }
                    for ev in self.call_events(sf, i, depth) {
                        if evs.len() < MAX_EVENTS {
                            evs.push(ev);
                        }
                    }
                    i += 1;
                }
            }
        }
        self.stack.remove(&key);
        self.memo.insert(key, evs.clone());
        evs
    }

    /// Events contributed by token `i` of `sf`: the callee's spliced
    /// summary when the call resolves to a scanned fn with a non-empty
    /// summary, else the direct event name.
    fn call_events(&mut self, sf: &SourceFile, i: usize, depth: usize) -> Vec<Ev> {
        let t = &sf.tokens;
        if t[i].kind != TokKind::Ident
            || !t.get(i + 1).is_some_and(|n| n.is("("))
            || (i > 0 && t[i - 1].is("fn"))
        {
            return Vec::new();
        }
        if let Some((rel, name)) = dataflow::resolve_at(self.files, sf, i) {
            let evs = self.of(&rel, &name, depth + 1);
            if !evs.is_empty() {
                return evs;
            }
        }
        direct_event(sf, i).into_iter().collect()
    }
}

/// Path configuration bits: index = `appended * 2 + synced_since`.
const A0S0: u8 = 1 << 0;
const A0S1: u8 = 1 << 1;
const A1S0: u8 = 1 << 2;
const A1S1: u8 = 1 << 3;

fn step(mask: u8, ev: Ev) -> u8 {
    match ev {
        Ev::Append => {
            if mask != 0 {
                A1S0
            } else {
                0
            }
        }
        Ev::AppendDurable => {
            if mask != 0 {
                A1S1
            } else {
                0
            }
        }
        Ev::Fsync => {
            let mut m = 0;
            if mask & (A0S0 | A0S1) != 0 {
                m |= A0S1;
            }
            if mask & (A1S0 | A1S1) != 0 {
                m |= A1S1;
            }
            m
        }
        _ => mask,
    }
}

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut sums = Summaries::new(files);
    for sf in files {
        if !in_scope(&sf.rel) {
            continue;
        }
        for span in &sf.fns {
            if span.is_test {
                continue;
            }
            check_fn(sf, span.body_start + 1, span.body_end, &span.name, &mut sums, &mut out);
        }
    }
    out
}

fn check_fn(
    sf: &SourceFile,
    lo: usize,
    hi: usize,
    fn_name: &str,
    sums: &mut Summaries<'_>,
    out: &mut Vec<Finding>,
) {
    let cfg = Cfg::build(&sf.tokens, lo, hi);

    // Per-statement event lists (with the line of each event's call
    // site; spliced events inherit the call site's line).
    let mut events: Vec<Vec<Vec<(Ev, u32)>>> = Vec::with_capacity(cfg.blocks.len());
    let mut has_append = false;
    for block in &cfg.blocks {
        let mut per_block = Vec::with_capacity(block.stmts.len());
        for stmt in &block.stmts {
            let mut evs = Vec::new();
            for i in stmt.lo..stmt.hi.min(sf.tokens.len()) {
                for ev in sums.call_events(sf, i, 0) {
                    has_append |= matches!(ev, Ev::Append | Ev::AppendDurable);
                    evs.push((ev, sf.tokens[i].line));
                }
            }
            per_block.push(evs);
        }
        events.push(per_block);
    }

    // May-set fixpoint of path configurations per block entry.
    let mut inm = vec![0u8; cfg.blocks.len()];
    inm[cfg.entry] = A0S0;
    for _ in 0..(4 * cfg.blocks.len() + 8) {
        let mut changed = false;
        for b in 0..cfg.blocks.len() {
            let mut m = inm[b];
            for evs in &events[b] {
                for &(ev, _) in evs {
                    m = step(m, ev);
                }
            }
            for &s in &cfg.blocks[b].succs {
                if inm[s] | m != inm[s] {
                    inm[s] |= m;
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }

    // Evidence pass.
    let mut renames: Vec<(usize, usize, usize, u32)> = Vec::new();
    let mut dirsyncs: Vec<(usize, usize, usize)> = Vec::new();
    for b in 0..cfg.blocks.len() {
        let mut m = inm[b];
        for (si, evs) in events[b].iter().enumerate() {
            for (ei, &(ev, line)) in evs.iter().enumerate() {
                match ev {
                    Ev::Publish | Ev::Ack if m & A1S0 != 0 => {
                        let what = if ev == Ev::Publish { "copy-on-write publish" } else { "acknowledgment" };
                        out.push(Finding {
                            file: sf.rel.clone(),
                            line,
                            rule: "ordering",
                            message: format!(
                                "{what} in `{fn_name}` while a WAL append may not yet be \
                                 fsynced — make the append durable (fsync / append_durable) \
                                 before publishing"
                            ),
                        });
                    }
                    Ev::Ack if m & (A0S0 | A0S1) != 0 && has_append => {
                        out.push(Finding {
                            file: sf.rel.clone(),
                            line,
                            rule: "ordering",
                            message: format!(
                                "acknowledgment in `{fn_name}` may precede the WAL append — \
                                 an acked submit must already be in the log"
                            ),
                        });
                    }
                    Ev::Rename => renames.push((b, si, ei, line)),
                    Ev::DirSync => dirsyncs.push((b, si, ei)),
                    _ => {}
                }
                m = step(m, ev);
            }
        }
    }

    // Rename → sync_dir forward reachability (same statement later,
    // later in the same block, or any CFG-reachable block — back edges
    // included, so a loop retry that syncs on the next pass counts).
    for (b, si, ei, line) in renames {
        let reach = cfg.reachable_from(b);
        let ok = dirsyncs.iter().any(|&(db, dsi, dei)| {
            (db == b && (dsi, dei) > (si, ei)) || reach.contains(&db)
        });
        if !ok {
            out.push(Finding {
                file: sf.rel.clone(),
                line,
                rule: "durability",
                message: format!(
                    "`fs::rename` in `{fn_name}` with no reachable `sync_dir` — the \
                     rename is not durable until the parent directory entry is fsynced"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn run(src: &str) -> Vec<Finding> {
        let sf =
            SourceFile::parse(PathBuf::from("x/storage/mod.rs"), "storage/mod.rs".into(), src);
        check(std::slice::from_ref(&sf))
    }

    #[test]
    fn publish_before_fsync_fires() {
        let f = run(
            "fn bad(&self) { self.wal.append(rev, tsv); self.cell.publish(data); }",
        );
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "ordering");
        assert!(f[0].message.contains("publish"), "{f:?}");
    }

    #[test]
    fn append_sync_publish_is_clean() {
        let f = run(
            "fn good(&self) { self.wal.append(rev, tsv); self.wal.sync(); \
             self.cell.publish(data); }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn append_durable_counts_as_synced() {
        let f = run(
            "fn good(&self) { self.wal.append_durable(rev, tsv, true); \
             self.cell.publish(data); }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn branch_that_skips_the_fsync_still_fires() {
        let f = run(
            "fn bad(&self) { self.wal.append(rev, tsv); \
             if fast { self.wal.sync(); } self.cell.publish(data); }",
        );
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "ordering");
    }

    #[test]
    fn ack_before_append_fires() {
        let f = run("fn bad(&self) { self.conn.ack(id); self.wal.append(rev, tsv); }");
        assert!(
            f.iter().any(|x| x.rule == "ordering" && x.message.contains("precede")),
            "{f:?}"
        );
    }

    #[test]
    fn rename_without_reachable_sync_dir_fires() {
        let f = run("fn bad(&self) { fs::rename(&a, &b).ok(); }");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "durability");
    }

    #[test]
    fn rename_reaches_sync_dir_through_a_loop_back_edge() {
        // The sync_dir is *earlier* in the loop body: only the back edge
        // makes it reachable from the rename — the old line scanner's
        // same-function heuristic is now a real path query.
        let f = run(
            "fn good(&self) { for _ in 0..2 { if ok { sync_dir(d); return; } \
             if fs::rename(&a, &b).is_err() { continue; } } }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn out_of_scope_files_are_ignored() {
        let sf = SourceFile::parse(
            PathBuf::from("x/models/fit.rs"),
            "models/fit.rs".into(),
            "fn f(&self) { self.wal.append(r, t); self.cell.publish(d); }",
        );
        assert!(check(std::slice::from_ref(&sf)).is_empty());
    }

    #[test]
    fn interprocedural_summary_expands_the_callee() {
        // `do_append` performs append+sync; the caller publishes after
        // calling it — clean only because the summary is spliced in.
        let f = run(
            "impl S { fn do_append(&self) { self.wal.append(r, t); self.wal.sync(); } \
             fn submit(&self) { self.do_append(); self.cell.publish(d); } }",
        );
        assert!(f.is_empty(), "{f:?}");
    }
}
