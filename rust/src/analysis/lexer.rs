//! Minimal Rust lexer for the `c3o lint` analyzer.
//!
//! Produces a flat token stream with line numbers plus a separate list
//! of comments — the rules need comment *text* to audit `// SAFETY:`
//! justifications (L3) and `// lint: allow(...)` markers. This is not a
//! full Rust lexer; it understands exactly enough to keep the
//! structural scanner honest about braces and identifiers: line and
//! nested block comments, plain / raw / byte string literals, char
//! literals vs lifetimes after `'`, and numeric literals (so `0..n`
//! does not read as a float).
//!
//! Everything the rules never look at (operator composition, keyword
//! classification) is left as single-character `Punct` tokens; patterns
//! like `::` are matched as two adjacent `:` tokens by the consumers.

/// Lexical class of a [`Token`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unsafe`, `unwrap`, ...).
    Ident,
    /// Numeric literal (integers, floats; suffix glued on).
    Num,
    /// String literal (plain, raw, or byte); `text` is the interior.
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`); `text` excludes the quote.
    Lifetime,
    /// Any other single character (`{`, `.`, `[`, `#`, ...).
    Punct,
}

/// One token with the 1-based source line it starts on.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    /// True when the token is exactly the punct/ident `s`.
    pub fn is(&self, s: &str) -> bool {
        self.text == s
    }
}

/// One comment with the 1-based line it starts on; `text` is the
/// interior (after `//`, or between `/*` and `*/`).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Tokenize `src`. Infallible by construction: unterminated constructs
/// run to end-of-file rather than erroring, which is the right behavior
/// for a linter that must never panic on the tree it audits.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let count_lines = |s: &[char]| s.iter().filter(|&&c| c == '\n').count() as u32;

    'outer: while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();

        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // Line comment (also doc comments `///`, `//!`).
        if c == '/' && next == Some('/') {
            let start = i + 2;
            let mut j = start;
            while j < chars.len() && chars[j] != '\n' {
                j += 1;
            }
            comments.push(Comment { line, text: chars[start..j].iter().collect() });
            i = j;
            continue;
        }

        // Block comment, nested.
        if c == '/' && next == Some('*') {
            let start_line = line;
            let start = i + 2;
            let mut depth = 1usize;
            let mut j = start;
            while j < chars.len() && depth > 0 {
                if chars[j] == '/' && chars.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && chars.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let end = j.saturating_sub(2).max(start);
            line += count_lines(&chars[i..j]);
            comments.push(Comment { line: start_line, text: chars[start..end].iter().collect() });
            i = j;
            continue;
        }

        // Raw / byte string prefixes: r"...", r#"..."#, b"...", br#"..."#.
        if (c == 'r' || c == 'b') && matches!(next, Some('"') | Some('#') | Some('\'')) {
            let mut j = i + 1;
            let mut raw = c == 'r';
            if c == 'b' && chars.get(j) == Some(&'r') {
                raw = true;
                j += 1;
            }
            if c == 'b' && chars.get(j) == Some(&'\'') {
                // Byte char literal b'x'.
                let (tok, adv, nl) = lex_char(&chars, j, line);
                toks.push(Token { kind: tok.0, text: tok.1, line });
                line += nl;
                i = j + adv;
                continue;
            }
            if raw {
                let mut hashes = 0usize;
                while chars.get(j) == Some(&'#') {
                    hashes += 1;
                    j += 1;
                }
                if chars.get(j) == Some(&'"') {
                    let start_line = line;
                    let body_start = j + 1;
                    let mut k = body_start;
                    while k < chars.len() {
                        if chars[k] == '"' {
                            let mut h = 0usize;
                            while chars.get(k + 1 + h) == Some(&'#') {
                                h += 1;
                            }
                            if h >= hashes {
                                line += count_lines(&chars[i..k]);
                                toks.push(Token {
                                    kind: TokKind::Str,
                                    text: chars[body_start..k].iter().collect(),
                                    line: start_line,
                                });
                                i = k + 1 + hashes;
                                continue 'outer;
                            }
                        }
                        k += 1;
                    }
                    // Unterminated: consume to EOF.
                    toks.push(Token {
                        kind: TokKind::Str,
                        text: chars[body_start..].iter().collect(),
                        line: start_line,
                    });
                    i = chars.len();
                    continue;
                }
                // `r` / `br` not followed by a string: plain ident path.
            }
            // `b"..."`: fall through to the string case below from j.
            if chars.get(j) == Some(&'"') {
                let start_line = line;
                let (text, adv, nl) = lex_quoted(&chars, j);
                line += nl;
                toks.push(Token { kind: TokKind::Str, text, line: start_line });
                i = j + adv;
                continue;
            }
        }

        // Identifier / keyword.
        if c == '_' || c.is_alphabetic() {
            let start = i;
            let mut j = i;
            while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
                j += 1;
            }
            toks.push(Token { kind: TokKind::Ident, text: chars[start..j].iter().collect(), line });
            i = j;
            continue;
        }

        // Number. Consume digits + ident-continue (hex, suffixes), plus
        // one `.fraction` only when a digit follows the dot — so range
        // expressions like `0..n` stay three tokens.
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
                j += 1;
            }
            if chars.get(j) == Some(&'.') && chars.get(j + 1).is_some_and(|d| d.is_ascii_digit()) {
                j += 1;
                while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
                    j += 1;
                }
            }
            toks.push(Token { kind: TokKind::Num, text: chars[start..j].iter().collect(), line });
            i = j;
            continue;
        }

        // String literal.
        if c == '"' {
            let start_line = line;
            let (text, adv, nl) = lex_quoted(&chars, i);
            line += nl;
            toks.push(Token { kind: TokKind::Str, text, line: start_line });
            i += adv;
            continue;
        }

        // Char literal vs lifetime.
        if c == '\'' {
            let (tok, adv, nl) = lex_char(&chars, i, line);
            toks.push(Token { kind: tok.0, text: tok.1, line });
            line += nl;
            i += adv;
            continue;
        }

        toks.push(Token { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }

    (toks, comments)
}

/// Lex a `"..."` string starting at `chars[at] == '"'`. Returns the
/// interior text, chars consumed, and newlines crossed.
fn lex_quoted(chars: &[char], at: usize) -> (String, usize, u32) {
    let mut j = at + 1;
    let mut nl = 0u32;
    let mut text = String::new();
    while j < chars.len() {
        match chars[j] {
            '\\' => {
                // Keep escapes verbatim; rules only compare literals
                // that contain none.
                if let Some(&e) = chars.get(j + 1) {
                    text.push('\\');
                    text.push(e);
                    if e == '\n' {
                        nl += 1;
                    }
                }
                j += 2;
            }
            '"' => return (text, j + 1 - at, nl),
            ch => {
                if ch == '\n' {
                    nl += 1;
                }
                text.push(ch);
                j += 1;
            }
        }
    }
    (text, chars.len() - at, nl)
}

/// Lex from a `'` at `chars[at]`: either a char literal or a lifetime.
/// Returns ((kind, text), chars consumed, newlines crossed).
fn lex_char(chars: &[char], at: usize, _line: u32) -> ((TokKind, String), usize, u32) {
    let next = chars.get(at + 1).copied();
    // Lifetime: `'ident` not closed by a quote right after.
    if let Some(n) = next {
        if (n == '_' || n.is_alphabetic()) && chars.get(at + 2) != Some(&'\'') {
            let mut j = at + 1;
            while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
                j += 1;
            }
            return ((TokKind::Lifetime, chars[at + 1..j].iter().collect()), j - at, 0);
        }
    }
    // Char literal: consume to the closing quote, honoring escapes.
    let mut j = at + 1;
    let mut nl = 0u32;
    let mut text = String::new();
    while j < chars.len() {
        match chars[j] {
            '\\' => {
                if let Some(&e) = chars.get(j + 1) {
                    text.push('\\');
                    text.push(e);
                }
                j += 2;
            }
            '\'' => return ((TokKind::Char, text), j + 1 - at, nl),
            ch => {
                if ch == '\n' {
                    nl += 1;
                }
                text.push(ch);
                j += 1;
            }
        }
    }
    ((TokKind::Char, text), chars.len() - at, nl)
}
