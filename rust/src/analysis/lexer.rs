//! Minimal Rust lexer for the `c3o lint` analyzer.
//!
//! Produces a flat token stream with line numbers plus a separate list
//! of comments — the rules need comment *text* to audit `// SAFETY:`
//! justifications (L3) and `// lint: allow(...)` markers. This is not a
//! full Rust lexer; it understands exactly enough to keep the
//! structural scanner honest about braces and identifiers: line and
//! nested block comments, plain / raw / byte string literals, raw
//! identifiers (`r#fn`), char literals vs lifetimes after `'`, and
//! numeric literals (so `0..n` does not read as a float).
//!
//! Every token and comment carries its `span` — the half-open char
//! index range `[lo, hi)` of the *full* lexeme in the source, including
//! quotes, prefixes, and raw-string hashes. The property tests assert
//! that spans tile the input exactly: sorted spans are disjoint and the
//! gaps between them contain only whitespace.
//!
//! Everything the rules never look at (operator composition, keyword
//! classification) is left as single-character `Punct` tokens; patterns
//! like `::` are matched as two adjacent `:` tokens by the consumers.

/// Lexical class of a [`Token`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unsafe`, `unwrap`, ...). A raw
    /// identifier keeps its `r#` prefix in `text`, so `r#fn` never
    /// compares equal to the keyword `fn`.
    Ident,
    /// Numeric literal (integers, floats; suffix glued on).
    Num,
    /// String literal (plain, raw, or byte); `text` is the interior.
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`); `text` excludes the quote.
    Lifetime,
    /// Any other single character (`{`, `.`, `[`, `#`, ...).
    Punct,
}

/// One token with the 1-based source line it starts on and the
/// half-open char-index range of its full lexeme.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub span: (usize, usize),
}

impl Token {
    /// True when the token is exactly the punct/ident `s`.
    pub fn is(&self, s: &str) -> bool {
        self.text == s
    }
}

/// One comment with the 1-based line it starts on; `text` is the
/// interior (after `//`, or between `/*` and `*/`). `span` covers the
/// delimiters too.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
    pub span: (usize, usize),
}

/// Tokenize `src`. Infallible by construction: unterminated constructs
/// run to end-of-file rather than erroring, which is the right behavior
/// for a linter that must never panic on the tree it audits.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let count_lines = |s: &[char]| s.iter().filter(|&&c| c == '\n').count() as u32;

    'outer: while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();

        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // Line comment (also doc comments `///`, `//!`).
        if c == '/' && next == Some('/') {
            let start = i + 2;
            let mut j = start;
            while j < chars.len() && chars[j] != '\n' {
                j += 1;
            }
            comments.push(Comment {
                line,
                text: chars[start..j].iter().collect(),
                span: (i, j),
            });
            i = j;
            continue;
        }

        // Block comment, nested.
        if c == '/' && next == Some('*') {
            let start_line = line;
            let start = i + 2;
            let mut depth = 1usize;
            let mut j = start;
            while j < chars.len() && depth > 0 {
                if chars[j] == '/' && chars.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && chars.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let end = j.saturating_sub(2).max(start);
            line += count_lines(&chars[i..j.min(chars.len())]);
            comments.push(Comment {
                line: start_line,
                text: chars[start..end.min(chars.len())].iter().collect(),
                span: (i, j.min(chars.len())),
            });
            i = j;
            continue;
        }

        // Raw / byte prefixes: r"...", r#"..."#, b"...", br#"..."#,
        // b'x', and raw identifiers r#ident.
        if (c == 'r' || c == 'b') && matches!(next, Some('"') | Some('#') | Some('\'')) {
            let mut j = i + 1;
            let mut raw = c == 'r';
            if c == 'b' && chars.get(j) == Some(&'r') {
                raw = true;
                j += 1;
            }
            if c == 'b' && chars.get(j) == Some(&'\'') {
                // Byte char literal b'x'.
                let (tok, adv, nl) = lex_char(&chars, j, line);
                toks.push(Token { kind: tok.0, text: tok.1, line, span: (i, j + adv) });
                line += nl;
                i = j + adv;
                continue;
            }
            if raw {
                let hash_start = j;
                let mut hashes = 0usize;
                while chars.get(j) == Some(&'#') {
                    hashes += 1;
                    j += 1;
                }
                // Raw identifier: `r#ident` — exactly one hash followed
                // by an identifier start, no quote. Emit a single Ident
                // token with the prefix kept verbatim, so `r#fn` never
                // reads as the keyword `fn` (and never as a raw-string
                // opening that would swallow the rest of the file).
                if c == 'r'
                    && hashes == 1
                    && chars.get(j).is_some_and(|&n| n == '_' || n.is_alphabetic())
                {
                    let mut k = j;
                    while k < chars.len() && (chars[k] == '_' || chars[k].is_alphanumeric()) {
                        k += 1;
                    }
                    toks.push(Token {
                        kind: TokKind::Ident,
                        text: chars[i..k].iter().collect(),
                        line,
                        span: (i, k),
                    });
                    i = k;
                    continue;
                }
                if chars.get(j) == Some(&'"') {
                    let start_line = line;
                    let body_start = j + 1;
                    let mut k = body_start;
                    while k < chars.len() {
                        if chars[k] == '"' {
                            let mut h = 0usize;
                            while chars.get(k + 1 + h) == Some(&'#') {
                                h += 1;
                            }
                            if h >= hashes {
                                line += count_lines(&chars[i..k]);
                                toks.push(Token {
                                    kind: TokKind::Str,
                                    text: chars[body_start..k].iter().collect(),
                                    line: start_line,
                                    span: (i, k + 1 + hashes),
                                });
                                i = k + 1 + hashes;
                                continue 'outer;
                            }
                        }
                        k += 1;
                    }
                    // Unterminated: consume to EOF.
                    toks.push(Token {
                        kind: TokKind::Str,
                        text: chars[body_start..].iter().collect(),
                        line: start_line,
                        span: (i, chars.len()),
                    });
                    i = chars.len();
                    continue;
                }
                // `r#` / `br#` followed by neither ident nor quote:
                // rewind past the hashes and fall through so the ident
                // branch below lexes the `r`/`br` alone.
                j = hash_start;
            }
            // `b"..."`: fall through to the string case below from j.
            if chars.get(j) == Some(&'"') {
                let start_line = line;
                let (text, adv, nl) = lex_quoted(&chars, j);
                line += nl;
                toks.push(Token { kind: TokKind::Str, text, line: start_line, span: (i, j + adv) });
                i = j + adv;
                continue;
            }
        }

        // Identifier / keyword.
        if c == '_' || c.is_alphabetic() {
            let start = i;
            let mut j = i;
            while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Ident,
                text: chars[start..j].iter().collect(),
                line,
                span: (start, j),
            });
            i = j;
            continue;
        }

        // Number. Consume digits + ident-continue (hex, suffixes), plus
        // one `.fraction` only when a digit follows the dot — so range
        // expressions like `0..n` stay three tokens.
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
                j += 1;
            }
            if chars.get(j) == Some(&'.') && chars.get(j + 1).is_some_and(|d| d.is_ascii_digit()) {
                j += 1;
                while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
                    j += 1;
                }
            }
            toks.push(Token {
                kind: TokKind::Num,
                text: chars[start..j].iter().collect(),
                line,
                span: (start, j),
            });
            i = j;
            continue;
        }

        // String literal.
        if c == '"' {
            let start_line = line;
            let (text, adv, nl) = lex_quoted(&chars, i);
            line += nl;
            toks.push(Token { kind: TokKind::Str, text, line: start_line, span: (i, i + adv) });
            i += adv;
            continue;
        }

        // Char literal vs lifetime.
        if c == '\'' {
            let (tok, adv, nl) = lex_char(&chars, i, line);
            toks.push(Token { kind: tok.0, text: tok.1, line, span: (i, i + adv) });
            line += nl;
            i += adv;
            continue;
        }

        toks.push(Token { kind: TokKind::Punct, text: c.to_string(), line, span: (i, i + 1) });
        i += 1;
    }

    (toks, comments)
}

/// Lex a `"..."` string starting at `chars[at] == '"'`. Returns the
/// interior text, chars consumed, and newlines crossed.
fn lex_quoted(chars: &[char], at: usize) -> (String, usize, u32) {
    let mut j = at + 1;
    let mut nl = 0u32;
    let mut text = String::new();
    while j < chars.len() {
        match chars[j] {
            '\\' => {
                // Keep escapes verbatim; rules only compare literals
                // that contain none.
                if let Some(&e) = chars.get(j + 1) {
                    text.push('\\');
                    text.push(e);
                    if e == '\n' {
                        nl += 1;
                    }
                }
                j += 2;
            }
            '"' => return (text, j + 1 - at, nl),
            ch => {
                if ch == '\n' {
                    nl += 1;
                }
                text.push(ch);
                j += 1;
            }
        }
    }
    (text, chars.len() - at, nl)
}

/// Lex from a `'` at `chars[at]`: either a char literal or a lifetime.
/// Returns ((kind, text), chars consumed, newlines crossed).
fn lex_char(chars: &[char], at: usize, _line: u32) -> ((TokKind, String), usize, u32) {
    let next = chars.get(at + 1).copied();
    // Lifetime: `'ident` not closed by a quote right after.
    if let Some(n) = next {
        if (n == '_' || n.is_alphabetic()) && chars.get(at + 2) != Some(&'\'') {
            let mut j = at + 1;
            while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
                j += 1;
            }
            return ((TokKind::Lifetime, chars[at + 1..j].iter().collect()), j - at, 0);
        }
    }
    // Char literal: consume to the closing quote, honoring escapes.
    let mut j = at + 1;
    let mut nl = 0u32;
    let mut text = String::new();
    while j < chars.len() {
        match chars[j] {
            '\\' => {
                if let Some(&e) = chars.get(j + 1) {
                    text.push('\\');
                    text.push(e);
                }
                j += 2;
            }
            '\'' => return ((TokKind::Char, text), j + 1 - at, nl),
            ch => {
                if ch == '\n' {
                    nl += 1;
                }
                text.push(ch);
                j += 1;
            }
        }
    }
    ((TokKind::Char, text), chars.len() - at, nl)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).0.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn raw_identifier_is_one_token() {
        // Regression: `r#fn` used to lex as Ident("r") + Punct("#") +
        // Ident("fn"), injecting a phantom `fn` keyword into the
        // scanner's view of the file.
        let toks = lex("let r#fn = 1;").0;
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, vec!["let", "r#fn"]);
        assert!(!toks.iter().any(|t| t.is("fn")), "phantom fn keyword: {toks:?}");
    }

    #[test]
    fn raw_identifier_keywords() {
        for kw in ["fn", "match", "type", "impl", "struct"] {
            let src = format!("let r#{kw} = 0;");
            let toks = lex(&src).0;
            assert!(
                toks.iter().any(|t| t.kind == TokKind::Ident && t.text == format!("r#{kw}")),
                "r#{kw} not lexed as one ident: {toks:?}"
            );
            assert!(!toks.iter().any(|t| t.is(kw)), "bare {kw} leaked: {toks:?}");
        }
    }

    #[test]
    fn raw_strings_still_lex() {
        assert_eq!(texts(r##"r#"body"#"##), vec!["body"]);
        assert_eq!(texts(r#"r"plain""#), vec!["plain"]);
        assert_eq!(texts(r##"br#"bytes"#"##), vec!["bytes"]);
        assert_eq!(texts(r#"b"bytes""#), vec!["bytes"]);
    }

    #[test]
    fn raw_ident_does_not_swallow_following_fn() {
        let src = "let a = r#type;\nfn real() {}\n";
        let (toks, _) = lex(src);
        let fns: Vec<u32> =
            toks.iter().filter(|t| t.kind == TokKind::Ident && t.is("fn")).map(|t| t.line).collect();
        assert_eq!(fns, vec![2], "exactly one real fn expected: {toks:?}");
    }

    #[test]
    fn spans_tile_the_input() {
        let src = "fn f(x: u32) -> u32 { // add\n    x + r#match + 0x2_u32\n}\n";
        let chars: Vec<char> = src.chars().collect();
        let (toks, comments) = lex(src);
        let mut spans: Vec<(usize, usize)> = toks.iter().map(|t| t.span).collect();
        spans.extend(comments.iter().map(|c| c.span));
        spans.sort();
        let mut prev = 0usize;
        for (lo, hi) in spans {
            assert!(lo >= prev, "overlapping spans at {lo}");
            assert!(lo < hi && hi <= chars.len(), "bad span ({lo},{hi})");
            assert!(
                chars[prev..lo].iter().all(|c| c.is_whitespace()),
                "non-whitespace gap before {lo}"
            );
            prev = hi;
        }
        assert!(chars[prev..].iter().all(|c| c.is_whitespace()));
    }

    #[test]
    fn string_span_includes_quotes() {
        let (toks, _) = lex(r#"x = "ab";"#);
        let s = toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, "ab");
        assert_eq!(s.span, (4, 8)); // `"ab"` at char indices 4..8
    }
}
