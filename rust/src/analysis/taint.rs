//! L7 — taint tracking for wire bytes (`taint`).
//!
//! Bytes that arrive from a socket or a WAL file are attacker-shaped
//! until proven otherwise: a decoded length prefix must be bounds-
//! checked before it sizes an allocation, a payload must be CRC-
//! verified before it is trusted. This rule tracks such values through
//! each function in the wire-facing files (`api/proto.rs`,
//! `storage/wal.rs`, `hub/transport.rs`) over the per-function CFG and
//! flags any tainted value that reaches an allocation/indexing sink
//! without passing a registered validator first.
//!
//! **Sources** (introduce taint):
//! - the buffer argument of `.read(..)` / `.read_exact(..)` /
//!   `.read_to_end(..)` — raw bytes off a socket or file;
//! - bindings produced by the frame decoders `le_u32_at`,
//!   `split_payload`, `from_le_bytes`, and whole-file reads
//!   (`fs::read`, `read_to_string`) — decoded integers are exactly the
//!   length/revision prefixes the WAL format warns about.
//!
//! **Validators** (kill taint):
//! - a comparison (`<`, `>`, `<=`, `>=`, `==`, `!=`) *adjacent* to the
//!   tainted name — adjacency keeps `=>`, `->` and generic argument
//!   lists from laundering anything;
//! - `.contains(..)` on a bounds range, `.min(..)` / `.clamp(..)`;
//! - a CRC check (`crc32(..)` in the statement);
//! - `ensure!` / `assert!`-family statements mentioning the name;
//! - [`crate::storage::wal::scan`] — it CRC-verifies every frame it
//!   accepts, so both its inputs and its outputs are trusted.
//!
//! **Sinks** (findings when reached tainted): `with_capacity(n)`,
//! `vec![_; n]`, `.take(n)`, `.set_len(n)`, and slice indexing
//! `buf[..n]`.
//!
//! Known limitation, on purpose: match-arm bindings are fresh
//! (untainted) — the scrutinee-to-binding link is not modeled. Wire
//! decoding in this tree binds through `let`-with-`match` statements,
//! which *are* tracked; modeling arm patterns would double the engine
//! for no additional real coverage.

use std::collections::BTreeSet;

use super::cfg::{Cfg, Stmt, StmtKind};
use super::dataflow;
use super::lexer::{TokKind, Token};
use super::scanner::{FnSpan, SourceFile};
use super::Finding;

/// Files whose functions are taint-checked (suffix match on `rel`).
const SCOPE: &[&str] = &["api/proto.rs", "storage/wal.rs", "hub/transport.rs"];

/// One tracked source-to-outcome flow, reported as machine-readable
/// evidence in the JSON lint report (and asserted non-empty by the
/// self-check test).
#[derive(Debug, Clone)]
pub struct TaintFlow {
    pub file: String,
    pub function: String,
    /// The tainted variable name.
    pub var: String,
    /// What made it tainted (`read_exact buffer`, `le_u32_at`, ...).
    pub source: String,
    pub source_line: u32,
    /// First validation that killed the taint, if any.
    pub validated_line: Option<u32>,
    /// First sink it reached while still tainted, if any.
    pub sink_line: Option<u32>,
    /// `"validated"`, `"dormant"` (never validated, never sunk), or
    /// `"flagged"` (reached a sink tainted — there is a finding).
    pub status: &'static str,
}

/// Run L7. Returns raw findings (marker filtering is the caller's job)
/// plus the flow evidence for every source observed.
pub fn check(files: &[SourceFile]) -> (Vec<Finding>, Vec<TaintFlow>) {
    let mut findings = Vec::new();
    let mut flows = Vec::new();
    for sf in files {
        if !SCOPE.iter().any(|s| sf.rel.ends_with(s)) {
            continue;
        }
        for span in &sf.fns {
            if span.is_test {
                continue;
            }
            check_fn(sf, span, &mut findings, &mut flows);
        }
    }
    (findings, flows)
}

fn check_fn(
    sf: &SourceFile,
    span: &FnSpan,
    findings: &mut Vec<Finding>,
    flows: &mut Vec<TaintFlow>,
) {
    // Nested fns are separate functions; the CFG builder skips their
    // token ranges structurally, so no extra masking is needed here.
    let cfg = Cfg::build(&sf.tokens, span.body_start + 1, span.body_end);
    let toks = &sf.tokens;

    // Fixpoint: the set of tainted names at each block entry.
    let entries = dataflow::forward(&cfg, |b, inp| {
        let mut st = inp.clone();
        for stmt in &cfg.blocks[b].stmts {
            transfer(toks, stmt, &mut st, None);
        }
        st
    });

    // Evidence pass: one deterministic walk per block with the final
    // entry states, recording sources, validations, and sink hits.
    let mut ev = Events::default();
    for (b, block) in cfg.blocks.iter().enumerate() {
        let mut st = entries[b].clone();
        for stmt in &block.stmts {
            transfer(toks, stmt, &mut st, Some(&mut ev));
        }
    }

    for (var, source, line) in ev.sources {
        let validated_line = ev
            .validated
            .iter()
            .filter(|(v, l)| *v == var && *l >= line)
            .map(|(_, l)| *l)
            .min();
        let sink_line = ev
            .sinks
            .iter()
            .filter(|(v, _, l)| *v == var && *l >= line)
            .map(|(_, _, l)| *l)
            .min();
        let status = if sink_line.is_some() {
            "flagged"
        } else if validated_line.is_some() {
            "validated"
        } else {
            "dormant"
        };
        flows.push(TaintFlow {
            file: sf.rel.clone(),
            function: span.name.clone(),
            var,
            source,
            source_line: line,
            validated_line,
            sink_line,
            status,
        });
    }
    for (var, sink, line) in ev.sinks {
        findings.push(Finding {
            file: sf.rel.clone(),
            line,
            rule: "taint",
            message: format!(
                "unvalidated wire value `{var}` reaches `{sink}` in `{}` — bound it \
                 (length cap / CRC / range check) before it sizes memory",
                span.name
            ),
        });
    }
}

/// Evidence captured during the reporting walk.
#[derive(Default)]
struct Events {
    /// (var, source description, line)
    sources: Vec<(String, String, u32)>,
    /// (var, line)
    validated: Vec<(String, u32)>,
    /// (var, sink name, line)
    sinks: Vec<(String, &'static str, u32)>,
}

/// Apply one statement to the taint state, optionally recording
/// evidence. Order: validation kills, then sink checks against the
/// surviving taint, then re-bindings and new sources.
fn transfer(toks: &[Token], stmt: &Stmt, st: &mut BTreeSet<String>, mut ev: Option<&mut Events>) {
    let (lo, hi) = (stmt.lo, stmt.hi.min(toks.len()));
    if lo >= hi {
        return;
    }
    let t = &toks[lo..hi];
    let line = stmt.line;

    // `scan(..)` launders everything it touches: kill mentioned taint
    // and bind its results clean.
    if calls_bare(t, "scan") {
        kill_mentioned(t, st, line, ev.as_deref_mut());
        for d in dataflow::defs(toks, stmt) {
            st.remove(&d);
        }
        return;
    }

    // Validators.
    if has_whole_stmt_validator(t) {
        kill_mentioned(t, st, line, ev.as_deref_mut());
    } else {
        for v in comparison_adjacent_vars(t) {
            if st.remove(&v) {
                if let Some(e) = ev.as_deref_mut() {
                    e.validated.push((v, line));
                }
            }
        }
    }

    // Sinks, against the post-validation state.
    if let Some(e) = ev.as_deref_mut() {
        for (var, sink) in sink_hits(t, st) {
            e.sinks.push((var, sink, line));
        }
    }

    // Sources and propagation.
    let defs = dataflow::defs(toks, stmt);
    let mut gen: Vec<String> = Vec::new();
    for (var, desc) in read_buffer_sources(t) {
        if let Some(e) = ev.as_deref_mut() {
            e.sources.push((var.clone(), desc.to_string(), line));
        }
        gen.push(var);
    }
    if let Some(decoder) = decoder_call(t) {
        for d in &defs {
            if let Some(e) = ev.as_deref_mut() {
                e.sources.push((d.clone(), decoder.to_string(), line));
            }
            gen.push(d.clone());
        }
    } else if stmt.kind != StmtKind::Pattern
        && dataflow::uses(toks, stmt).iter().any(|u| st.contains(u))
    {
        // Tainted right-hand side: the bindings inherit the taint.
        gen.extend(defs.iter().cloned());
    }
    // Re-binding kills stale taint; pattern bindings are fresh.
    for d in &defs {
        st.remove(d);
    }
    for v in gen {
        st.insert(v);
    }
}

/// Does the statement call the bare function `name(` (no `.`/`::` path
/// prefix required — `scan(&bytes)` either way)?
fn calls_bare(t: &[Token], name: &str) -> bool {
    t.iter().enumerate().any(|(i, tok)| {
        tok.kind == TokKind::Ident
            && tok.is(name)
            && t.get(i + 1).is_some_and(|n| n.is("("))
    })
}

/// Whole-statement validators: any mention of a tainted var in the same
/// statement counts as validated.
fn has_whole_stmt_validator(t: &[Token]) -> bool {
    for (i, tok) in t.iter().enumerate() {
        if tok.kind != TokKind::Ident {
            continue;
        }
        let called = t.get(i + 1).is_some_and(|n| n.is("("));
        match tok.text.as_str() {
            "contains" | "min" | "clamp" if called && i > 0 && t[i - 1].is(".") => return true,
            "crc32" if called => return true,
            "ensure" | "assert" | "assert_eq" | "assert_ne" | "debug_assert" => return true,
            _ => {}
        }
    }
    false
}

/// Variable names adjacent (within two tokens) to a real comparison
/// operator. `=>`, `->`, `..=` and generic brackets are excluded by the
/// operator tests, and adjacency keeps a type annotation's `<`/`>` from
/// validating names elsewhere in the statement.
fn comparison_adjacent_vars(t: &[Token]) -> Vec<String> {
    let mut out = Vec::new();
    for (i, tok) in t.iter().enumerate() {
        if tok.kind != TokKind::Punct {
            continue;
        }
        let type_ish = |j: usize| {
            t.get(j).is_some_and(|x| {
                x.kind == TokKind::Ident
                    && (x.text.chars().next().is_some_and(char::is_uppercase)
                        || matches!(
                            x.text.as_str(),
                            "u8" | "u16" | "u32" | "u64" | "usize" | "i8" | "i16" | "i32"
                                | "i64" | "isize" | "f32" | "f64" | "bool" | "str"
                        ))
            })
        };
        let is_cmp = match tok.text.as_str() {
            // `::<` turbofish and `Vec<...>` generic openers are not
            // comparisons; neither is the `>` closing a generic list
            // (recognized by the type-like ident right before it).
            "<" => !(i > 0 && (t[i - 1].is(":") || type_ish(i - 1))),
            ">" => !(i > 0 && (t[i - 1].is("=") || t[i - 1].is("-") || type_ish(i - 1))),
            "=" => {
                // `==` (either half) or `!=`; plain assignment `=` is not
                // a comparison, `..=` is a range.
                let prev_eq = i > 0 && (t[i - 1].is("=") || t[i - 1].is("!"));
                let next_eq = t.get(i + 1).is_some_and(|n| n.is("="));
                prev_eq || next_eq
            }
            _ => false,
        };
        if !is_cmp {
            continue;
        }
        for j in i.saturating_sub(2)..=(i + 2).min(t.len().saturating_sub(1)) {
            let n = &t[j];
            if n.kind == TokKind::Ident
                && n.text.chars().next().is_some_and(|c| c == '_' || c.is_lowercase())
            {
                out.push(n.text.clone());
            }
        }
    }
    out.sort();
    out.dedup();
    out
}

/// Remove every tainted name mentioned in the statement, recording the
/// validations.
fn kill_mentioned(t: &[Token], st: &mut BTreeSet<String>, line: u32, ev: Option<&mut Events>) {
    let mentioned: Vec<String> = t
        .iter()
        .filter(|tok| tok.kind == TokKind::Ident && st.contains(&tok.text))
        .map(|tok| tok.text.clone())
        .collect();
    if let Some(e) = ev {
        for v in &mentioned {
            if !e.validated.iter().any(|(w, l)| w == v && *l == line) {
                e.validated.push((v.clone(), line));
            }
        }
    }
    for v in mentioned {
        st.remove(&v);
    }
}

/// `recv.read(..)`-family calls: returns the buffer variables tainted by
/// each (the lowercase idents inside the call's argument list).
fn read_buffer_sources(t: &[Token]) -> Vec<(String, &'static str)> {
    let mut out = Vec::new();
    for (i, tok) in t.iter().enumerate() {
        if tok.kind != TokKind::Ident {
            continue;
        }
        let desc = match tok.text.as_str() {
            "read" => "read buffer",
            "read_exact" => "read_exact buffer",
            "read_to_end" => "read_to_end buffer",
            _ => continue,
        };
        if !(i > 0 && t[i - 1].is(".")) || !t.get(i + 1).is_some_and(|n| n.is("(")) {
            continue;
        }
        // Arguments: idents inside the balanced parens.
        let mut depth = 0usize;
        let mut j = i + 1;
        while j < t.len() {
            if t[j].is("(") {
                depth += 1;
            } else if t[j].is(")") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if t[j].kind == TokKind::Ident
                && !t[j].is("mut")
                && !t[j].is("self")
                && t[j].text.chars().next().is_some_and(|c| c == '_' || c.is_lowercase())
            {
                out.push((t[j].text.clone(), desc));
            }
            j += 1;
        }
    }
    out
}

/// Does the statement call a registered wire decoder? Its bindings are
/// tainted. (`fs::read` / `read_to_string` load whole files the WAL
/// scan has not yet vetted.)
fn decoder_call(t: &[Token]) -> Option<&'static str> {
    for (i, tok) in t.iter().enumerate() {
        if tok.kind != TokKind::Ident || !t.get(i + 1).is_some_and(|n| n.is("(")) {
            continue;
        }
        match tok.text.as_str() {
            "le_u32_at" => return Some("le_u32_at"),
            "split_payload" => return Some("split_payload"),
            "from_le_bytes" | "from_be_bytes" | "from_ne_bytes" => return Some("from_le_bytes"),
            "read_to_string" => return Some("read_to_string"),
            "read" if i >= 2 && t[i - 1].is(":") && t[i - 2].is(":") => return Some("fs::read"),
            _ => {}
        }
    }
    None
}

/// Sink hits: (tainted var, sink name) for every sink shape whose size
/// argument mentions a currently-tainted name.
fn sink_hits(t: &[Token], st: &BTreeSet<String>) -> Vec<(String, &'static str)> {
    let mut out = Vec::new();
    let tainted_in = |lo: usize, hi: usize, out: &mut Vec<(String, &'static str)>, sink| {
        for tok in &t[lo.min(t.len())..hi.min(t.len())] {
            if tok.kind == TokKind::Ident && st.contains(&tok.text) {
                out.push((tok.text.clone(), sink));
            }
        }
    };
    let balanced_end = |open: usize| {
        let (o, c) = match t.get(open).map(|x| x.text.as_str()) {
            Some("(") => ("(", ")"),
            Some("[") => ("[", "]"),
            _ => return open + 1,
        };
        let mut depth = 0usize;
        let mut j = open;
        while j < t.len() {
            if t[j].is(o) {
                depth += 1;
            } else if t[j].is(c) {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            j += 1;
        }
        t.len()
    };
    for (i, tok) in t.iter().enumerate() {
        // with_capacity(n) / take(n) / set_len(n)
        if tok.kind == TokKind::Ident && t.get(i + 1).is_some_and(|n| n.is("(")) {
            let sink = match tok.text.as_str() {
                "with_capacity" => Some("with_capacity"),
                "take" if i > 0 && t[i - 1].is(".") => Some("take"),
                "set_len" if i > 0 && t[i - 1].is(".") => Some("set_len"),
                _ => None,
            };
            if let Some(sink) = sink {
                tainted_in(i + 2, balanced_end(i + 1), &mut out, sink);
            }
        }
        // vec![elem; n]
        if tok.kind == TokKind::Ident
            && tok.is("vec")
            && t.get(i + 1).is_some_and(|n| n.is("!"))
            && t.get(i + 2).is_some_and(|n| n.is("["))
        {
            let end = balanced_end(i + 2);
            // Only the length expression (after the `;`) sizes memory.
            if let Some(semi) = (i + 3..end).find(|&k| t[k].is(";")) {
                tainted_in(semi + 1, end, &mut out, "vec![_; n]");
            }
        }
        // Slice indexing: `expr[ .. ]` — `[` directly after an ident,
        // `)`, or `]` (not an array literal or vec! body).
        if tok.is("[")
            && i > 0
            && (t[i - 1].kind == TokKind::Ident || t[i - 1].is(")") || t[i - 1].is("]"))
            && !(i > 1 && t[i - 2].is("!"))
        {
            tainted_in(i + 1, balanced_end(i), &mut out, "slice index");
        }
    }
    out.sort();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::SourceFile;
    use std::path::PathBuf;

    fn run(src: &str) -> (Vec<Finding>, Vec<TaintFlow>) {
        let sf =
            SourceFile::parse(PathBuf::from("x/storage/wal.rs"), "storage/wal.rs".into(), src);
        check(std::slice::from_ref(&sf))
    }

    #[test]
    fn unvalidated_length_reaches_vec_macro() {
        let (f, flows) = run(
            "fn bad(r: &mut R) -> V {\n\
             let mut head = [0u8; 8];\n\
             r.read_exact(&mut head).unwrap();\n\
             let n = le_u32_at(&head, 0).unwrap() as usize;\n\
             let buf = vec![0u8; n];\n\
             buf\n}\n",
        );
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains('n'), "{f:?}");
        assert!(flows.iter().any(|fl| fl.var == "n" && fl.status == "flagged"), "{flows:?}");
    }

    #[test]
    fn bounds_check_validates_the_length() {
        let (f, flows) = run(
            "fn good(r: &mut R) -> V {\n\
             let mut head = [0u8; 8];\n\
             r.read_exact(&mut head).unwrap();\n\
             let n = le_u32_at(&head, 0).unwrap() as usize;\n\
             if n > MAX_RECORD_BYTES { return V::new(); }\n\
             let buf = vec![0u8; n];\n\
             buf\n}\n",
        );
        assert!(f.is_empty(), "{f:?}");
        assert!(flows.iter().any(|fl| fl.var == "n" && fl.status == "validated"), "{flows:?}");
    }

    #[test]
    fn scan_launders_file_bytes() {
        let (f, flows) = run(
            "fn open_log(p: &P) {\n\
             let bytes = fs::read(p).unwrap();\n\
             let result = scan(&bytes);\n\
             file.set_len(result.valid_len).unwrap();\n}\n",
        );
        assert!(f.is_empty(), "{f:?}");
        assert!(flows.iter().any(|fl| fl.var == "bytes" && fl.status == "validated"), "{flows:?}");
    }

    #[test]
    fn tainted_index_is_a_sink() {
        let (f, _) = run(
            "fn bad(buf: &[u8], r: &mut R) -> u8 {\n\
             let mut head = [0u8; 4];\n\
             r.read_exact(&mut head).unwrap();\n\
             let off = le_u32_at(&head, 0).unwrap() as usize;\n\
             buf[off]\n}\n",
        );
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("off"), "{f:?}");
    }

    #[test]
    fn out_of_scope_files_are_ignored() {
        let sf = SourceFile::parse(
            PathBuf::from("x/models/fit.rs"),
            "models/fit.rs".into(),
            "fn f(r: &mut R) { let mut b = [0u8; 4]; r.read_exact(&mut b).unwrap(); \
             let n = le_u32_at(&b, 0).unwrap(); let v = vec![0u8; n]; drop(v); }",
        );
        let (f, flows) = check(std::slice::from_ref(&sf));
        assert!(f.is_empty() && flows.is_empty());
    }
}
