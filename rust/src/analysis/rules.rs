//! L2, L3, L5, L6, L9: panic-freedom, unsafe audit, protocol
//! exhaustiveness, logging discipline, allocation-free hot paths.
//! (L1 lock-order lives in [`super::lock_order`]; L4/L8 durability
//! ordering in [`super::ordering`]; L7 taint in [`super::taint`].)

use std::collections::BTreeSet;

use super::lexer::{TokKind, Token};
use super::scanner::SourceFile;
use super::Finding;

/// Modules where a panic kills a reactor or worker mid-frame: the L2
/// deny-list. Matched as `/`-separated rel-path suffixes.
const HOT_PATH: &[&str] = &[
    "api/proto.rs",
    "hub/transport.rs",
    "hub/server.rs",
    "storage/wal.rs",
];

fn is_hot(rel: &str) -> bool {
    HOT_PATH.iter().any(|h| rel == *h || rel.ends_with(&format!("/{h}")))
}

/// L2 — panic-freedom on hot paths: no `.unwrap()` / `.expect(` /
/// `panic!`-family macros / fallible slice indexing outside tests.
/// Deliberate sites carry `// lint: allow(panics, reason = "...")`.
pub fn panic_freedom(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !is_hot(&sf.rel) {
        return out;
    }
    let t = &sf.tokens;
    for i in 0..t.len() {
        if sf.in_test(i) {
            continue;
        }
        let tok = &t[i];
        if tok.kind == TokKind::Ident
            && matches!(tok.text.as_str(), "unwrap" | "expect")
            && i > 0
            && t[i - 1].is(".")
            && t.get(i + 1).is_some_and(|x| x.is("("))
        {
            out.push(Finding {
                file: sf.rel.clone(),
                line: tok.line,
                rule: "panics",
                message: format!(
                    "`.{}(` on a hot path — return a structured error or annotate \
                     with `// lint: allow(panics, reason = \"...\")`",
                    tok.text
                ),
            });
            continue;
        }
        if tok.kind == TokKind::Ident
            && matches!(
                tok.text.as_str(),
                "panic" | "unreachable" | "todo" | "unimplemented"
            )
            && t.get(i + 1).is_some_and(|x| x.is("!"))
        {
            out.push(Finding {
                file: sf.rel.clone(),
                line: tok.line,
                rule: "panics",
                message: format!("`{}!` on a hot path", tok.text),
            });
            continue;
        }
        // Fallible slice/array indexing: `expr[...]` where expr ends in
        // an ident, `)` or `]`. The infallible full-range form `[..]`
        // is exempt; macro (`vec![`) and attribute (`#[`) brackets are
        // naturally excluded because their previous token is `!` / `#`.
        if tok.is("[") && i > 0 {
            let prev = &t[i - 1];
            let indexes = prev.kind == TokKind::Ident || prev.is(")") || prev.is("]");
            let full_range = t.get(i + 1).is_some_and(|x| x.is("."))
                && t.get(i + 2).is_some_and(|x| x.is("."))
                && t.get(i + 3).is_some_and(|x| x.is("]"));
            if indexes && !full_range {
                out.push(Finding {
                    file: sf.rel.clone(),
                    line: tok.line,
                    rule: "panics",
                    message: "direct slice indexing on a hot path — use `.get(..)` \
                              or annotate with `// lint: allow(panics, ...)`"
                        .to_string(),
                });
            }
        }
    }
    out
}

/// L3 — unsafe audit: every `unsafe` token must be covered by a
/// `// SAFETY:` comment on the same line or the contiguous comment /
/// attribute block immediately above it.
pub fn unsafe_audit(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut seen_lines = BTreeSet::new();
    for (i, tok) in sf.tokens.iter().enumerate() {
        if tok.kind != TokKind::Ident || !tok.is("unsafe") || sf.in_test(i) {
            continue;
        }
        if !seen_lines.insert(tok.line) {
            continue;
        }
        if !has_safety_comment(sf, tok.line) {
            out.push(Finding {
                file: sf.rel.clone(),
                line: tok.line,
                rule: "safety",
                message: "`unsafe` without an immediately preceding `// SAFETY:` \
                          comment justifying its preconditions"
                    .to_string(),
            });
        }
    }
    out
}

fn has_safety_comment(sf: &SourceFile, line: u32) -> bool {
    if sf.line(line).contains("SAFETY:") {
        return true;
    }
    let mut k = line.saturating_sub(1);
    while k >= 1 {
        let s = sf.line(k).trim();
        if s.is_empty() || s.starts_with('#') {
            // Blank spacing or attributes between the comment and the
            // item are tolerated.
        } else if s.starts_with("//") {
            if s.contains("SAFETY:") {
                return true;
            }
        } else {
            return false;
        }
        k -= 1;
    }
    false
}

// L4 (durability) moved to `super::ordering` — the rename/sync_dir pairing
// is now one instance of the CFG-driven durability-ordering automaton, which
// checks reachability instead of same-function co-occurrence.

/// L9 — allocation-free hot paths: the reactor dispatch loop
/// (`hub/server.rs`) and the per-row predict paths (`api/service.rs`)
/// must not allocate per call. Banned shapes: `Vec::new(`,
/// `Box::new(`, `.to_vec(`, `.clone(`, `format!`. Registered hot
/// functions only — cold paths (startup, shutdown, error formatting)
/// allocate freely. Deliberate sites carry
/// `// lint: allow(alloc_hot, reason = "...")`.
pub fn alloc_hot(sf: &SourceFile) -> Vec<Finding> {
    const HOT_FNS: &[(&str, &[&str])] = &[
        (
            "hub/server.rs",
            &[
                "run",
                "tick",
                "accept_ready",
                "conn_event",
                "handle_readable",
                "pump_frames",
                "drain_outbox",
                "flush_and_update",
                "update_interest",
                "sweep",
                "close_conn",
                "worker_loop",
                "complete_span",
            ],
        ),
        ("api/service.rs", &["predict_rows", "predict_batch"]),
    ];
    let mut out = Vec::new();
    let Some((_, hot)) = HOT_FNS
        .iter()
        .find(|(f, _)| sf.rel == *f || sf.rel.ends_with(&format!("/{f}")))
    else {
        return out;
    };
    let t = &sf.tokens;
    for span in &sf.fns {
        if span.is_test || !hot.contains(&span.name.as_str()) {
            continue;
        }
        let nested = super::dataflow::nested_fn_spans(sf, span);
        let mut i = span.body_start + 1;
        while i < span.body_end {
            if let Some(end) = nested.iter().find_map(|&(s, e)| (s == i).then_some(e)) {
                i = end + 1;
                continue;
            }
            if sf.in_test(i) {
                i += 1;
                continue;
            }
            if let Some(what) = banned_alloc_at(t, i) {
                out.push(Finding {
                    file: sf.rel.clone(),
                    line: t[i].line,
                    rule: "alloc_hot",
                    message: format!(
                        "`{what}` in hot-path fn `{}` — allocation per call; reuse a \
                         scratch buffer or annotate with \
                         `// lint: allow(alloc_hot, reason = \"...\")`",
                        span.name
                    ),
                });
            }
            i += 1;
        }
    }
    out
}

/// Match one of the banned allocation shapes starting at token `i`.
fn banned_alloc_at(t: &[Token], i: usize) -> Option<&'static str> {
    let tok = t.get(i)?;
    if tok.kind != TokKind::Ident {
        return None;
    }
    let path_new = |head: &str| {
        tok.is(head)
            && t.get(i + 1).is_some_and(|x| x.is(":"))
            && t.get(i + 2).is_some_and(|x| x.is(":"))
            && t.get(i + 3).is_some_and(|x| x.is("new"))
            && t.get(i + 4).is_some_and(|x| x.is("("))
    };
    if path_new("Vec") {
        return Some("Vec::new()");
    }
    if path_new("Box") {
        return Some("Box::new()");
    }
    let method = |name: &str| {
        tok.is(name)
            && i >= 1
            && t[i - 1].is(".")
            && t.get(i + 1).is_some_and(|x| x.is("("))
    };
    if method("to_vec") {
        return Some(".to_vec()");
    }
    if method("clone") {
        return Some(".clone()");
    }
    if tok.is("format") && t.get(i + 1).is_some_and(|x| x.is("!")) {
        return Some("format!");
    }
    None
}

/// L6 — logging discipline: library code reports diagnostics through
/// the structured logger ([`crate::obs::log`]), never bare `eprintln!`,
/// so every message respects `--log-level` and test capture. `main.rs`
/// is exempt (the CLI's terminal output is its interface), as are
/// tests; deliberate sites carry `// lint: allow(logging, reason =
/// "...")` — the logger's own stderr sink is the one such site.
pub fn logging(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if sf.rel == "main.rs" || sf.rel.ends_with("/main.rs") {
        return out;
    }
    let t = &sf.tokens;
    for i in 0..t.len() {
        if sf.in_test(i) {
            continue;
        }
        let tok = &t[i];
        if tok.kind == TokKind::Ident
            && tok.is("eprintln")
            && t.get(i + 1).is_some_and(|x| x.is("!"))
        {
            out.push(Finding {
                file: sf.rel.clone(),
                line: tok.line,
                rule: "logging",
                message: "bare `eprintln!` in library code — use \
                          `crate::obs::log::{error,warn,info,debug}` or annotate \
                          with `// lint: allow(logging, reason = \"...\")`"
                    .to_string(),
            });
        }
    }
    out
}

/// L5 — protocol exhaustiveness: every op-name string returned by
/// `Op::name()` in `api/proto.rs` must be matched in `Op::decode`,
/// dispatched in `api/service.rs`, and exercised by `HubClient`
/// (`hub/client.rs`) — an op added to one side cannot silently drift.
pub fn protocol(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    let find = |suffix: &str| {
        files
            .iter()
            .find(|f| f.rel == suffix || f.rel.ends_with(&format!("/{suffix}")))
    };
    let (Some(proto), Some(service), Some(client)) = (
        find("api/proto.rs"),
        find("api/service.rs"),
        find("hub/client.rs"),
    ) else {
        return out; // not linting the full tree: rule does not apply
    };

    // (variant, op string, line) triples from `fn name`.
    let mut ops: Vec<(String, String, u32)> = Vec::new();
    if let Some(span) = proto.fns.iter().find(|f| f.name == "name" && !f.is_test) {
        let t = &proto.tokens;
        let mut variant: Option<String> = None;
        for i in span.body_start..=span.body_end {
            let tok = &t[i];
            if tok.kind == TokKind::Ident
                && tok.is("Op")
                && t.get(i + 1).is_some_and(|x| x.is(":"))
                && t.get(i + 2).is_some_and(|x| x.is(":"))
            {
                if let Some(v) = t.get(i + 3).filter(|x| x.kind == TokKind::Ident) {
                    variant = Some(v.text.clone());
                }
            }
            if tok.kind == TokKind::Str {
                if let Some(v) = variant.take() {
                    ops.push((v, tok.text.clone(), tok.line));
                }
            }
        }
    }

    let decode_strs: BTreeSet<&str> = proto
        .fns
        .iter()
        .filter(|f| f.name == "decode" && !f.is_test)
        .flat_map(|span| {
            proto.tokens[span.body_start..=span.body_end]
                .iter()
                .filter(|t| t.kind == TokKind::Str)
                .map(|t| t.text.as_str())
        })
        .collect();

    let variants_in = |sf: &SourceFile, only_fn: Option<&str>| -> BTreeSet<String> {
        let ranges: Vec<(usize, usize)> = match only_fn {
            Some(name) => sf
                .fns
                .iter()
                .filter(|f| f.name == name && !f.is_test)
                .map(|f| (f.body_start, f.body_end))
                .collect(),
            None => vec![(0, sf.tokens.len().saturating_sub(1))],
        };
        let mut set = BTreeSet::new();
        for (s, e) in ranges {
            for i in s..=e.min(sf.tokens.len().saturating_sub(1)) {
                if sf.in_test(i) {
                    continue;
                }
                let t = &sf.tokens;
                if t[i].kind == TokKind::Ident
                    && t[i].is("Op")
                    && t.get(i + 1).is_some_and(|x| x.is(":"))
                    && t.get(i + 2).is_some_and(|x| x.is(":"))
                {
                    if let Some(v) = t.get(i + 3).filter(|x| x.kind == TokKind::Ident) {
                        set.insert(v.text.clone());
                    }
                }
            }
        }
        set
    };

    let has_dispatch = service.fns.iter().any(|f| f.name == "dispatch" && !f.is_test);
    let dispatched = variants_in(service, has_dispatch.then_some("dispatch"));
    let client_ops = variants_in(client, None);

    for (variant, op, line) in &ops {
        if !decode_strs.contains(op.as_str()) {
            out.push(Finding {
                file: proto.rel.clone(),
                line: *line,
                rule: "protocol",
                message: format!("op \"{op}\" is named but never matched in `Op::decode`"),
            });
        }
        if !dispatched.contains(variant) {
            out.push(Finding {
                file: proto.rel.clone(),
                line: *line,
                rule: "protocol",
                message: format!(
                    "`Op::{variant}` (\"{op}\") is not dispatched in `api/service.rs`"
                ),
            });
        }
        if !client_ops.contains(variant) {
            out.push(Finding {
                file: proto.rel.clone(),
                line: *line,
                rule: "protocol",
                message: format!(
                    "`Op::{variant}` (\"{op}\") is not exercised by `HubClient` \
                     (`hub/client.rs`)"
                ),
            });
        }
    }
    out
}
