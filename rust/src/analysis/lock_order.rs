//! L1 — lock-order analysis.
//!
//! Extracts `lock()/read()/write()` acquisition sites per function,
//! tracks which guards are *held* (a `let`-bound guard lives to the end
//! of its block or an explicit `drop(guard)`; a chained temporary lives
//! to the end of its statement), and checks every "acquired B while
//! holding A" edge against the project's total lock order. Any
//! inversion or cycle is a finding.
//!
//! Propagation is **full-depth interprocedural**: the project call
//! graph ([`dataflow::CallGraph`]) feeds a transitive-lock-set fixpoint
//! — each function's set is its direct acquisitions plus everything its
//! resolvable callees may acquire, to any depth. A call made while a
//! guard is held therefore contributes an edge for every lock anywhere
//! below it, with the sample call chain recorded on the edge (`via:
//! "append -> append_durable"`). PR 8's lint propagated a single
//! receiver-gated level; the chain annotation is what makes the deeper
//! reports actionable.
//!
//! The order is the one DESIGN.md §7–§11 prescribe in prose, now
//! codified (lower rank = acquired first):
//!
//! | rank | class             | site |
//! |------|-------------------|------|
//! | 10   | `submit_lock`     | per-job submit serialization (`hub/repo.rs`) |
//! | 12   | `fit_gates`       | fit-gate map (`api/service.rs`) |
//! | 15   | `fit_gate`        | one job's cold-fit gate |
//! | 20   | `repos`           | hub repository map (`hub/repo.rs`) |
//! | 30   | `storage`         | durable-store handle slot (`hub/repo.rs`) |
//! | 50   | `cache_stripe`    | 16-stripe fitted-model cache (`api/service.rs`) |
//! | 55   | `engine`          | fit-engine config slot |
//! | 56   | `follower_of`     | replication role slot |
//! | 57   | `coalesce_window` | predict-coalescing window knob |
//! | 60   | `coalesce_groups` | coalesce group map |
//! | 65   | `group_state`     | one coalesce group's state |
//! | 70   | `queue_jobs`      | reactor worker queue (`hub/server.rs`) |
//! | 75   | `outbox_replies`  | reactor reply outbox (`hub/server.rs`) |
//! | 80   | `snapshots`       | snapshot serialization (`storage/mod.rs`) |
//! | 85   | `coverage`        | contribution coverage map (`storage/mod.rs`) |
//! | 90   | `wal`             | per-repo WAL handle (`storage/mod.rs`) |
//!
//! Receivers not in the registry (io handles, bench scratch, fixture
//! code) are ignored — the rule audits the named hub/storage locks, not
//! every `RwLock` in existence.

use std::collections::{BTreeMap, BTreeSet};

use super::dataflow;
use super::lexer::TokKind;
use super::scanner::{FnSpan, SourceFile};
use super::Finding;

/// Classify a lock receiver name into (class, rank). `None` = not a
/// registered lock; the acquisition is ignored.
pub fn classify(receiver: &str) -> Option<(&'static str, u32)> {
    Some(match receiver {
        "lock" | "submit_lock" => ("submit_lock", 10),
        "fit_gates" => ("fit_gates", 12),
        "gate" => ("fit_gate", 15),
        "repos" => ("repos", 20),
        "storage" => ("storage", 30),
        "cache" | "stripe" => ("cache_stripe", 50),
        "engine" => ("engine", 55),
        "follower_of" => ("follower_of", 56),
        "coalesce_window" => ("coalesce_window", 57),
        "coalesce_groups" | "groups" => ("coalesce_groups", 60),
        "state" | "st" => ("group_state", 65),
        "jobs" => ("queue_jobs", 70),
        "replies" => ("outbox_replies", 75),
        "snapshots" | "latest" => ("snapshots", 80),
        "coverage" => ("coverage", 85),
        "wals" | "wal" => ("wal", 90),
        _ => return None,
    })
}

/// A currently-held guard during the interval walk.
#[derive(Debug, Clone)]
struct Hold {
    class: &'static str,
    rank: u32,
    binding: Option<String>,
    depth: usize,
}

/// An observed "acquired `to` while holding `from`" edge.
#[derive(Debug, Clone)]
pub struct Edge {
    pub from: &'static str,
    pub from_rank: u32,
    pub to: &'static str,
    pub to_rank: u32,
    pub file: String,
    pub line: u32,
    /// Set when the inner acquisition came from a called function: the
    /// call chain down to the acquiring fn (`"append -> append_durable"`).
    pub via: Option<String>,
}

/// Run L1 over all files. Returns raw findings (marker filtering is
/// the caller's job) at the line of each offending inner acquisition.
pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let observed = edges(files);
    let mut findings = Vec::new();
    let mut seen: BTreeSet<(String, u32, String)> = BTreeSet::new();
    for e in &observed {
        if e.from_rank < e.to_rank {
            continue;
        }
        let via = match &e.via {
            Some(c) => format!(" (via call to `{c}`)"),
            None => String::new(),
        };
        let msg = if e.from == e.to {
            format!(
                "re-entrant acquisition of `{}`{via} — self-deadlock risk",
                e.from
            )
        } else {
            format!(
                "lock-order inversion: `{}` (rank {}) acquired while \
                 holding `{}` (rank {}){via}; the project order requires \
                 `{}` before `{}`",
                e.to, e.to_rank, e.from, e.from_rank, e.to, e.from
            )
        };
        if seen.insert((e.file.clone(), e.line, msg.clone())) {
            findings.push(Finding {
                file: e.file.clone(),
                line: e.line,
                rule: "lock_order",
                message: msg,
            });
        }
    }

    // Cycle check over the class digraph. With a total rank order every
    // cycle contains an inversion already reported above, but the graph
    // check keeps the rule honest if ranks are ever made partial.
    if findings.is_empty() {
        if let Some(cycle) = find_cycle(&observed) {
            let at = observed.first();
            findings.push(Finding {
                file: at.map(|e| e.file.clone()).unwrap_or_default(),
                line: at.map(|e| e.line).unwrap_or(1),
                rule: "lock_order",
                message: format!("lock graph contains a cycle: {}", cycle.join(" -> ")),
            });
        }
    }

    findings
}

type FnKey = (String, String);
type LockSet = BTreeSet<(&'static str, u32)>;

/// All observed inter-lock edges (also drives the `--fix-report` DAG
/// dump).
pub fn edges(files: &[SourceFile]) -> Vec<Edge> {
    // Pass 1: direct acquisition classes per (file rel, fn name).
    let mut trans: BTreeMap<FnKey, LockSet> = BTreeMap::new();
    for sf in files {
        for span in &sf.fns {
            if span.is_test {
                continue;
            }
            trans
                .entry((sf.rel.clone(), span.name.clone()))
                .or_default()
                .extend(direct_classes(sf, span));
        }
    }

    // Pass 2: transitive closure over the call graph — each fn's set
    // absorbs its callees' sets until fixpoint. `via` keeps one sample
    // call chain per (fn, class) for the report.
    let cg = dataflow::CallGraph::build(files);
    let mut via: BTreeMap<(FnKey, &'static str), Vec<String>> = BTreeMap::new();
    for _ in 0..64 {
        let mut updates: Vec<(FnKey, (&'static str, u32), Vec<String>)> = Vec::new();
        for (key, callees) in &cg.calls {
            for (ck, _line) in callees {
                if ck == key {
                    continue;
                }
                let Some(cset) = trans.get(ck) else { continue };
                for &(c, r) in cset {
                    if trans.get(key).is_none_or(|h| !h.contains(&(c, r))) {
                        let mut chain = vec![ck.1.clone()];
                        if let Some(rest) = via.get(&(ck.clone(), c)) {
                            chain.extend(rest.iter().cloned());
                        }
                        updates.push((key.clone(), (c, r), chain));
                    }
                }
            }
        }
        if updates.is_empty() {
            break;
        }
        for (key, cr, chain) in updates {
            if trans.entry(key.clone()).or_default().insert(cr) {
                via.entry((key, cr.0)).or_insert(chain);
            }
        }
    }

    // Pass 3: interval walk per fn, emitting edges to each acquisition
    // and to every lock transitively reachable through a call made
    // while something is held.
    let mut out = Vec::new();
    for sf in files {
        for span in &sf.fns {
            if span.is_test {
                continue;
            }
            walk_fn(sf, span, files, &trans, &via, &mut out);
        }
    }
    out
}

/// Lightweight scan: every registered acquisition class in a fn body,
/// ignoring hold intervals (the pass-1 seeds).
fn direct_classes(sf: &SourceFile, span: &FnSpan) -> Vec<(&'static str, u32)> {
    let nested = dataflow::nested_fn_spans(sf, span);
    let mut out = Vec::new();
    let mut i = span.body_start + 1;
    while i < span.body_end {
        if let Some(end) = nested.iter().find_map(|&(s, e)| (s == i).then_some(e)) {
            i = end + 1;
            continue;
        }
        if let Some((class, rank)) = acquisition_at(sf, i) {
            out.push((class, rank));
        }
        i += 1;
    }
    out
}

/// Is token `i` the `lock/read/write` ident of a registered
/// `receiver.lock().unwrap()`-shaped acquisition? Returns its class.
fn acquisition_at(sf: &SourceFile, i: usize) -> Option<(&'static str, u32)> {
    let t = &sf.tokens;
    let m = t.get(i)?;
    if m.kind != TokKind::Ident || !matches!(m.text.as_str(), "lock" | "read" | "write") {
        return None;
    }
    if !t.get(i.checked_sub(1)?)?.is(".") {
        return None;
    }
    if !(t.get(i + 1)?.is("(") && t.get(i + 2)?.is(")") && t.get(i + 3)?.is(".")) {
        return None;
    }
    let u = t.get(i + 4)?;
    if !(u.kind == TokKind::Ident && matches!(u.text.as_str(), "unwrap" | "expect")) {
        return None;
    }
    let recv = dataflow::receiver_name(sf, i.checked_sub(2)?)?;
    classify(&recv)
}

/// Full interval walk of one fn: tracks held guards and statement
/// temporaries, emits an edge for every acquisition (or resolvable call
/// with a non-empty transitive lock set) that happens under a hold.
fn walk_fn(
    sf: &SourceFile,
    span: &FnSpan,
    files: &[SourceFile],
    trans: &BTreeMap<FnKey, LockSet>,
    via: &BTreeMap<(FnKey, &'static str), Vec<String>>,
    edges: &mut Vec<Edge>,
) {
    let t = &sf.tokens;
    let nested = dataflow::nested_fn_spans(sf, span);
    let mut holds: Vec<Hold> = Vec::new();
    let mut temps: Vec<Hold> = Vec::new();
    let mut depth = 0usize;
    let mut stmt_start = span.body_start + 1;
    let mut i = span.body_start + 1;

    while i < span.body_end {
        if let Some(end) = nested.iter().find_map(|&(s, e)| (s == i).then_some(e)) {
            i = end + 1;
            stmt_start = i;
            continue;
        }
        let tok = &t[i];
        if tok.kind == TokKind::Punct {
            match tok.text.as_str() {
                "{" => {
                    depth += 1;
                    stmt_start = i + 1;
                    i += 1;
                    continue;
                }
                "}" => {
                    holds.retain(|h| h.depth != depth);
                    depth = depth.saturating_sub(1);
                    stmt_start = i + 1;
                    i += 1;
                    continue;
                }
                ";" => {
                    temps.clear();
                    stmt_start = i + 1;
                    i += 1;
                    continue;
                }
                _ => {}
            }
        }

        // Explicit `drop(guard)` releases the named hold early.
        if tok.kind == TokKind::Ident
            && tok.is("drop")
            && t.get(i + 1).is_some_and(|x| x.is("("))
            && t.get(i + 3).is_some_and(|x| x.is(")"))
        {
            if let Some(name) = t.get(i + 2).filter(|x| x.kind == TokKind::Ident) {
                holds.retain(|h| h.binding.as_deref() != Some(name.text.as_str()));
            }
        }

        // Acquisition site.
        if let Some((class, rank)) = acquisition_at(sf, i) {
            for h in holds.iter().chain(temps.iter()) {
                edges.push(Edge {
                    from: h.class,
                    from_rank: h.rank,
                    to: class,
                    to_rank: rank,
                    file: sf.rel.clone(),
                    line: tok.line,
                    via: None,
                });
            }
            match held_binding(sf, span, i, stmt_start) {
                Some(binding) => holds.push(Hold {
                    class,
                    rank,
                    binding,
                    depth,
                }),
                None => temps.push(Hold {
                    class,
                    rank,
                    binding: None,
                    depth,
                }),
            }
            i += 1;
            continue;
        }

        // Transitive call propagation, only while something is held.
        if (!holds.is_empty() || !temps.is_empty()) && tok.kind == TokKind::Ident {
            if let Some((callee_rel, callee)) = dataflow::resolve_at(files, sf, i) {
                let key = (callee_rel, callee.clone());
                if let Some(classes) = trans.get(&key) {
                    for &(c, r) in classes {
                        let mut chain = vec![callee.clone()];
                        if let Some(rest) = via.get(&(key.clone(), c)) {
                            chain.extend(rest.iter().cloned());
                        }
                        for h in holds.iter().chain(temps.iter()) {
                            edges.push(Edge {
                                from: h.class,
                                from_rank: h.rank,
                                to: c,
                                to_rank: r,
                                file: sf.rel.clone(),
                                line: tok.line,
                                via: Some(chain.join(" -> ")),
                            });
                        }
                    }
                }
            }
        }

        i += 1;
    }
}

/// Does the acquisition at token `i` produce a held guard? Yes when the
/// statement is `let <pat> = <chain>.unwrap();` — the guard is bound —
/// and the initializer is not a `*`-deref copy (which releases at the
/// semicolon). Returns `Some(binding)` for a held guard, `None` for a
/// temporary.
fn held_binding(
    sf: &SourceFile,
    span: &FnSpan,
    i: usize,
    stmt_start: usize,
) -> Option<Option<String>> {
    let t = &sf.tokens;
    // Find the end of the `.unwrap(...)` / `.expect(...)` call.
    let call_open = i + 5;
    if !t.get(call_open)?.is("(") {
        return None;
    }
    let mut d = 0usize;
    let mut k = call_open;
    while k < span.body_end {
        if t[k].is("(") {
            d += 1;
        } else if t[k].is(")") {
            d = d.saturating_sub(1);
            if d == 0 {
                break;
            }
        }
        k += 1;
    }
    if !t.get(k + 1)?.is(";") {
        return None; // chained further: a temporary
    }
    if !t.get(stmt_start)?.is("let") {
        return None; // bare expression statement: a temporary
    }
    // `let x = *guard.read().unwrap();` copies out and releases.
    let mut e = stmt_start;
    while e < i {
        if t[e].is("=") && !t.get(e + 1).is_some_and(|x| x.is("=")) {
            if t.get(e + 1).is_some_and(|x| x.is("*")) {
                return None;
            }
            break;
        }
        e += 1;
    }
    // Binding: first ident after `let` that isn't `mut`.
    let mut b = stmt_start + 1;
    let binding = loop {
        let tok = t.get(b)?;
        if tok.kind == TokKind::Ident && !tok.is("mut") {
            break Some(tok.text.clone());
        }
        if tok.is("=") {
            break None;
        }
        b += 1;
    };
    Some(binding)
}

/// DFS cycle detection over the deduped class digraph.
fn find_cycle(edges: &[Edge]) -> Option<Vec<String>> {
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in edges {
        if e.from != e.to {
            adj.entry(e.from).or_default().insert(e.to);
        }
    }
    fn dfs<'a>(
        n: &'a str,
        adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
        state: &mut BTreeMap<&'a str, u8>,
        stack: &mut Vec<&'a str>,
    ) -> Option<Vec<String>> {
        state.insert(n, 1);
        stack.push(n);
        if let Some(next) = adj.get(n) {
            for &m in next {
                match state.get(m).copied().unwrap_or(0) {
                    1 => {
                        let pos = stack.iter().position(|&x| x == m).unwrap_or(0);
                        let mut cyc: Vec<String> = stack
                            .get(pos..)
                            .unwrap_or(&[])
                            .iter()
                            .map(|s| s.to_string())
                            .collect();
                        cyc.push(m.to_string());
                        return Some(cyc);
                    }
                    0 => {
                        if let Some(c) = dfs(m, adj, state, stack) {
                            return Some(c);
                        }
                    }
                    _ => {}
                }
            }
        }
        stack.pop();
        state.insert(n, 2);
        None
    }
    let nodes: Vec<&str> = adj.keys().copied().collect();
    let mut state: BTreeMap<&str, u8> = BTreeMap::new();
    let mut stack: Vec<&str> = Vec::new();
    for n in nodes {
        if state.get(n).copied().unwrap_or(0) == 0 {
            if let Some(c) = dfs(n, &adj, &mut state, &mut stack) {
                return Some(c);
            }
        }
    }
    None
}
