//! Dataflow scaffolding shared by the flow-sensitive lint rules.
//!
//! Three pieces, all deliberately small:
//!
//! 1. **Def/use extraction** over [`Stmt`] token ranges — which
//!    variable names a statement (re)binds and which it reads. This is
//!    name-based, not place-based: `x.field` and `x` are the same name,
//!    shadowing re-binds the name. For the taint and ordering rules
//!    that is the right precision/complexity trade.
//! 2. **A forward may-fixpoint** over a [`Cfg`]: union join, iterate to
//!    a fixed point (bounded — all transfer lattices here are finite
//!    sets of variable names), returning each block's entry state.
//! 3. **The project call graph**: every call site that resolves to a
//!    function that actually exists in the scanned tree, keyed by
//!    `(file rel, fn name)`. Resolution is receiver-gated exactly like
//!    the original lock-order rule: `self.f()`, registered component
//!    handles (`store.append()`), and lowercase `module::f()` paths
//!    resolve; arbitrary method names on arbitrary receivers do not.
//!    The lock, ordering, and taint rules all walk this one graph.

use std::collections::{BTreeMap, BTreeSet};

use super::cfg::{Cfg, Stmt, StmtKind};
use super::lexer::{TokKind, Token};
use super::scanner::{FnSpan, SourceFile};

// ---------------------------------------------------------------------------
// Call resolution (shared with lock_order / ordering).
// ---------------------------------------------------------------------------

/// Method-call receivers resolved across files: the named component
/// handles that hop between hub / storage layers.
pub(crate) fn component_file(receiver: &str) -> Option<&'static str> {
    Some(match receiver {
        "state" => "hub/repo.rs",
        "store" | "storage" => "storage/mod.rs",
        "service" | "svc" => "api/service.rs",
        "wal" => "storage/wal.rs",
        _ => return None,
    })
}

/// Method names never treated as cross-component calls.
pub(crate) fn never_a_call(name: &str) -> bool {
    matches!(name, "lock" | "read" | "write" | "unwrap" | "expect" | "clone" | "drop")
}

/// Walk back from token `j` (the token just before the `.` of a method
/// chain) to the receiver's base name, skipping one balanced `(...)` or
/// `[...]` group: `self.stripe(&key).write()` → `stripe`.
pub(crate) fn receiver_name(sf: &SourceFile, j: usize) -> Option<String> {
    let t = &sf.tokens;
    let tok = t.get(j)?;
    if tok.kind == TokKind::Ident {
        return Some(tok.text.clone());
    }
    let (close, open) = match tok.text.as_str() {
        ")" => (")", "("),
        "]" => ("]", "["),
        _ => return None,
    };
    let mut depth = 0usize;
    let mut k = j;
    loop {
        let tk = t.get(k)?;
        if tk.is(close) {
            depth += 1;
        } else if tk.is(open) {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                break;
            }
        }
        k = k.checked_sub(1)?;
    }
    let prev = t.get(k.checked_sub(1)?)?;
    if prev.kind == TokKind::Ident {
        Some(prev.text.clone())
    } else {
        None
    }
}

/// Resolve a call at token `i` (a method or path-fn name ident) to
/// (callee file rel-suffix, callee fn name). Receiver-gated: only
/// `self.`, registered component handles, and `module::` paths resolve
/// — generic method names on arbitrary receivers do not.
pub(crate) fn resolve_call(sf: &SourceFile, i: usize) -> Option<(String, String)> {
    let t = &sf.tokens;
    let name = t.get(i)?;
    if name.kind != TokKind::Ident || !t.get(i + 1)?.is("(") {
        return None;
    }
    if never_a_call(&name.text) {
        return None;
    }
    // `receiver.name(...)`.
    if t.get(i.wrapping_sub(1)).is_some_and(|x| x.is(".")) {
        let recv = t.get(i.checked_sub(2)?)?;
        if recv.kind != TokKind::Ident {
            return None;
        }
        if recv.is("self") {
            return Some((sf.rel.clone(), name.text.clone()));
        }
        if let Some(file) = component_file(&recv.text) {
            return Some((file.to_string(), name.text.clone()));
        }
        return None;
    }
    // `module::name(...)`.
    if t.get(i.wrapping_sub(1)).is_some_and(|x| x.is(":"))
        && t.get(i.wrapping_sub(2)).is_some_and(|x| x.is(":"))
    {
        let m = t.get(i.checked_sub(3)?)?;
        if m.kind == TokKind::Ident && m.text.chars().next().is_some_and(char::is_lowercase) {
            return Some((format!("{}.rs", m.text), name.text.clone()));
        }
    }
    None
}

/// Find the scanned file a rel-suffix refers to (`module.rs` from a
/// path call matches by suffix, with `module/mod.rs` as the fallback
/// spelling).
pub(crate) fn find_file<'a>(files: &'a [SourceFile], callee_file: &str) -> Option<&'a SourceFile> {
    let stem = callee_file.trim_end_matches(".rs");
    files.iter().find(|f| {
        f.rel == callee_file
            || f.rel.ends_with(&format!("/{callee_file}"))
            || f.rel == format!("{stem}/mod.rs")
            || f.rel.ends_with(&format!("/{stem}/mod.rs"))
    })
}

/// Resolve the call at token `i` all the way to a *concrete* scanned
/// function: the target file must be in `files` and must define a
/// non-test `fn` of that name. Returns `(callee rel, callee fn)`.
pub(crate) fn resolve_at(
    files: &[SourceFile],
    sf: &SourceFile,
    i: usize,
) -> Option<(String, String)> {
    let (suffix, name) = resolve_call(sf, i)?;
    let target = find_file(files, &suffix)?;
    if target.fns.iter().any(|f| !f.is_test && f.name == name) {
        Some((target.rel.clone(), name))
    } else {
        None
    }
}

/// Body token ranges of fns nested inside `span` (closures are *not*
/// masked — a closure runs in its caller's context; a nested `fn` is a
/// separate function analyzed on its own).
pub(crate) fn nested_fn_spans(sf: &SourceFile, span: &FnSpan) -> Vec<(usize, usize)> {
    sf.fns
        .iter()
        .filter(|f| f.body_start > span.body_start && f.body_end < span.body_end)
        .map(|f| (f.body_start, f.body_end))
        .collect()
}

// ---------------------------------------------------------------------------
// Def / use extraction.
// ---------------------------------------------------------------------------

/// Index of the statement-level assignment `=` in `[lo, hi)`, at
/// bracket depth 0, excluding `==`, `!=`, `<=`, `>=`, and `=>`.
fn top_level_eq(tokens: &[Token], lo: usize, hi: usize) -> Option<usize> {
    let hi = hi.min(tokens.len());
    let mut depth = 0usize;
    let mut i = lo;
    while i < hi {
        let t = &tokens[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth = depth.saturating_sub(1),
                "=" if depth == 0 => {
                    let next_bad =
                        tokens.get(i + 1).is_some_and(|n| n.is("=") || n.is(">"));
                    let prev_bad = i > lo
                        && matches!(tokens[i - 1].text.as_str(), "=" | "!" | "<" | ">");
                    if !next_bad && !prev_bad {
                        return Some(i);
                    }
                }
                _ => {}
            }
        }
        i += 1;
    }
    None
}

/// Is the `=` at `eq` a compound assignment (`+=`, `|=`, ...)?
fn is_compound(tokens: &[Token], lo: usize, eq: usize) -> bool {
    eq > lo
        && matches!(
            tokens[eq - 1].text.as_str(),
            "+" | "-" | "*" | "/" | "%" | "&" | "|" | "^"
        )
}

/// Variable names a statement (re)binds. Name-based: lowercase idents
/// in binding position; uppercase idents (enum/struct paths) and `mut`
/// are skipped.
pub fn defs(tokens: &[Token], stmt: &Stmt) -> Vec<String> {
    let (lo, hi) = (stmt.lo, stmt.hi.min(tokens.len()));
    if lo >= hi {
        return Vec::new();
    }
    let lower = |t: &Token| {
        t.kind == TokKind::Ident
            && t.text.chars().next().is_some_and(|c| c == '_' || c.is_lowercase())
            && !matches!(t.text.as_str(), "mut" | "ref" | "if" | "let" | "in" | "box")
    };
    let mut out = Vec::new();
    match stmt.kind {
        StmtKind::Pattern => {
            // Match-arm pattern: every lowercase ident is a fresh
            // binding (guard reads are conservatively treated the same
            // way — the scrutinee-to-binding taint link is deliberately
            // not modeled; see the taint rule's module docs).
            for t in &tokens[lo..hi] {
                if lower(t) {
                    out.push(t.text.clone());
                }
            }
        }
        StmtKind::Normal | StmtKind::Cond => {
            let has_let = tokens[lo..hi].iter().any(|t| t.kind == TokKind::Ident && t.is("let"));
            let eq = top_level_eq(tokens, lo, hi);
            if has_let {
                // `let <pat> = ...` (or `let <pat>;`): bindings are the
                // lowercase idents between `let` and the `=`.
                let let_at = lo
                    + tokens[lo..hi]
                        .iter()
                        .position(|t| t.kind == TokKind::Ident && t.is("let"))
                        .unwrap_or(0);
                let end = eq.unwrap_or(hi).min(hi);
                let mut depth = 0usize;
                let mut k = let_at;
                while k < end {
                    let t = &tokens[k];
                    if t.kind == TokKind::Punct {
                        match t.text.as_str() {
                            "(" | "[" | "{" => depth += 1,
                            ")" | "]" | "}" => depth = depth.saturating_sub(1),
                            // A `:` at depth 0 that is not `::` starts
                            // a type annotation — nothing after it (up
                            // to the `=`) binds a name.
                            ":" if depth == 0
                                && !tokens.get(k + 1).is_some_and(|n| n.is(":"))
                                && !(k > let_at && tokens[k - 1].is(":")) =>
                            {
                                break;
                            }
                            _ => {}
                        }
                    }
                    // Skip field names in struct patterns (`a:` in
                    // `Foo { a: b }` — the label, not a binding).
                    let is_field_label = depth > 0
                        && tokens.get(k + 1).is_some_and(|n| n.is(":"))
                        && tokens.get(k + 2).map(|n| !n.is(":")).unwrap_or(true);
                    if lower(t) && !is_field_label {
                        out.push(t.text.clone());
                    }
                    k += 1;
                }
            } else if let Some(e) = eq {
                // Plain assignment: the place left of `=`. Walk back
                // over compound-op puncts and one balanced index/call
                // group to the base ident (`self.field`, `arr[i]`).
                let mut k = e;
                while k > lo && is_compound(tokens, lo, k) {
                    k -= 1;
                }
                if k > lo {
                    if let Some(name) = place_base(tokens, lo, k - 1) {
                        out.push(name);
                    }
                }
            }
        }
    }
    out.sort();
    out.dedup();
    out
}

/// Base name of the assignable place ending at token `j` (inclusive):
/// `field` for `self.field`, `arr` for `arr[i]`, `x` for `x`.
fn place_base(tokens: &[Token], lo: usize, j: usize) -> Option<String> {
    let t = tokens.get(j)?;
    if t.kind == TokKind::Ident {
        return Some(t.text.clone());
    }
    if t.is("]") {
        // Skip the balanced `[...]` backwards, then name the base.
        let mut depth = 0usize;
        let mut k = j;
        loop {
            let tk = tokens.get(k)?;
            if tk.is("]") {
                depth += 1;
            } else if tk.is("[") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            if k == lo {
                return None;
            }
            k -= 1;
        }
        if k > lo {
            return place_base(tokens, lo, k - 1);
        }
    }
    None
}

/// Variable names a statement reads: the right-hand side of a `let` /
/// assignment, or the whole statement otherwise. Compound assignments
/// (`x += e`) read their target too.
pub fn uses(tokens: &[Token], stmt: &Stmt) -> Vec<String> {
    let (lo, hi) = (stmt.lo, stmt.hi.min(tokens.len()));
    if lo >= hi {
        return Vec::new();
    }
    let eq = match stmt.kind {
        StmtKind::Pattern => None,
        _ => top_level_eq(tokens, lo, hi),
    };
    let start = match eq {
        Some(e) => e + 1,
        None => lo,
    };
    let mut out = Vec::new();
    if let Some(e) = eq {
        if is_compound(tokens, lo, e) {
            for t in &tokens[lo..e] {
                if t.kind == TokKind::Ident {
                    out.push(t.text.clone());
                }
            }
        }
    }
    for t in &tokens[start.min(hi)..hi] {
        if t.kind == TokKind::Ident {
            out.push(t.text.clone());
        }
    }
    out.sort();
    out.dedup();
    out
}

// ---------------------------------------------------------------------------
// Forward may-fixpoint.
// ---------------------------------------------------------------------------

/// Iterate `transfer` over the CFG to a forward fixed point with union
/// join; returns each block's *entry* state. `transfer(block, entry)`
/// must be monotone in `entry` for termination; the iteration is also
/// hard-capped, which keeps the linter total even on a buggy transfer.
pub fn forward<F>(cfg: &Cfg, transfer: F) -> Vec<BTreeSet<String>>
where
    F: Fn(usize, &BTreeSet<String>) -> BTreeSet<String>,
{
    let n = cfg.blocks.len();
    let mut entry: Vec<BTreeSet<String>> = vec![BTreeSet::new(); n];
    for _ in 0..64 {
        let mut changed = false;
        for b in 0..n {
            let out = transfer(b, &entry[b]);
            for &s in &cfg.blocks[b].succs {
                if s >= n {
                    continue;
                }
                for v in &out {
                    if !entry[s].contains(v) {
                        entry[s].insert(v.clone());
                        changed = true;
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }
    entry
}

// ---------------------------------------------------------------------------
// Project call graph.
// ---------------------------------------------------------------------------

/// The project-wide call graph over concretely-resolved call sites.
pub struct CallGraph {
    /// `(caller rel, caller fn)` → list of `((callee rel, callee fn),
    /// call-site line)`, in body order, duplicates kept.
    pub calls: BTreeMap<(String, String), Vec<((String, String), u32)>>,
}

impl CallGraph {
    /// Scan every non-test function in `files` and record each call
    /// site that resolves to a function defined in the scanned tree.
    pub fn build(files: &[SourceFile]) -> CallGraph {
        let mut calls: BTreeMap<(String, String), Vec<((String, String), u32)>> = BTreeMap::new();
        for sf in files {
            for span in &sf.fns {
                if span.is_test {
                    continue;
                }
                let nested = nested_fn_spans(sf, span);
                let mut i = span.body_start + 1;
                while i < span.body_end.min(sf.tokens.len()) {
                    if let Some(end) = nested.iter().find_map(|&(s, e)| (s == i).then_some(e)) {
                        i = end + 1;
                        continue;
                    }
                    if sf.tokens[i].kind == TokKind::Ident {
                        if let Some(target) = resolve_at(files, sf, i) {
                            calls
                                .entry((sf.rel.clone(), span.name.clone()))
                                .or_default()
                                .push((target, sf.tokens[i].line));
                        }
                    }
                    i += 1;
                }
            }
        }
        CallGraph { calls }
    }

    /// Call sites of one function (empty slice when it calls nothing
    /// resolvable).
    pub fn callees(&self, rel: &str, name: &str) -> &[((String, String), u32)] {
        self.calls
            .get(&(rel.to_string(), name.to_string()))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::cfg::Cfg;
    use crate::analysis::lexer::lex;

    fn first_stmts(body: &str) -> (Vec<Token>, Vec<Stmt>) {
        let src = format!("fn f() {{ {body} }}");
        let (toks, _) = lex(&src);
        let open = toks.iter().position(|t| t.is("{")).unwrap();
        let cfg = Cfg::build(&toks, open + 1, toks.len() - 1);
        let stmts = cfg.blocks.iter().flat_map(|b| b.stmts.clone()).collect();
        (toks, stmts)
    }

    #[test]
    fn let_defs_and_uses() {
        let (toks, stmts) = first_stmts("let mut n = le_u32_at(buf, 0);");
        assert_eq!(defs(&toks, &stmts[0]), vec!["n"]);
        let u = uses(&toks, &stmts[0]);
        assert!(u.contains(&"buf".to_string()) && u.contains(&"le_u32_at".to_string()));
        assert!(!u.contains(&"n".to_string()));
    }

    #[test]
    fn assignment_defs() {
        let (toks, stmts) = first_stmts("self.len = end;");
        assert_eq!(defs(&toks, &stmts[0]), vec!["len"]);
        let (toks, stmts) = first_stmts("total += chunk;");
        assert_eq!(defs(&toks, &stmts[0]), vec!["total"]);
        // Compound assignment reads its target too.
        assert!(uses(&toks, &stmts[0]).contains(&"total".to_string()));
    }

    #[test]
    fn tuple_let_defs_both() {
        let (toks, stmts) = first_stmts("let (a, b) = pair;");
        assert_eq!(defs(&toks, &stmts[0]), vec!["a", "b"]);
    }

    #[test]
    fn comparison_is_not_assignment() {
        let (toks, stmts) = first_stmts("check(a == b);");
        assert!(defs(&toks, &stmts[0]).is_empty());
    }

    #[test]
    fn forward_reaches_fixpoint_through_loop() {
        let src = "fn f() { let t = src(); while go { sink(t); } }";
        let (toks, _) = lex(src);
        let open = toks.iter().position(|t| t.is("{")).unwrap();
        let cfg = Cfg::build(&toks, open + 1, toks.len() - 1);
        // Transfer: a block that defines `t` gens it; otherwise pass.
        let entries = forward(&cfg, |b, inp| {
            let mut out = inp.clone();
            for s in &cfg.blocks[b].stmts {
                if defs(&toks, s).contains(&"t".to_string()) {
                    out.insert("t".to_string());
                }
            }
            out
        });
        // Every non-entry block (incl. the loop body) sees `t`.
        for (i, e) in entries.iter().enumerate() {
            if i != cfg.entry {
                assert!(e.contains("t"), "block {i} missing t: {entries:?}");
            }
        }
    }
}
