//! `c3o lint` — a project-invariant static analyzer for the hub tree.
//!
//! DESIGN.md §7–§11 grew a set of correctness invariants that used to
//! live only in prose: the lock acquisition order across submit locks /
//! cache stripes / coalesce groups / reactor queues, panic-freedom on
//! the reactor and WAL hot paths, `SAFETY` justification for the epoll
//! FFI, and fsync-before-rename durability discipline. This module
//! machine-checks them on every build (`.github/workflows/ci.yml` runs
//! `c3o lint rust/src` as a blocking step).
//!
//! The analyzer is deliberately self-contained: a hand-rolled lexer
//! ([`lexer`]) and a brace/function-aware scanner ([`scanner`]) over
//! the project's own sources — no syn, no rustc internals, no external
//! crates — because the crate builds against an offline cache. v2 adds
//! a real dataflow layer: a statement-level CFG per function ([`cfg`]),
//! def/use chains and a project call graph ([`dataflow`]), and three
//! analyses built on them — full-depth interprocedural lock-set
//! propagation ([`lock_order`]), taint tracking for wire-derived bytes
//! ([`taint`], L7), and a durability-ordering state machine
//! ([`ordering`], L8, which subsumes the old same-function
//! rename/sync_dir check as one instance). It is a *project* linter,
//! not a general one: the lock registry in [`lock_order`] names this
//! codebase's locks, and the hot-path lists in [`rules`] name this
//! codebase's reactor files. See DESIGN.md §12 for the rule catalog
//! and the allow-marker grammar.
//!
//! Escape hatch: a deliberate violation carries, on its line or the
//! comment block right above it,
//!
//! ```text
//! // lint: allow(<rule>, reason = "<why this is sound>")
//! ```
//!
//! where `<rule>` is one of `lock_order`, `panics`, `safety`,
//! `durability`, `protocol`, `logging`, `taint`, `ordering`,
//! `alloc_hot`. A marker with a missing or empty reason is itself a
//! finding — the escape hatch documents, it does not silence.

pub mod cfg;
pub mod dataflow;
pub mod lexer;
pub mod lock_order;
pub mod ordering;
pub mod rules;
pub mod scanner;
pub mod taint;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use scanner::SourceFile;

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the linted root, `/`-separated.
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// The result of linting a tree.
#[derive(Debug)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub fns_scanned: usize,
    /// Observed inter-lock edges (for the `--fix-report` DAG dump and
    /// `--format dot`).
    pub lock_edges: Vec<lock_order::Edge>,
    /// Every taint flow L7 traced, including the validated and dormant
    /// ones — evidence that the analysis saw the wire values, not just
    /// that nothing fired.
    pub taint_flows: Vec<taint::TaintFlow>,
}

/// Lint every `.rs` file under `root`. Findings already filtered
/// through allow markers and sorted by (file, line, rule).
pub fn lint_dir(root: &Path) -> crate::Result<LintReport> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();

    let mut files = Vec::new();
    for path in paths {
        let src = std::fs::read_to_string(&path)?;
        let rel = rel_path(root, &path);
        files.push(SourceFile::parse(path, rel, &src));
    }

    let mut findings = Vec::new();
    findings.extend(lock_order::check(&files));
    for sf in &files {
        findings.extend(rules::panic_freedom(sf));
        findings.extend(rules::unsafe_audit(sf));
        findings.extend(rules::logging(sf));
        findings.extend(rules::alloc_hot(sf));
    }
    findings.extend(rules::protocol(&files));
    findings.extend(ordering::check(&files));
    let (taint_findings, taint_flows) = taint::check(&files);
    findings.extend(taint_findings);

    // Apply allow markers; malformed / reasonless markers are findings.
    let markers: BTreeMap<&str, FileMarkers> =
        files.iter().map(|sf| (sf.rel.as_str(), file_markers(sf))).collect();
    findings.retain(|f| {
        markers
            .get(f.file.as_str())
            .is_none_or(|m| !m.allows(f.line, f.rule))
    });
    for (rel, m) in &markers {
        for &(line, ref msg) in &m.bad {
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: "marker",
                message: msg.clone(),
            });
        }
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    findings.dedup();

    Ok(LintReport {
        findings,
        files_scanned: files.len(),
        fns_scanned: files.iter().map(|f| f.fns.len()).sum(),
        lock_edges: lock_order::edges(&files),
        taint_flows,
    })
}

/// Render the report for the CLI. One `file:line: [rule] message` per
/// finding plus a summary line; `fix_report` appends per-rule
/// remediation notes and the observed lock DAG.
pub fn render(report: &LintReport, root: &Path, fix_report: bool) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!(
            "{}/{}:{}: [{}] {}\n",
            root.display(),
            f.file,
            f.line,
            f.rule,
            f.message
        ));
    }
    if report.findings.is_empty() {
        out.push_str(&format!(
            "c3o lint: clean — {} files, {} fns, 0 findings\n",
            report.files_scanned, report.fns_scanned
        ));
    } else {
        out.push_str(&format!(
            "c3o lint: {} finding(s) in {} files scanned\n",
            report.findings.len(),
            report.files_scanned
        ));
    }
    if fix_report {
        out.push_str(&fix_notes(report));
    }
    out
}

fn fix_notes(report: &LintReport) -> String {
    let mut out = String::from("\n== fix report ==\n");
    let mut by_rule: BTreeMap<&str, usize> = BTreeMap::new();
    for f in &report.findings {
        *by_rule.entry(f.rule).or_insert(0) += 1;
    }
    let hint = |rule: &str| -> &str {
        match rule {
            "lock_order" => {
                "reorder the acquisitions to follow the rank table in \
                 DESIGN.md §12, or shrink the outer guard's scope \
                 (drop(guard) / a `{}` block) so the locks never overlap"
            }
            "panics" => {
                "return a structured error (WireError / io::Error) for \
                 anything reachable from peer or disk input; annotate \
                 deliberate mutex-poisoning unwraps with \
                 `// lint: allow(panics, reason = \"...\")`"
            }
            "safety" => {
                "add `// SAFETY:` immediately above the unsafe block, \
                 stating the preconditions and why the surrounding code \
                 establishes them"
            }
            "durability" => {
                "call `sync_dir` on the parent directory after the \
                 rename (see storage/mod.rs), or justify with \
                 `// lint: allow(durability, ...)`"
            }
            "protocol" => {
                "wire the op through Op::decode, the service dispatch \
                 and HubClient together — partial plumbing drifts"
            }
            "logging" => {
                "route the diagnostic through the structured logger \
                 (`crate::obs::log::{error,warn,info,debug}`) so it \
                 respects --log-level and test capture, or justify with \
                 `// lint: allow(logging, ...)`"
            }
            "taint" => {
                "bound the wire-derived value before it sizes memory: \
                 compare it against a cap / remaining-bytes, verify the \
                 frame CRC, or route the bytes through `scan` — the \
                 validator registry is in analysis/taint.rs"
            }
            "ordering" => {
                "make the WAL append durable (fsync / append_durable) \
                 on every path that reaches the publish or ack — the \
                 automaton traced a path where the data is not yet on \
                 disk when it becomes visible"
            }
            "alloc_hot" => {
                "hoist the allocation out of the per-call path into a \
                 reusable scratch buffer (std::mem::take / clear-and-\
                 refill), or justify once-per-call-boundary copies with \
                 `// lint: allow(alloc_hot, reason = \"...\")`"
            }
            _ => "write the marker as // lint: allow(rule, reason = \"...\")",
        }
    };
    for (rule, n) in &by_rule {
        out.push_str(&format!("[{rule}] {n} finding(s): {}\n", hint(rule)));
    }
    out.push_str("\nobserved lock DAG (acquired-before edges):\n");
    let mut seen = std::collections::BTreeSet::new();
    for e in &report.lock_edges {
        if seen.insert((e.from, e.to)) {
            out.push_str(&format!(
                "  {} (rank {}) -> {} (rank {})\n",
                e.from, e.from_rank, e.to, e.to_rank
            ));
        }
    }
    if seen.is_empty() {
        out.push_str("  (none observed)\n");
    }
    out
}

/// Render the report as one JSON document (`--format json`; CI uploads
/// it as an artifact). Deterministic: objects are BTreeMaps and the
/// vectors were sorted by the linter.
pub fn render_json(report: &LintReport, root: &Path) -> String {
    use crate::util::json::Json;
    let findings: Vec<Json> = report
        .findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("rule", Json::Str(f.rule.to_string())),
                ("message", Json::Str(f.message.clone())),
            ])
        })
        .collect();
    let mut seen = std::collections::BTreeSet::new();
    let mut edges: Vec<Json> = Vec::new();
    for e in &report.lock_edges {
        if seen.insert((e.from, e.to)) {
            edges.push(Json::obj(vec![
                ("from", Json::Str(e.from.to_string())),
                ("from_rank", Json::Num(e.from_rank as f64)),
                ("to", Json::Str(e.to.to_string())),
                ("to_rank", Json::Num(e.to_rank as f64)),
            ]));
        }
    }
    let flows: Vec<Json> = report
        .taint_flows
        .iter()
        .map(|fl| {
            Json::obj(vec![
                ("file", Json::Str(fl.file.clone())),
                ("function", Json::Str(fl.function.clone())),
                ("var", Json::Str(fl.var.clone())),
                ("source", Json::Str(fl.source.clone())),
                ("source_line", Json::Num(fl.source_line as f64)),
                (
                    "validated_line",
                    fl.validated_line.map_or(Json::Null, |l| Json::Num(l as f64)),
                ),
                (
                    "sink_line",
                    fl.sink_line.map_or(Json::Null, |l| Json::Num(l as f64)),
                ),
                ("status", Json::Str(fl.status.to_string())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("root", Json::Str(root.display().to_string())),
        ("files_scanned", Json::Num(report.files_scanned as f64)),
        ("fns_scanned", Json::Num(report.fns_scanned as f64)),
        ("clean", Json::Bool(report.findings.is_empty())),
        ("findings", Json::Arr(findings)),
        ("lock_edges", Json::Arr(edges)),
        ("taint_flows", Json::Arr(flows)),
    ]);
    format!("{doc}\n")
}

/// Render the observed lock DAG as Graphviz (`--format dot`). Edges
/// are deduped by (from, to); an edge against the rank order is drawn
/// red and bold so the inversion is visible in the rendered graph.
pub fn render_dot(report: &LintReport) -> String {
    let mut out = String::from("digraph lock_order {\n");
    out.push_str("  rankdir=LR;\n");
    out.push_str("  node [shape=box, fontname=\"monospace\"];\n");
    let mut nodes = std::collections::BTreeSet::new();
    let mut seen = std::collections::BTreeSet::new();
    for e in &report.lock_edges {
        nodes.insert((e.from, e.from_rank));
        nodes.insert((e.to, e.to_rank));
        seen.insert((e.from, e.to, e.from_rank >= e.to_rank));
    }
    for (name, rank) in &nodes {
        out.push_str(&format!("  {name} [label=\"{name}\\nrank {rank}\"];\n"));
    }
    for (from, to, inverted) in &seen {
        if *inverted {
            out.push_str(&format!("  {from} -> {to} [color=red, penwidth=2.0];\n"));
        } else {
            out.push_str(&format!("  {from} -> {to};\n"));
        }
    }
    out.push_str("}\n");
    out
}

// ---------------------------------------------------------------------------
// Allow markers
// ---------------------------------------------------------------------------

/// Markers of one file: `line -> rules allowed there`, plus malformed
/// marker findings.
struct FileMarkers {
    allow: BTreeMap<u32, Vec<String>>,
    bad: Vec<(u32, String)>,
}

impl FileMarkers {
    /// Is `(line, rule)` covered? Coverage (same line, or the first
    /// source line below the marker's comment block) was expanded into
    /// the map at parse time, so this is a lookup.
    fn allows(&self, line: u32, rule: &str) -> bool {
        self.allow
            .get(&line)
            .is_some_and(|rules| rules.iter().any(|r| r == rule))
    }
}

/// Parse every `// lint: allow(...)` marker in a file. A marker on
/// comment line L covers L and the next source line below the comment
/// block it belongs to (computed here so `allows` is a map lookup).
fn file_markers(sf: &SourceFile) -> FileMarkers {
    let mut allow: BTreeMap<u32, Vec<String>> = BTreeMap::new();
    let mut bad = Vec::new();
    for c in &sf.comments {
        let text = c.text.trim_start();
        if !text.starts_with("lint:") {
            continue;
        }
        match parse_marker(text) {
            Ok((rule, reason)) => {
                if reason.trim().is_empty() {
                    bad.push((
                        c.line,
                        format!(
                            "allow({rule}) marker without a reason — write \
                             `// lint: allow({rule}, reason = \"...\")`"
                        ),
                    ));
                    continue;
                }
                // The marker covers its own line and every line of the
                // comment/blank block below it up to and including the
                // first source line.
                let mut l = c.line;
                loop {
                    allow.entry(l).or_default().push(rule.clone());
                    l += 1;
                    let s = sf.line(l);
                    let trimmed = s.trim();
                    let is_gap = trimmed.is_empty()
                        || trimmed.starts_with("//")
                        || trimmed.starts_with('#');
                    if !is_gap {
                        allow.entry(l).or_default().push(rule.clone());
                        break;
                    }
                    if l as usize > sf.lines.len() {
                        break;
                    }
                }
            }
            Err(msg) => bad.push((c.line, msg)),
        }
    }
    FileMarkers { allow, bad }
}

/// Parse `lint: allow(rule, reason = "...")`. Returns (rule, reason).
fn parse_marker(text: &str) -> Result<(String, String), String> {
    let malformed =
        || "malformed lint marker — write `// lint: allow(rule, reason = \"...\")`".to_string();
    let rest = text.strip_prefix("lint:").ok_or_else(malformed)?.trim_start();
    let rest = rest.strip_prefix("allow(").ok_or_else(malformed)?;
    let close = rest.rfind(')').ok_or_else(malformed)?;
    let inner = rest.get(..close).ok_or_else(malformed)?;
    let (rule, reason) = match inner.split_once(',') {
        Some((r, rest)) => {
            let rest = rest.trim_start();
            let reason = rest
                .strip_prefix("reason")
                .map(|r| r.trim_start())
                .and_then(|r| r.strip_prefix('='))
                .map(|r| r.trim().trim_matches('"').to_string())
                .ok_or_else(malformed)?;
            (r.trim().to_string(), reason)
        }
        None => (inner.trim().to_string(), String::new()),
    };
    const RULES: &[&str] = &[
        "lock_order",
        "panics",
        "safety",
        "durability",
        "protocol",
        "logging",
        "taint",
        "ordering",
        "alloc_hot",
    ];
    if !RULES.contains(&rule.as_str()) {
        return Err(format!(
            "unknown rule `{rule}` in lint marker (known: {})",
            RULES.join(", ")
        ));
    }
    Ok((rule, reason))
}

// ---------------------------------------------------------------------------
// File walking
// ---------------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}
