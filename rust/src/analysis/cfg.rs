//! Per-function control-flow graph for the `c3o lint` dataflow rules.
//!
//! A deliberately small statement-level parser over the token stream:
//! it does not understand Rust expressions, only enough structure to
//! split a function body into statements and wire the branch/loop/match
//! edges the dataflow engine needs. Statements are token ranges; the
//! rules re-scan those ranges with their own pattern matchers.
//!
//! Design constraints, in order:
//! 1. **Never panic, never loop forever** — the property tests feed
//!    this parser random byte mutations of real source files. Every
//!    loop strictly advances its cursor and every slice index is
//!    clamped to the range being parsed.
//! 2. **Conservative edges** — when structure is ambiguous (a `loop`
//!    whose `break` we did not see, a macro body), we add the edge that
//!    makes the analysis weaker (more paths), never fewer. Dataflow
//!    verdicts stay sound for the rules built on top (which report
//!    must-not-happen orderings over may-reach paths).
//! 3. **Expression-level control flow stays inside one statement** —
//!    `let x = if c { a } else { b };` is a single `Normal` statement.
//!    The taint rule treats it textually, which is exactly as precise
//!    as the line scanner it replaces, while statement-level `if` /
//!    `while` / `match` get real branch structure.

use super::lexer::{TokKind, Token};

/// What a statement is, for the transfer functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StmtKind {
    /// Plain statement (possibly a `let`, call, assignment, ...).
    Normal,
    /// Branch condition (`if c`, `while c`, `match scrutinee`). The
    /// token range covers only the condition/scrutinee expression.
    Cond,
    /// Match-arm pattern (plus guard, if any). Identifiers bound here
    /// are definitions from the automaton's point of view.
    Pattern,
}

/// One statement: a half-open token range `[lo, hi)` plus the 1-based
/// line of its first token.
#[derive(Debug, Clone)]
pub struct Stmt {
    pub lo: usize,
    pub hi: usize,
    pub line: u32,
    pub kind: StmtKind,
}

/// One basic block: statements executed in order, then a jump to any of
/// `succs`.
#[derive(Debug, Clone, Default)]
pub struct Block {
    pub stmts: Vec<Stmt>,
    pub succs: Vec<usize>,
}

/// A function body CFG. `entry` is always block 0; `exit` is a single
/// empty block every fall-off-the-end path reaches. Early returns and
/// `?` are *not* modeled as edges to exit — the rules that care about
/// "reaches the end" semantics (ordering) treat any path as suspect,
/// which is the conservative direction.
#[derive(Debug, Clone)]
pub struct Cfg {
    pub blocks: Vec<Block>,
    pub entry: usize,
    pub exit: usize,
}

impl Cfg {
    /// Build the CFG for the token range `(lo, hi)` — exclusive of the
    /// outer braces — of a function body in `tokens`.
    pub fn build(tokens: &[Token], lo: usize, hi: usize) -> Cfg {
        let hi = hi.min(tokens.len());
        let lo = lo.min(hi);
        let mut b = Builder { tokens, blocks: vec![Block::default()], loops: Vec::new() };
        let last = b.seq(lo, hi, 0);
        let exit = b.new_block();
        b.blocks[last].succs.push(exit);
        // Wire every dead-end block (no successors, not the exit) to
        // exit so dataflow fixpoints converge over total graphs.
        for idx in 0..b.blocks.len() {
            if idx != exit && b.blocks[idx].succs.is_empty() {
                b.blocks[idx].succs.push(exit);
            }
        }
        Cfg { blocks: b.blocks, entry: 0, exit }
    }

    /// Blocks reachable from `from` (exclusive of `from` unless it is
    /// on a cycle back to itself), for forward may-reach queries.
    pub fn reachable_from(&self, from: usize) -> Vec<usize> {
        let mut seen = vec![false; self.blocks.len()];
        let mut stack: Vec<usize> = self.blocks.get(from).map(|b| b.succs.clone()).unwrap_or_default();
        let mut out = Vec::new();
        while let Some(n) = stack.pop() {
            if n >= seen.len() || seen[n] {
                continue;
            }
            seen[n] = true;
            out.push(n);
            stack.extend(self.blocks[n].succs.iter().copied());
        }
        out
    }
}

struct Builder<'a> {
    tokens: &'a [Token],
    blocks: Vec<Block>,
    /// Stack of (header_block, after_block) for `break`/`continue`.
    loops: Vec<(usize, usize)>,
}

impl<'a> Builder<'a> {
    fn new_block(&mut self) -> usize {
        self.blocks.push(Block::default());
        self.blocks.len() - 1
    }

    fn edge(&mut self, from: usize, to: usize) {
        if !self.blocks[from].succs.contains(&to) {
            self.blocks[from].succs.push(to);
        }
    }

    fn line_at(&self, i: usize) -> u32 {
        self.tokens.get(i).map(|t| t.line).unwrap_or(0)
    }

    /// Push a statement onto `cur`, wiring `break`/`continue` edges if
    /// the statement contains them at top level.
    fn push_stmt(&mut self, cur: usize, stmt: Stmt) {
        let (lo, hi) = (stmt.lo, stmt.hi);
        self.blocks[cur].stmts.push(stmt);
        if let Some(&(header, after)) = self.loops.last() {
            for i in lo..hi.min(self.tokens.len()) {
                let t = &self.tokens[i];
                if t.kind == TokKind::Ident {
                    if t.is("break") {
                        self.edge(cur, after);
                    } else if t.is("continue") {
                        self.edge(cur, header);
                    }
                }
            }
        }
    }

    /// Skip a balanced bracket group starting at the opener `tokens[i]`;
    /// returns the index just past the matching closer (or `hi`).
    fn skip_balanced(&self, i: usize, hi: usize) -> usize {
        let open = match self.tokens.get(i).map(|t| t.text.as_str()) {
            Some("(") => "(",
            Some("[") => "[",
            Some("{") => "{",
            _ => return i + 1,
        };
        let close = match open {
            "(" => ")",
            "[" => "]",
            _ => "}",
        };
        let mut depth = 0usize;
        let mut j = i;
        while j < hi.min(self.tokens.len()) {
            let t = &self.tokens[j];
            if t.kind == TokKind::Punct {
                if t.is(open) {
                    depth += 1;
                } else if t.is(close) {
                    depth -= 1;
                    if depth == 0 {
                        return j + 1;
                    }
                }
            }
            j += 1;
        }
        hi
    }

    /// Parse the statement sequence `[lo, hi)` appending to block
    /// `cur`; returns the block that control falls out of.
    fn seq(&mut self, lo: usize, hi: usize, mut cur: usize) -> usize {
        let hi = hi.min(self.tokens.len());
        let mut i = lo;
        while i < hi {
            let t = &self.tokens[i];
            if t.kind == TokKind::Punct && t.is(";") {
                i += 1;
                continue;
            }
            if t.kind == TokKind::Ident {
                match t.text.as_str() {
                    "if" => {
                        i = self.parse_if(i, hi, &mut cur);
                        continue;
                    }
                    "while" | "for" => {
                        i = self.parse_while_for(i, hi, &mut cur);
                        continue;
                    }
                    "loop" => {
                        i = self.parse_loop(i, hi, &mut cur);
                        continue;
                    }
                    "match" => {
                        i = self.parse_match(i, hi, &mut cur);
                        continue;
                    }
                    "unsafe" if self.tokens.get(i + 1).is_some_and(|n| n.is("{")) => {
                        // `unsafe { ... }` block statement: treat the
                        // braces as a plain nested block.
                        i += 1;
                        continue;
                    }
                    "fn" => {
                        // Nested fn item: skip its body entirely; it is
                        // analyzed as its own function by the scanner.
                        let mut j = i + 1;
                        while j < hi && !self.tokens[j].is("{") {
                            j += 1;
                        }
                        i = self.skip_balanced(j, hi);
                        continue;
                    }
                    _ => {}
                }
            }
            if t.kind == TokKind::Punct && t.is("{") {
                // Bare nested block at statement position.
                let end = self.skip_balanced(i, hi);
                cur = self.seq(i + 1, end.saturating_sub(1).max(i + 1), cur);
                i = end;
                continue;
            }
            // Plain statement: consume to the `;` at depth 0, treating
            // any bracket group (closures, struct literals, trailing
            // blocks of expression-level if/match) as opaque.
            let start = i;
            let mut j = i;
            let mut ended_with_block = false;
            while j < hi {
                let tk = &self.tokens[j];
                if tk.kind == TokKind::Punct {
                    if tk.is(";") {
                        break;
                    }
                    if tk.is("(") || tk.is("[") || tk.is("{") {
                        let after = self.skip_balanced(j, hi);
                        // A `{...}` group that closes the statement
                        // without a `;` (e.g. an expression-position
                        // block at the end of the body).
                        ended_with_block = tk.is("{")
                            && self
                                .tokens
                                .get(after)
                                .map(|n| !n.is(".") && !n.is("?") && !n.is("else"))
                                .unwrap_or(true);
                        if ended_with_block {
                            j = after;
                            break;
                        }
                        j = after;
                        continue;
                    }
                    if tk.is("}") {
                        // Unbalanced close: end of this range.
                        break;
                    }
                }
                j += 1;
            }
            let end = if j < hi && !ended_with_block { j + 1 } else { j };
            if end > start {
                self.push_stmt(
                    cur,
                    Stmt { lo: start, hi: end.min(hi), line: self.line_at(start), kind: StmtKind::Normal },
                );
            }
            i = end.max(start + 1);
        }
        cur
    }

    /// Find the `{` that opens the branch body after a condition
    /// starting at `i`, skipping balanced groups inside the condition.
    fn find_body_brace(&self, mut i: usize, hi: usize) -> usize {
        while i < hi.min(self.tokens.len()) {
            let t = &self.tokens[i];
            if t.kind == TokKind::Punct {
                if t.is("{") {
                    return i;
                }
                if t.is("(") || t.is("[") {
                    i = self.skip_balanced(i, hi);
                    continue;
                }
                if t.is(";") || t.is("}") {
                    return i; // malformed; stop here
                }
            }
            i += 1;
        }
        hi.min(self.tokens.len())
    }

    fn parse_if(&mut self, if_at: usize, hi: usize, cur: &mut usize) -> usize {
        let brace = self.find_body_brace(if_at + 1, hi);
        if self.tokens.get(brace).map(|t| !t.is("{")).unwrap_or(true) {
            // Malformed `if`: swallow one token and move on.
            self.push_stmt(
                *cur,
                Stmt { lo: if_at, hi: brace.min(hi), line: self.line_at(if_at), kind: StmtKind::Normal },
            );
            return brace.max(if_at + 1);
        }
        self.push_stmt(
            *cur,
            Stmt { lo: if_at + 1, hi: brace, line: self.line_at(if_at), kind: StmtKind::Cond },
        );
        let body_end = self.skip_balanced(brace, hi);
        let then_blk = self.new_block();
        self.edge(*cur, then_blk);
        let then_out = self.seq(brace + 1, body_end.saturating_sub(1).max(brace + 1), then_blk);
        let join = self.new_block();
        self.edge(then_out, join);
        let mut i = body_end;
        let mut had_else = false;
        if self.tokens.get(i).is_some_and(|t| t.is("else")) {
            had_else = true;
            if self.tokens.get(i + 1).is_some_and(|t| t.is("if")) {
                // `else if`: recurse with the current block as the
                // alternative path's origin.
                let mut alt = *cur;
                i = self.parse_if(i + 1, hi, &mut alt);
                self.edge(alt, join);
            } else {
                let eb = self.find_body_brace(i + 1, hi);
                if self.tokens.get(eb).is_some_and(|t| t.is("{")) {
                    let else_end = self.skip_balanced(eb, hi);
                    let else_blk = self.new_block();
                    self.edge(*cur, else_blk);
                    let else_out = self.seq(eb + 1, else_end.saturating_sub(1).max(eb + 1), else_blk);
                    self.edge(else_out, join);
                    i = else_end;
                } else {
                    had_else = false;
                    i += 1;
                }
            }
        }
        if !had_else {
            self.edge(*cur, join);
        }
        *cur = join;
        i.max(if_at + 1)
    }

    fn parse_while_for(&mut self, kw_at: usize, hi: usize, cur: &mut usize) -> usize {
        let brace = self.find_body_brace(kw_at + 1, hi);
        if self.tokens.get(brace).map(|t| !t.is("{")).unwrap_or(true) {
            self.push_stmt(
                *cur,
                Stmt { lo: kw_at, hi: brace.min(hi), line: self.line_at(kw_at), kind: StmtKind::Normal },
            );
            return brace.max(kw_at + 1);
        }
        let header = self.new_block();
        self.edge(*cur, header);
        self.push_stmt(
            header,
            Stmt { lo: kw_at + 1, hi: brace, line: self.line_at(kw_at), kind: StmtKind::Cond },
        );
        let body_end = self.skip_balanced(brace, hi);
        let after = self.new_block();
        let body = self.new_block();
        self.edge(header, body);
        self.edge(header, after);
        self.loops.push((header, after));
        let body_out = self.seq(brace + 1, body_end.saturating_sub(1).max(brace + 1), body);
        self.loops.pop();
        self.edge(body_out, header);
        *cur = after;
        body_end.max(kw_at + 1)
    }

    fn parse_loop(&mut self, kw_at: usize, hi: usize, cur: &mut usize) -> usize {
        let brace = self.find_body_brace(kw_at + 1, hi);
        if self.tokens.get(brace).map(|t| !t.is("{")).unwrap_or(true) {
            self.push_stmt(
                *cur,
                Stmt { lo: kw_at, hi: brace.min(hi), line: self.line_at(kw_at), kind: StmtKind::Normal },
            );
            return brace.max(kw_at + 1);
        }
        let header = self.new_block();
        self.edge(*cur, header);
        let body_end = self.skip_balanced(brace, hi);
        let after = self.new_block();
        self.loops.push((header, after));
        let body_out = self.seq(brace + 1, body_end.saturating_sub(1).max(brace + 1), header);
        self.loops.pop();
        self.edge(body_out, header);
        // Conservative: even a `loop` we saw no `break` in gets an edge
        // to `after` (a macro or nested closure may break out).
        self.edge(header, after);
        *cur = after;
        body_end.max(kw_at + 1)
    }

    fn parse_match(&mut self, kw_at: usize, hi: usize, cur: &mut usize) -> usize {
        let brace = self.find_body_brace(kw_at + 1, hi);
        if self.tokens.get(brace).map(|t| !t.is("{")).unwrap_or(true) {
            self.push_stmt(
                *cur,
                Stmt { lo: kw_at, hi: brace.min(hi), line: self.line_at(kw_at), kind: StmtKind::Normal },
            );
            return brace.max(kw_at + 1);
        }
        self.push_stmt(
            *cur,
            Stmt { lo: kw_at + 1, hi: brace, line: self.line_at(kw_at), kind: StmtKind::Cond },
        );
        let body_end = self.skip_balanced(brace, hi);
        let arms_hi = body_end.saturating_sub(1).max(brace + 1);
        let join = self.new_block();
        let mut i = brace + 1;
        let mut any_arm = false;
        while i < arms_hi {
            // Pattern (+ guard): tokens up to the `=>` at depth 0.
            let pat_start = i;
            let mut j = i;
            let mut found_arrow = false;
            while j < arms_hi {
                let t = &self.tokens[j];
                if t.kind == TokKind::Punct {
                    if t.is("(") || t.is("[") || t.is("{") {
                        j = self.skip_balanced(j, arms_hi);
                        continue;
                    }
                    if t.is("=")
                        && self.tokens.get(j + 1).is_some_and(|n| n.is(">"))
                        && !(j > 0 && self.tokens[j - 1].is("."))
                    {
                        found_arrow = true;
                        break;
                    }
                }
                j += 1;
            }
            if !found_arrow {
                break;
            }
            let arm = self.new_block();
            self.edge(*cur, arm);
            any_arm = true;
            if j > pat_start {
                self.push_stmt(
                    arm,
                    Stmt { lo: pat_start, hi: j, line: self.line_at(pat_start), kind: StmtKind::Pattern },
                );
            }
            let body_at = j + 2; // past `=` `>`
            let arm_out;
            if self.tokens.get(body_at).is_some_and(|t| t.is("{")) {
                let arm_end = self.skip_balanced(body_at, arms_hi);
                arm_out = self.seq(body_at + 1, arm_end.saturating_sub(1).max(body_at + 1), arm);
                i = arm_end;
                if self.tokens.get(i).is_some_and(|t| t.is(",")) {
                    i += 1;
                }
            } else {
                // Expression arm: tokens to the `,` at depth 0.
                let mut k = body_at;
                while k < arms_hi {
                    let t = &self.tokens[k];
                    if t.kind == TokKind::Punct {
                        if t.is("(") || t.is("[") || t.is("{") {
                            k = self.skip_balanced(k, arms_hi);
                            continue;
                        }
                        if t.is(",") {
                            break;
                        }
                    }
                    k += 1;
                }
                if k > body_at {
                    self.push_stmt(
                        arm,
                        Stmt {
                            lo: body_at,
                            hi: k.min(arms_hi),
                            line: self.line_at(body_at),
                            kind: StmtKind::Normal,
                        },
                    );
                }
                arm_out = arm;
                i = (k + 1).max(body_at + 1);
            }
            self.edge(arm_out, join);
        }
        if !any_arm {
            self.edge(*cur, join);
        }
        *cur = join;
        body_end.max(kw_at + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn cfg_of(body: &str) -> (Vec<Token>, Cfg) {
        let src = format!("fn f() {{ {body} }}");
        let (toks, _) = lex(&src);
        // Body tokens are between the outer braces: find them.
        let open = toks.iter().position(|t| t.is("{")).unwrap();
        let close = toks.len() - 1;
        let cfg = Cfg::build(&toks, open + 1, close);
        (toks, cfg)
    }

    fn all_stmt_count(cfg: &Cfg) -> usize {
        cfg.blocks.iter().map(|b| b.stmts.len()).sum()
    }

    #[test]
    fn straight_line_is_one_block_per_stmt_list() {
        let (_, cfg) = cfg_of("let a = 1; let b = a + 2; use_it(b);");
        assert_eq!(all_stmt_count(&cfg), 3);
        assert_eq!(cfg.blocks[cfg.entry].stmts.len(), 3);
    }

    #[test]
    fn if_else_branches_and_joins() {
        let (_, cfg) = cfg_of("let a = 1; if a > 0 { f(a); } else { g(a); } tail();");
        // entry has the let + cond; two branch blocks; a join with tail().
        let entry = &cfg.blocks[cfg.entry];
        assert_eq!(entry.stmts.len(), 2);
        assert_eq!(entry.stmts[1].kind, StmtKind::Cond);
        assert_eq!(entry.succs.len(), 2);
        assert_eq!(all_stmt_count(&cfg), 5);
    }

    #[test]
    fn while_has_back_edge() {
        let (_, cfg) = cfg_of("while x < 3 { x += 1; } done();");
        // Find the header (block holding the Cond stmt).
        let header = cfg
            .blocks
            .iter()
            .position(|b| b.stmts.iter().any(|s| s.kind == StmtKind::Cond))
            .unwrap();
        // Some block must loop back to the header.
        assert!(
            cfg.blocks.iter().enumerate().any(|(i, b)| i != cfg.entry && b.succs.contains(&header)),
            "no back edge: {cfg:?}"
        );
    }

    #[test]
    fn match_arms_are_separate_blocks() {
        let (_, cfg) = cfg_of("match v { Some(x) => use_it(x), None => {} } tail();");
        let patterns =
            cfg.blocks.iter().flat_map(|b| &b.stmts).filter(|s| s.kind == StmtKind::Pattern).count();
        assert_eq!(patterns, 2);
    }

    #[test]
    fn expression_if_stays_in_one_stmt() {
        let (_, cfg) = cfg_of("let x = if c { 1 } else { 2 }; after(x);");
        assert_eq!(cfg.blocks[cfg.entry].stmts.len(), 2);
    }

    #[test]
    fn never_panics_on_garbage() {
        for src in ["} } {", "if { { {", "match", "loop {", "fn fn fn", "=> , => ;"] {
            let (toks, _) = lex(src);
            let _ = Cfg::build(&toks, 0, toks.len());
        }
    }
}
