//! Compacted snapshots of the hub's repositories (DESIGN.md §9).
//!
//! A snapshot is one numbered directory under `<data-dir>/snapshots/`
//! holding each repository's full dataset as TSV (the paper's §VI-A
//! layout, unchanged) plus `MANIFEST.json` with the metadata the TSVs
//! cannot carry: description, maintainer designation and — critically —
//! the *revision watermark* each dataset was captured at, which is what
//! lets recovery line the WAL tail up against the snapshot.
//!
//! Publication is atomic: the snapshot directory is written and fsynced
//! first (manifest last — a directory without one is an aborted attempt
//! and is ignored), then the `CURRENT` pointer file flips to the new
//! sequence via tmp + rename. A crash at any point leaves `CURRENT`
//! naming a complete older snapshot whose WAL was never compacted, so
//! replay still covers everything.

use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::Context;

use super::sync_dir;
use crate::data::{Dataset, JobKind};
use crate::util::json::Json;

/// Metadata of one repository inside a snapshot manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct RepoManifest {
    pub job: JobKind,
    /// Revision watermark: the repo revision this snapshot captured.
    pub revision: u64,
    pub records: u64,
    pub description: String,
    pub maintainer_machine: Option<String>,
}

/// A loaded snapshot: per-repo metadata plus the datasets.
#[derive(Debug)]
pub struct Snapshot {
    pub seq: u64,
    pub repos: Vec<(RepoManifest, Dataset)>,
}

/// Borrowed image of one repository, as handed to [`write`].
#[derive(Debug)]
pub struct RepoImage<'a> {
    pub job: JobKind,
    pub revision: u64,
    pub description: &'a str,
    pub maintainer_machine: Option<&'a str>,
    pub data: &'a Dataset,
}

fn snapshots_root(dir: &Path) -> PathBuf {
    dir.join("snapshots")
}

fn seq_dir(dir: &Path, seq: u64) -> PathBuf {
    snapshots_root(dir).join(format!("{seq:06}"))
}

fn current_path(dir: &Path) -> PathBuf {
    snapshots_root(dir).join("CURRENT")
}

fn write_sync(path: &Path, bytes: &[u8]) -> crate::Result<()> {
    let mut f =
        File::create(path).with_context(|| format!("creating {}", path.display()))?;
    f.write_all(bytes)?;
    f.sync_all()
        .with_context(|| format!("fsync {}", path.display()))?;
    Ok(())
}

/// Write snapshot `seq` and atomically flip `CURRENT` to it, then prune
/// older snapshot directories (only the newest is ever needed: recovery
/// is snapshot + WAL tail, never a snapshot chain).
pub fn write(dir: &Path, seq: u64, repos: &[RepoImage<'_>]) -> crate::Result<()> {
    let out = seq_dir(dir, seq);
    // A leftover directory from a crashed attempt at this seq is garbage.
    if out.exists() {
        fs::remove_dir_all(&out)
            .with_context(|| format!("clearing stale snapshot {}", out.display()))?;
    }
    fs::create_dir_all(&out)
        .with_context(|| format!("creating snapshot dir {}", out.display()))?;
    let mut entries = Vec::new();
    for repo in repos {
        let text = repo.data.to_table()?.to_text()?;
        write_sync(&out.join(format!("{}.tsv", repo.job)), text.as_bytes())?;
        entries.push(Json::obj(vec![
            ("job", Json::Str(repo.job.to_string())),
            ("revision", Json::Num(repo.revision as f64)),
            ("records", Json::Num(repo.data.len() as f64)),
            ("description", Json::Str(repo.description.to_string())),
            (
                "maintainer_machine",
                match repo.maintainer_machine {
                    Some(m) => Json::Str(m.to_string()),
                    None => Json::Null,
                },
            ),
        ]));
    }
    let manifest = Json::obj(vec![
        ("seq", Json::Num(seq as f64)),
        ("repos", Json::Arr(entries)),
    ]);
    // Manifest last: its presence marks the directory complete.
    write_sync(&out.join("MANIFEST.json"), manifest.to_string().as_bytes())?;
    sync_dir(&out);

    let tmp = snapshots_root(dir).join("CURRENT.tmp");
    write_sync(&tmp, format!("{seq}\n").as_bytes())?;
    fs::rename(&tmp, current_path(dir)).context("flipping snapshot CURRENT")?;
    sync_dir(&snapshots_root(dir));
    prune(dir, seq);
    Ok(())
}

/// Best-effort removal of snapshot directories older than `keep`.
fn prune(dir: &Path, keep: u64) {
    if let Ok(rd) = fs::read_dir(snapshots_root(dir)) {
        for entry in rd.flatten() {
            if let Ok(seq) = entry.file_name().to_string_lossy().parse::<u64>() {
                if seq < keep {
                    let _ = fs::remove_dir_all(entry.path());
                }
            }
        }
    }
}

/// Load the newest complete snapshot under `dir`, or `None` on a fresh
/// data dir. `CURRENT` is authoritative; if it is missing or unreadable
/// the highest sequence with a manifest is used instead. A snapshot that
/// `CURRENT` never flipped to is deliberately ignored: its WAL was never
/// compacted, so replaying on the older snapshot recovers the same state.
pub fn load_latest(dir: &Path) -> crate::Result<Option<Snapshot>> {
    let root = snapshots_root(dir);
    if !root.exists() {
        return Ok(None);
    }
    let seq = fs::read_to_string(current_path(dir))
        .ok()
        .and_then(|text| text.trim().parse::<u64>().ok())
        .or_else(|| highest_complete(&root));
    let seq = match seq {
        Some(seq) => seq,
        None => return Ok(None),
    };
    let out = seq_dir(dir, seq);
    let manifest_text = fs::read_to_string(out.join("MANIFEST.json"))
        .with_context(|| format!("reading snapshot manifest in {}", out.display()))?;
    let manifest = Json::parse(&manifest_text)
        .with_context(|| format!("parsing snapshot manifest in {}", out.display()))?;
    let entries = manifest
        .get("repos")
        .and_then(Json::as_arr)
        .context("snapshot manifest: missing repos array")?;
    let mut repos = Vec::new();
    for entry in entries {
        let job: JobKind = entry
            .get("job")
            .and_then(Json::as_str)
            .context("snapshot manifest: repo missing job")?
            .parse()?;
        let revision = entry
            .get("revision")
            .and_then(Json::as_u64)
            .context("snapshot manifest: repo missing revision")?;
        let description = entry
            .get("description")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let maintainer_machine = entry
            .get("maintainer_machine")
            .and_then(Json::as_str)
            .map(|s| s.to_string());
        let data = Dataset::load(job, &out.join(format!("{job}.tsv")))
            .with_context(|| format!("loading snapshot dataset for {job}"))?;
        let records = data.len() as u64;
        if let Some(expect) = entry.get("records").and_then(Json::as_u64) {
            anyhow::ensure!(
                expect == records,
                "snapshot {seq}: {job} has {records} records on disk, manifest says {expect}"
            );
        }
        repos.push((
            RepoManifest { job, revision, records, description, maintainer_machine },
            data,
        ));
    }
    Ok(Some(Snapshot { seq, repos }))
}

fn highest_complete(root: &Path) -> Option<u64> {
    let mut best = None;
    if let Ok(rd) = fs::read_dir(root) {
        for entry in rd.flatten() {
            if let Ok(seq) = entry.file_name().to_string_lossy().parse::<u64>() {
                if entry.path().join("MANIFEST.json").exists()
                    && best.map_or(true, |b| seq > b)
                {
                    best = Some(seq);
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RunRecord;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("c3o_snap_test_{}_{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn dataset(n: usize) -> Dataset {
        let mut ds = Dataset::new(JobKind::Sort);
        for i in 0..n {
            ds.push(RunRecord {
                machine_type: "m5.xlarge".into(),
                scale_out: 2 + i as u32,
                data_size_gb: 10.0 + i as f64 * 0.125,
                context: vec![],
                runtime_s: 100.0 / (1 + i) as f64,
            })
            .unwrap();
        }
        ds
    }

    #[test]
    fn fresh_dir_has_no_snapshot() {
        let dir = temp_dir("fresh");
        assert!(load_latest(&dir).unwrap().is_none());
        fs::create_dir_all(dir.join("snapshots")).unwrap();
        assert!(load_latest(&dir).unwrap().is_none(), "empty snapshots root");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_load_roundtrip_preserves_metadata_and_watermark() {
        let dir = temp_dir("roundtrip");
        let data = dataset(3);
        let images = [RepoImage {
            job: JobKind::Sort,
            revision: 7,
            description: "standard Spark sort",
            maintainer_machine: Some("m5.xlarge"),
            data: &data,
        }];
        write(&dir, 1, &images).unwrap();
        let snap = load_latest(&dir).unwrap().unwrap();
        assert_eq!(snap.seq, 1);
        assert_eq!(snap.repos.len(), 1);
        let (meta, loaded) = &snap.repos[0];
        assert_eq!(meta.job, JobKind::Sort);
        assert_eq!(meta.revision, 7);
        assert_eq!(meta.records, 3);
        assert_eq!(meta.description, "standard Spark sort");
        assert_eq!(meta.maintainer_machine.as_deref(), Some("m5.xlarge"));
        assert_eq!(loaded.records, data.records, "TSV roundtrip is exact");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn newer_snapshot_replaces_and_prunes_older() {
        let dir = temp_dir("prune");
        let d1 = dataset(2);
        let d2 = dataset(5);
        write(
            &dir,
            1,
            &[RepoImage {
                job: JobKind::Sort,
                revision: 2,
                description: "v1",
                maintainer_machine: None,
                data: &d1,
            }],
        )
        .unwrap();
        write(
            &dir,
            2,
            &[RepoImage {
                job: JobKind::Sort,
                revision: 5,
                description: "v2",
                maintainer_machine: None,
                data: &d2,
            }],
        )
        .unwrap();
        let snap = load_latest(&dir).unwrap().unwrap();
        assert_eq!(snap.seq, 2);
        assert_eq!(snap.repos[0].0.revision, 5);
        assert_eq!(snap.repos[0].0.maintainer_machine, None);
        assert!(!seq_dir(&dir, 1).exists(), "older snapshot pruned");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn aborted_snapshot_without_manifest_is_ignored() {
        let dir = temp_dir("aborted");
        let d1 = dataset(2);
        write(
            &dir,
            1,
            &[RepoImage {
                job: JobKind::Sort,
                revision: 3,
                description: "good",
                maintainer_machine: None,
                data: &d1,
            }],
        )
        .unwrap();
        // Crash mid-snapshot 2: directory exists, no manifest, CURRENT
        // still points at 1.
        fs::create_dir_all(seq_dir(&dir, 2)).unwrap();
        fs::write(seq_dir(&dir, 2).join("sort.tsv"), b"partial").unwrap();
        let snap = load_latest(&dir).unwrap().unwrap();
        assert_eq!(snap.seq, 1, "CURRENT is authoritative");

        // CURRENT lost entirely: fall back to the highest *complete* dir.
        fs::remove_file(current_path(&dir)).unwrap();
        let snap = load_latest(&dir).unwrap().unwrap();
        assert_eq!(snap.seq, 1, "incomplete snapshot 2 must be skipped");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_dataset_snapshots_cleanly() {
        let dir = temp_dir("empty");
        let data = Dataset::new(JobKind::Grep);
        write(
            &dir,
            1,
            &[RepoImage {
                job: JobKind::Grep,
                revision: 0,
                description: "empty repo",
                maintainer_machine: None,
                data: &data,
            }],
        )
        .unwrap();
        let snap = load_latest(&dir).unwrap().unwrap();
        assert_eq!(snap.repos[0].0.records, 0);
        assert!(snap.repos[0].1.is_empty());
        fs::remove_dir_all(&dir).ok();
    }
}
