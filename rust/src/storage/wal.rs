//! Per-repository write-ahead log (DESIGN.md §9).
//!
//! One append-only file per repository holds every accepted contribution
//! that is not yet covered by a published snapshot. Each record is
//! length-prefixed and checksummed:
//!
//! ```text
//! [len: u32 LE] [crc32(payload): u32 LE] [payload: len bytes]
//! payload = [revision: u64 LE] [contribution TSV, UTF-8]
//! ```
//!
//! The `revision` is the repository revision the contribution *committed
//! as*, so replay can line records up against a snapshot's revision
//! watermark and recovery keeps revisions strictly monotone across
//! restarts.
//!
//! Crash semantics: a record is appended with a single `write_all` before
//! the commit publishes, so a crash can only leave a *torn tail* — a
//! half-written final record. [`Wal::open`] scans the file, truncates
//! everything from the first bad frame on, and positions the file for
//! append; every record that survived the scan was fully written and is
//! safe to replay. fsync is the caller's policy decision
//! ([`crate::storage::FsyncPolicy`]): [`Wal::append`] only guarantees the
//! bytes reached the kernel, [`Wal::sync`] makes them storage-durable.

use std::fs::{self, File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::Context;

/// Frame header bytes: `len` + `crc`.
const HEADER_BYTES: usize = 8;
/// Payload bytes preceding the TSV text: the commit revision.
const REVISION_BYTES: usize = 8;
/// Upper bound on one record's payload. A parsed length beyond this is
/// treated as corruption, not as an allocation request.
const MAX_RECORD_BYTES: usize = 64 * 1024 * 1024;

const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        // lint: allow(panics, reason = "const-eval: i < 256 by the loop bound, so an OOB index would be a compile error, not a runtime panic")
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 (IEEE), the frame checksum.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        // lint: allow(panics, reason = "index is masked to 0..=255 and the table has 256 entries — infallible")
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Little-endian `u32` at byte offset `pos`, `None` past the end.
fn le_u32_at(bytes: &[u8], pos: usize) -> Option<u32> {
    let raw = bytes.get(pos..pos.checked_add(4)?)?;
    Some(u32::from_le_bytes(raw.try_into().ok()?))
}

/// Split a record payload into (revision, TSV bytes); `None` when the
/// payload is shorter than the revision prefix.
fn split_payload(payload: &[u8]) -> Option<(u64, &[u8])> {
    let head = payload.get(..REVISION_BYTES)?;
    let tail = payload.get(REVISION_BYTES..)?;
    Some((u64::from_le_bytes(head.try_into().ok()?), tail))
}

/// One decoded WAL record: an accepted contribution and the repository
/// revision it committed as.
#[derive(Debug, Clone, PartialEq)]
pub struct WalRecord {
    pub revision: u64,
    /// The accepted contribution, TSV-encoded (same codec as the wire's
    /// `submit_runs` payload).
    pub data_tsv: String,
}

/// Outcome of scanning a WAL file's bytes.
#[derive(Debug)]
pub struct WalScan {
    /// Records of the valid prefix, in append order.
    pub records: Vec<WalRecord>,
    /// Byte length of the valid prefix.
    pub valid_len: u64,
    /// Whether bytes past `valid_len` existed (a torn or corrupt tail
    /// that [`Wal::open`] truncates).
    pub torn: bool,
}

/// Decode as many complete, checksummed records as `bytes` holds. Stops
/// at the first bad frame: records past a torn one cannot be trusted.
pub fn scan(bytes: &[u8]) -> WalScan {
    let mut records = Vec::new();
    let mut pos = 0usize;
    loop {
        if pos + HEADER_BYTES > bytes.len() {
            break;
        }
        let (len, crc) = match (le_u32_at(bytes, pos), le_u32_at(bytes, pos + 4)) {
            (Some(len), Some(crc)) => (len as usize, crc),
            _ => break,
        };
        if len < REVISION_BYTES || len > MAX_RECORD_BYTES {
            break;
        }
        let start = pos + HEADER_BYTES;
        let end = match start.checked_add(len) {
            Some(end) if end <= bytes.len() => end,
            _ => break,
        };
        let Some(payload) = bytes.get(start..end) else {
            break;
        };
        if crc32(payload) != crc {
            break;
        }
        let Some((revision, tsv_bytes)) = split_payload(payload) else {
            break;
        };
        let tsv = match std::str::from_utf8(tsv_bytes) {
            Ok(tsv) => tsv,
            Err(_) => break,
        };
        records.push(WalRecord { revision, data_tsv: tsv.to_string() });
        pos = end;
    }
    WalScan { records, valid_len: pos as u64, torn: pos < bytes.len() }
}

fn encode(revision: u64, data_tsv: &str) -> crate::Result<Vec<u8>> {
    let tsv = data_tsv.as_bytes();
    let payload_len = REVISION_BYTES + tsv.len();
    anyhow::ensure!(
        payload_len <= MAX_RECORD_BYTES,
        "WAL record too large: {payload_len} bytes"
    );
    let mut payload = Vec::with_capacity(payload_len);
    payload.extend_from_slice(&revision.to_le_bytes());
    payload.extend_from_slice(tsv);
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload_len);
    buf.extend_from_slice(&(payload_len as u32).to_le_bytes());
    buf.extend_from_slice(&crc32(&payload).to_le_bytes());
    buf.extend_from_slice(&payload);
    Ok(buf)
}

/// An open write-ahead log file.
#[derive(Debug)]
pub struct Wal {
    path: PathBuf,
    file: File,
    /// Byte length of the valid record prefix — the append position.
    /// Tracked so a failed partial append can be rolled back with
    /// `set_len` instead of leaving a torn frame mid-file that would
    /// poison every *later* acknowledged record at recovery (scan stops
    /// at the first bad frame).
    len: u64,
    /// Set when a failed append could not be rolled back: the file may
    /// hold a torn frame, so further appends would land after garbage
    /// and be silently truncated by the next recovery. A poisoned WAL
    /// refuses appends — the submit path then refuses acknowledgments —
    /// until the process restarts and `open` truncates the tail.
    poisoned: bool,
    /// Whether bytes were appended since the last fsync.
    dirty: bool,
}

impl Wal {
    /// Open `path` (creating it and its parents if missing), scan the
    /// existing records, truncate any torn tail, and leave the file
    /// positioned for append. Returns the log and the scan result.
    pub fn open(path: &Path) -> crate::Result<(Wal, WalScan)> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating WAL dir {}", parent.display()))?;
        }
        let mut file = OpenOptions::new()
            .read(true)
            .append(true)
            .create(true)
            .open(path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)
            .with_context(|| format!("reading WAL {}", path.display()))?;
        let result = scan(&bytes);
        if result.torn {
            file.set_len(result.valid_len)
                .with_context(|| format!("truncating torn WAL tail in {}", path.display()))?;
            file.sync_data().ok();
        }
        let wal = Wal {
            path: path.to_path_buf(),
            file,
            len: result.valid_len,
            poisoned: false,
            dirty: false,
        };
        Ok((wal, result))
    }

    /// Append one record. A single `write_all`, so a crash mid-append
    /// leaves at most a torn tail (truncated by the next [`Wal::open`]).
    /// A *failed* partial write is rolled back with `set_len`; if even
    /// the rollback fails the log is poisoned and refuses further
    /// appends, so a torn mid-file frame can never silently swallow
    /// later acknowledged records at recovery. Durability against OS
    /// crash is [`Wal::sync`]'s job.
    pub fn append(&mut self, revision: u64, data_tsv: &str) -> crate::Result<()> {
        anyhow::ensure!(
            !self.poisoned,
            "WAL {} is poisoned by an earlier failed append; restart to recover",
            self.path.display()
        );
        let buf = encode(revision, data_tsv)?;
        if let Err(e) = self.file.write_all(&buf) {
            // Partial frames must not stay in the file: everything after
            // them would be truncated by the next recovery scan.
            if self.file.set_len(self.len).is_err() {
                self.poisoned = true;
            }
            return Err(anyhow::Error::new(e)
                .context(format!("appending to WAL {}", self.path.display())));
        }
        self.len += buf.len() as u64;
        self.dirty = true;
        Ok(())
    }

    /// [`Wal::append`] and, when `sync`, fsync before returning. A failed
    /// fsync rolls the frame back (or poisons the log), exactly like a
    /// failed write: the record was *not* acknowledged, so leaving its
    /// intact frame in place would let it shadow the next acknowledged
    /// record claiming the same revision — recovery would then resurrect
    /// the unacknowledged one and skip the acknowledged one.
    pub fn append_durable(
        &mut self,
        revision: u64,
        data_tsv: &str,
        sync: bool,
    ) -> crate::Result<()> {
        let before = self.len;
        let was_dirty = self.dirty;
        let append_start = crate::obs::now_us();
        self.append(revision, data_tsv)?;
        crate::obs::metrics().record_since(crate::obs::Stage::WalAppend, append_start);
        if sync {
            if let Err(e) = self.sync() {
                if self.file.set_len(before).is_ok() {
                    self.len = before;
                    // Bytes up to `before` are exactly as durable as they
                    // were before this call.
                    self.dirty = was_dirty;
                } else {
                    self.poisoned = true;
                }
                return Err(e);
            }
        }
        Ok(())
    }

    /// fsync appended bytes, if any.
    pub fn sync(&mut self) -> crate::Result<()> {
        if self.dirty {
            let fsync_start = crate::obs::now_us();
            self.file
                .sync_data()
                .with_context(|| format!("fsync WAL {}", self.path.display()))?;
            crate::obs::metrics().record_since(crate::obs::Stage::WalFsync, fsync_start);
            self.dirty = false;
        }
        Ok(())
    }

    /// Drop records with `revision <= watermark` — they are covered by a
    /// published snapshot. Rewrites the log atomically (tmp file +
    /// rename) and continues appending to the new file. Records appended
    /// concurrently with the snapshot (revision past the watermark) are
    /// preserved; the caller serializes `compact` against `append` by
    /// holding the same lock around both.
    pub fn compact(&mut self, watermark: u64) -> crate::Result<()> {
        self.sync()?;
        let bytes = fs::read(&self.path)
            .with_context(|| format!("reading WAL {} for compaction", self.path.display()))?;
        let result = scan(&bytes);
        let tmp = self.path.with_extension("wal.tmp");
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            for rec in &result.records {
                if rec.revision > watermark {
                    f.write_all(&encode(rec.revision, &rec.data_tsv)?)?;
                }
            }
            f.sync_data()?;
        }
        fs::rename(&tmp, &self.path)
            .with_context(|| format!("publishing compacted WAL {}", self.path.display()))?;
        if let Some(parent) = self.path.parent() {
            super::sync_dir(parent);
        }
        // The old handle points at the unlinked inode; reopen for append.
        self.file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(&self.path)
            .with_context(|| format!("reopening compacted WAL {}", self.path.display()))?;
        self.len = self
            .file
            .metadata()
            .with_context(|| format!("sizing compacted WAL {}", self.path.display()))?
            .len();
        // The rewrite kept only intact frames, so a poisoned log is
        // healed by compaction.
        self.poisoned = false;
        self.dirty = false;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Stream the log at `path` frame by frame and return up to `max` records
/// with `revision > from_revision`, in append order — the log-shipping
/// read (DESIGN.md §11). Unlike [`scan`], this never loads the whole file:
/// memory is bounded by one frame plus the returned page. Every frame is
/// CRC-verified, *including skipped ones*, so the scan invariant holds:
/// nothing at or past the first bad frame is ever yielded. A bad or short
/// frame ends the read silently — with a live writer it is simply an
/// append racing us, and the durable prefix we already decoded is exactly
/// what a follower may consume.
pub fn read_tail(
    path: &Path,
    from_revision: u64,
    max: usize,
) -> crate::Result<Vec<WalRecord>> {
    use std::io::BufReader;
    let file = match File::open(path) {
        Ok(f) => f,
        // A WAL that was never created is an empty log, not an error:
        // compaction can legitimately leave nothing behind.
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => {
            return Err(anyhow::Error::new(e)
                .context(format!("opening WAL {} for tail read", path.display())))
        }
    };
    let mut reader = BufReader::new(file);
    let mut out = Vec::new();
    let mut header = [0u8; HEADER_BYTES];
    while out.len() < max {
        // EOF (possibly mid-header: a torn tail or a racing append).
        if reader.read_exact(&mut header).is_err() {
            break;
        }
        let (len, crc) = match (le_u32_at(&header, 0), le_u32_at(&header, 4)) {
            (Some(len), Some(crc)) => (len as usize, crc),
            _ => break,
        };
        if !(REVISION_BYTES..=MAX_RECORD_BYTES).contains(&len) {
            break;
        }
        let mut payload = vec![0u8; len];
        if reader.read_exact(&mut payload).is_err() {
            break;
        }
        if crc32(&payload) != crc {
            break;
        }
        let Some((revision, tsv_bytes)) = split_payload(&payload) else {
            break;
        };
        if revision <= from_revision {
            continue;
        }
        let tsv = match std::str::from_utf8(tsv_bytes) {
            Ok(tsv) => tsv,
            Err(_) => break,
        };
        out.push(WalRecord { revision, data_tsv: tsv.to_string() });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_wal(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("c3o_wal_test_{}_{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir.join("test.wal")
    }

    #[test]
    fn crc32_known_vector() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_reopen_roundtrip() {
        let path = temp_wal("roundtrip");
        {
            let (mut wal, result) = Wal::open(&path).unwrap();
            assert!(result.records.is_empty());
            assert!(!result.torn);
            wal.append(1, "h\t1\nr\t2\n").unwrap();
            wal.append(2, "h\t1\nr\t3\n").unwrap();
            wal.sync().unwrap();
        }
        let (_, result) = Wal::open(&path).unwrap();
        assert!(!result.torn);
        assert_eq!(result.records.len(), 2);
        assert_eq!(result.records[0].revision, 1);
        assert_eq!(result.records[1].data_tsv, "h\t1\nr\t3\n");
        fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn torn_tail_is_truncated_and_survivors_kept() {
        let path = temp_wal("torn");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            wal.append(1, "a\t1\n").unwrap();
            wal.append(2, "a\t2\n").unwrap();
            wal.sync().unwrap();
        }
        let full = fs::read(&path).unwrap();

        // Kill -9 mid-append: half of record 3 on disk.
        let mut torn = full.clone();
        torn.extend_from_slice(&encode(3, "a\t3\n").unwrap()[..7]);
        fs::write(&path, &torn).unwrap();
        let (_, result) = Wal::open(&path).unwrap();
        assert!(result.torn);
        assert_eq!(result.records.len(), 2, "acknowledged records survive");
        assert_eq!(
            fs::metadata(&path).unwrap().len(),
            full.len() as u64,
            "torn tail truncated on open"
        );

        // A second open sees a clean file.
        let (_, result) = Wal::open(&path).unwrap();
        assert!(!result.torn);
        assert_eq!(result.records.len(), 2);
        fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn corrupt_byte_stops_replay_at_the_flip() {
        let path = temp_wal("corrupt");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            wal.append(1, "a\t1\n").unwrap();
            wal.append(2, "a\t2\n").unwrap();
            wal.sync().unwrap();
        }
        let mut bytes = fs::read(&path).unwrap();
        let rec1_len = encode(1, "a\t1\n").unwrap().len();
        // Flip a payload byte of record 2: CRC mismatch.
        let idx = rec1_len + HEADER_BYTES + 2;
        bytes[idx] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        let (_, result) = Wal::open(&path).unwrap();
        assert!(result.torn);
        assert_eq!(result.records.len(), 1, "only the intact prefix replays");
        assert_eq!(result.records[0].revision, 1);
        fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn compact_drops_covered_records_and_keeps_appending() {
        let path = temp_wal("compact");
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(1, "a\t1\n").unwrap();
        wal.append(2, "a\t2\n").unwrap();
        wal.append(3, "a\t3\n").unwrap();
        wal.compact(2).unwrap();
        let (mut wal, result) = Wal::open(&path).unwrap();
        assert_eq!(result.records.len(), 1);
        assert_eq!(result.records[0].revision, 3);

        // The log still accepts appends after compaction.
        wal.append(4, "a\t4\n").unwrap();
        wal.sync().unwrap();
        let (_, result) = Wal::open(&path).unwrap();
        assert_eq!(result.records.len(), 2);
        assert_eq!(result.records[1].revision, 4);

        // Compacting everything empties the file.
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.compact(4).unwrap();
        assert_eq!(fs::metadata(&path).unwrap().len(), 0);
        fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn read_tail_pages_above_the_watermark() {
        let path = temp_wal("tail");
        let (mut wal, _) = Wal::open(&path).unwrap();
        for rev in 1..=5u64 {
            wal.append(rev, &format!("a\t{rev}\n")).unwrap();
        }
        wal.sync().unwrap();

        // Everything above revision 2, capped at 2 records per page.
        let page = read_tail(&path, 2, 2).unwrap();
        assert_eq!(
            page.iter().map(|r| r.revision).collect::<Vec<_>>(),
            vec![3, 4]
        );
        assert_eq!(page[0].data_tsv, "a\t3\n");
        // Next page from the last revision served.
        let page = read_tail(&path, 4, 100).unwrap();
        assert_eq!(page.len(), 1);
        assert_eq!(page[0].revision, 5);
        // A caught-up reader gets an empty page, as does max == 0.
        assert!(read_tail(&path, 5, 100).unwrap().is_empty());
        assert!(read_tail(&path, 0, 0).unwrap().is_empty());
        // A missing file is an empty log (post-compaction state).
        assert!(read_tail(&path.with_extension("nope"), 0, 10).unwrap().is_empty());
        fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn read_tail_stops_at_corruption_even_while_skipping() {
        let path = temp_wal("tailcorrupt");
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(1, "a\t1\n").unwrap();
        wal.append(2, "a\t2\n").unwrap();
        wal.append(3, "a\t3\n").unwrap();
        wal.sync().unwrap();
        let mut bytes = fs::read(&path).unwrap();
        // Corrupt record 2's payload. A tail read from revision 2 would
        // *skip* records 1 and 2 — but the bad frame must still end the
        // read before record 3, exactly like `scan`.
        let rec_len = encode(1, "a\t1\n").unwrap().len();
        bytes[rec_len + HEADER_BYTES + 2] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        assert!(read_tail(&path, 2, 100).unwrap().is_empty());
        // A torn final frame likewise ends the read silently.
        let mut torn = fs::read(&path).unwrap()[..rec_len].to_vec();
        torn.extend_from_slice(&encode(9, "a\t9\n").unwrap()[..7]);
        fs::write(&path, &torn).unwrap();
        let page = read_tail(&path, 0, 100).unwrap();
        assert_eq!(page.len(), 1);
        assert_eq!(page[0].revision, 1);
        fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn absurd_length_prefix_is_corruption_not_allocation() {
        let path = temp_wal("hugelen");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&[0xAA; 32]);
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, &bytes).unwrap();
        let (_, result) = Wal::open(&path).unwrap();
        assert!(result.torn);
        assert!(result.records.is_empty());
        assert_eq!(result.valid_len, 0);
        fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
