//! Durable hub storage: WAL + snapshots + crash recovery (DESIGN.md §9).
//!
//! The C3O hub's value *is* its ever-growing shared corpus (paper §III,
//! §VI) — so an acknowledged `submit_runs` must survive a hub restart or
//! crash. This module makes it so with the classic two-tier layout:
//!
//! * [`wal`] — one append-only, checksummed log per repository. Every
//!   accepted contribution is appended (carrying its commit revision)
//!   *before* the copy-on-write publish that makes it visible.
//! * [`snapshot`] — periodic compacted snapshots: each repo's full
//!   dataset as TSV plus a manifest with description / maintainer
//!   metadata and the revision watermark. After a snapshot publishes,
//!   WAL records it covers are dropped.
//! * [`DurableStore`] — ties both together: `open` recovers (latest
//!   snapshot, then the WAL tail replayed on top, torn trailing record
//!   truncated), `append` logs a contribution under the configured
//!   [`FsyncPolicy`], `snapshot` compacts.
//!
//! Recovery invariants (tested in `rust/tests/durability.rs`):
//! 1. every contribution whose submit was acknowledged is recovered,
//! 2. repository revisions are strictly monotone across restarts (the
//!    fitted-model cache keys on revisions, so reuse would serve stale
//!    models), and
//! 3. a recovered hub predicts bit-identically to one that never
//!    restarted.

pub mod snapshot;
pub mod wal;

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io::{ErrorKind, Write};
use std::path::{Path, PathBuf};
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Context;

use crate::data::{Dataset, JobKind};
use crate::util::tsv::Table;

pub use snapshot::{RepoImage, RepoManifest};
pub use wal::{Wal, WalRecord};

/// When WAL appends become durable against an OS crash or power loss.
/// Every policy survives a *process* crash (kill -9): appends reach the
/// kernel before the submit is acknowledged, fsync only decides when
/// they reach stable storage.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync before every submit acknowledgment. Safest, slowest.
    Always,
    /// A background flusher fsyncs on a fixed cadence (the hub server's
    /// `flush_interval`). An OS crash can lose at most the last interval.
    #[default]
    Interval,
    /// Never fsync on the append path (the OS writes back on its own
    /// schedule; snapshots and graceful shutdown still sync).
    Never,
}

impl fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Interval => "interval",
            FsyncPolicy::Never => "never",
        })
    }
}

impl FromStr for FsyncPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "always" => FsyncPolicy::Always,
            "interval" => FsyncPolicy::Interval,
            "never" => FsyncPolicy::Never,
            other => anyhow::bail!("unknown fsync policy: {other} (always|interval|never)"),
        })
    }
}

/// Durability tuning for a [`DurableStore`].
#[derive(Debug, Clone)]
pub struct StorageConfig {
    pub fsync: FsyncPolicy,
    /// Automatic snapshot threshold: once this many contributions have
    /// accumulated in the WALs since the last snapshot,
    /// [`DurableStore::should_snapshot`] turns true and the hub's
    /// durability thread writes one. 0 disables automatic snapshots
    /// (recovery then replays the whole WAL — correct, just slower).
    pub snapshot_every: u64,
}

impl Default for StorageConfig {
    fn default() -> Self {
        StorageConfig { fsync: FsyncPolicy::Interval, snapshot_every: 64 }
    }
}

/// One repository's recovered state, as returned by [`DurableStore::open`].
#[derive(Debug)]
pub struct RecoveredRepo {
    pub job: JobKind,
    /// Revision watermark after replay — strictly monotone with the
    /// pre-crash revision sequence.
    pub revision: u64,
    /// `None` when only WAL records existed (no snapshot manifest ever
    /// captured this repo's metadata); the hub then keeps the registered
    /// repo's metadata.
    pub description: Option<String>,
    pub maintainer_machine: Option<String>,
    pub data: Dataset,
    /// WAL records replayed on top of the snapshot.
    pub replayed: u64,
}

/// Storage counters surfaced through the hub's `stats` op.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StorageStats {
    /// WAL appends (accepted contributions logged) since open.
    pub wal_appends: u64,
    /// Snapshots written since open.
    pub snapshots: u64,
    /// Appends not yet covered by a snapshot.
    pub pending: u64,
}

/// One page of WAL records shipped to a follower, with the leader-side
/// context it needs to interpret them ([`DurableStore::tail`]).
#[derive(Debug)]
pub struct WalTailPage {
    /// Records with `revision > from_revision`, in append order.
    pub records: Vec<WalRecord>,
    /// The repo's durable revision watermark at read time.
    pub durable_revision: u64,
    /// The requested watermark predates the log's horizon: compaction
    /// dropped records the reader still needs, so the page is not
    /// contiguous with `from_revision` and the reader must snapshot-
    /// bootstrap instead of applying it.
    pub compacted: bool,
}

/// Best-effort directory fsync so a create/rename survives power loss —
/// shared by the WAL and snapshot layers.
pub(crate) fn sync_dir(path: &Path) {
    if let Ok(d) = fs::File::open(path) {
        let _ = d.sync_all();
    }
}

/// Advisory single-writer lock on a data dir. Two hubs appending to the
/// same WALs would assign the same revisions twice and recovery would
/// drop one side's acknowledged records — so a second open must fail
/// loudly instead.
///
/// Protocol: the owner's pid is staged in a per-pid tmp file, fsynced,
/// then `hard_link`ed to `LOCK` — link creation is atomic and fails on an
/// existing target, and the staging means a visible `LOCK` always has
/// complete content (a concurrent reader can never see a half-written
/// pid and mistake a *live* lock for a stale one). A lock left by a dead
/// process (kill -9) is detected via `/proc/<pid>` and taken over; where
/// `/proc` does not exist (non-Linux) liveness cannot be probed with std
/// alone, so the holder is assumed alive and the error tells the
/// operator what to do. Pid recycling can produce a false "still
/// running" the same way.
fn acquire_lock(dir: &Path) -> crate::Result<PathBuf> {
    let path = dir.join("LOCK");
    let tmp = dir.join(format!("LOCK.{}.tmp", std::process::id()));
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("staging lock file {}", tmp.display()))?;
        writeln!(f, "{}", std::process::id())
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().ok();
    }
    for _ in 0..2 {
        match fs::hard_link(&tmp, &path) {
            Ok(()) => {
                let _ = fs::remove_file(&tmp);
                sync_dir(dir);
                return Ok(path);
            }
            Err(e) if e.kind() == ErrorKind::AlreadyExists => {
                let holder = fs::read_to_string(&path)
                    .ok()
                    .and_then(|s| s.trim().parse::<u32>().ok());
                let alive = match holder {
                    Some(pid) if Path::new("/proc").exists() => {
                        Path::new(&format!("/proc/{pid}")).exists()
                    }
                    Some(_) => true,
                    // LOCK files become visible only with complete
                    // content, so unparsable means corruption, not a
                    // half-written live lock.
                    None => false,
                };
                if alive {
                    let _ = fs::remove_file(&tmp);
                    anyhow::bail!(
                        "data dir {} is locked by process {} ({}); stop it, or remove \
                         the LOCK file if that process is known to be dead",
                        dir.display(),
                        holder.unwrap_or(0),
                        path.display()
                    );
                }
                // Stale lock from a crashed process: take it over with a
                // *verified claim*. A bare remove would race a concurrent
                // takeover — both judge the same LOCK stale, the slower
                // remove deletes the faster one's freshly-installed live
                // lock, and two writers own the dir. Renaming the file
                // aside is atomic and claims one specific inode; checking
                // its content proves it was the stale lock we judged, not
                // a fresh live one installed in between.
                let claimed = dir.join(format!("LOCK.claimed.{}", std::process::id()));
                if fs::rename(&path, &claimed).is_err() {
                    // Another claimant moved it first; re-evaluate.
                    continue;
                }
                let claimed_holder = fs::read_to_string(&claimed)
                    .ok()
                    .and_then(|s| s.trim().parse::<u32>().ok());
                if claimed_holder == holder {
                    // Confirmed: we claimed the dead owner's lock. Drop it
                    // and loop to install ours.
                    let _ = fs::remove_file(&claimed);
                } else {
                    // We grabbed a live lock installed mid-takeover: put
                    // it back with hard_link — which, unlike rename, can
                    // never clobber a LOCK some third claimant installed
                    // while it was aside — and refuse, loudly. (If that
                    // third lock exists the link fails and the newer
                    // owner simply stands.)
                    let _ = fs::hard_link(&claimed, &path);
                    let _ = fs::remove_file(&claimed);
                    let _ = fs::remove_file(&tmp);
                    anyhow::bail!(
                        "data dir {} lock changed owner during stale takeover \
                         (now process {}); retry",
                        dir.display(),
                        claimed_holder.unwrap_or(0)
                    );
                }
            }
            Err(e) => {
                let _ = fs::remove_file(&tmp);
                return Err(anyhow::Error::new(e)
                    .context(format!("creating lock file {}", path.display())));
            }
        }
    }
    let _ = fs::remove_file(&tmp);
    anyhow::bail!("could not acquire {} (lost the takeover race twice)", path.display())
}

/// Removes the lock file unless ownership was transferred to the store —
/// so a recovery error after `acquire_lock` cannot leak a lock owned by
/// a live pid (which would refuse every retry until process exit).
struct LockGuard(Option<PathBuf>);

impl LockGuard {
    fn into_path(mut self) -> PathBuf {
        self.0.take().expect("lock guard consumed once")
    }
}

impl Drop for LockGuard {
    fn drop(&mut self) {
        if let Some(path) = &self.0 {
            let _ = fs::remove_file(path);
        }
    }
}

/// The durable side of a hub data dir: per-repo WALs plus the snapshot
/// store. One instance per data dir; shared behind an `Arc` by
/// [`crate::hub::HubState`] and the server's durability thread.
/// Holds the data dir's `LOCK` file for its lifetime (released on drop;
/// a crash leaves it stale, and the next open takes it over).
#[derive(Debug)]
pub struct DurableStore {
    dir: PathBuf,
    lock_path: PathBuf,
    config: StorageConfig,
    /// Per-repo WAL, each behind its own lock: appends to different
    /// repositories do not serialize, and compaction takes the same lock
    /// as append so a rewrite never races a write.
    wals: BTreeMap<JobKind, Mutex<Wal>>,
    /// Per-repo durable coverage: `(revision watermark, record count)`
    /// reconstructible from snapshot + WAL, advanced by `append` and
    /// `snapshot`. `append` enforces `revision == watermark + 1` — the
    /// contiguity recovery depends on — and
    /// [`crate::hub::HubState::set_storage`] checks a repo's whole state
    /// is covered before attaching, so storage attached to a
    /// pre-populated repository without a baseline snapshot fails
    /// loudly up front instead of silently losing the base records at
    /// the next recovery.
    coverage: Mutex<BTreeMap<JobKind, (u64, usize)>>,
    /// Serializes snapshot writes; holds the latest published sequence.
    snapshots: Mutex<u64>,
    appends_total: AtomicU64,
    appends_since_snapshot: AtomicU64,
    snapshots_taken: AtomicU64,
    torn_tails: u64,
}

impl DurableStore {
    /// Open (or create) a durable data dir and recover its state: load
    /// the latest complete snapshot, then replay each repository's WAL
    /// tail on top — truncating a torn trailing record — and return the
    /// recovered repositories with their revision watermarks.
    pub fn open(
        dir: &Path,
        config: StorageConfig,
    ) -> crate::Result<(DurableStore, Vec<RecoveredRepo>)> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating data dir {}", dir.display()))?;
        let lock = LockGuard(Some(acquire_lock(dir)?));
        let snap = snapshot::load_latest(dir)?;
        let seq = snap.as_ref().map_or(0, |s| s.seq);
        let mut recovered: BTreeMap<JobKind, RecoveredRepo> = BTreeMap::new();
        if let Some(snap) = snap {
            for (meta, data) in snap.repos {
                recovered.insert(
                    meta.job,
                    RecoveredRepo {
                        job: meta.job,
                        revision: meta.revision,
                        description: Some(meta.description),
                        maintainer_machine: meta.maintainer_machine,
                        data,
                        replayed: 0,
                    },
                );
            }
        }

        let mut wals = BTreeMap::new();
        let mut torn_tails = 0u64;
        for job in JobKind::ALL {
            let (wal, scan) = Wal::open(&dir.join("wal").join(format!("{job}.wal")))?;
            if scan.torn {
                torn_tails += 1;
            }
            for rec in scan.records {
                let entry = recovered.entry(job).or_insert_with(|| RecoveredRepo {
                    job,
                    revision: 0,
                    description: None,
                    maintainer_machine: None,
                    data: Dataset::new(job),
                    replayed: 0,
                });
                if rec.revision <= entry.revision {
                    // Covered by the snapshot already (the snapshot
                    // published but its WAL compaction never ran).
                    continue;
                }
                anyhow::ensure!(
                    rec.revision == entry.revision + 1,
                    "WAL gap for {job}: repository at revision {}, next WAL record \
                     claims revision {} — refusing to recover with a hole",
                    entry.revision,
                    rec.revision
                );
                let contribution = Table::parse(&rec.data_tsv)
                    .and_then(|t| Dataset::from_table(job, &t))
                    .with_context(|| {
                        format!("replaying {job} WAL record at revision {}", rec.revision)
                    })?;
                for r in contribution.records {
                    entry.data.push(r)?;
                }
                entry.revision = rec.revision;
                entry.replayed += 1;
            }
            wals.insert(job, Mutex::new(wal));
        }

        let coverage: BTreeMap<JobKind, (u64, usize)> = recovered
            .values()
            .map(|r| (r.job, (r.revision, r.data.len())))
            .collect();
        // The replayed WAL backlog counts as pending: a hub that crashes
        // repeatedly before reaching the snapshot threshold must still
        // compact once the *accumulated* tail crosses it, or the WAL (and
        // every restart's replay time) grows without bound.
        let backlog: u64 = recovered.values().map(|r| r.replayed).sum();
        let store = DurableStore {
            dir: dir.to_path_buf(),
            lock_path: lock.into_path(),
            config,
            wals,
            coverage: Mutex::new(coverage),
            snapshots: Mutex::new(seq),
            appends_total: AtomicU64::new(0),
            appends_since_snapshot: AtomicU64::new(backlog),
            snapshots_taken: AtomicU64::new(0),
            torn_tails,
        };
        Ok((store, recovered.into_values().collect()))
    }

    /// Append one accepted contribution, committing as `revision`, to
    /// `job`'s WAL. Called inside the per-repo submit critical section
    /// *before* the copy-on-write publish: if this fails, the submission
    /// is not acknowledged and no state changes. `revision` must extend
    /// the durable watermark by exactly one — recovery replays on that
    /// contiguity — so storage attached to a pre-populated repository
    /// needs a baseline snapshot ([`crate::hub::HubState::snapshot_to`])
    /// first. Under [`FsyncPolicy::Always`] the record is
    /// storage-durable on return.
    pub fn append(&self, job: JobKind, revision: u64, data_tsv: &str) -> crate::Result<()> {
        let wal = self
            .wals
            .get(&job)
            .with_context(|| format!("no WAL for {job}"))?;
        // Contiguity check outside the WAL lock so appends to different
        // repositories still run their I/O in parallel. Same-repo appends
        // are serialized upstream by the per-repo submit lock, so the
        // check-then-advance cannot race with itself.
        {
            let mut coverage = self.coverage.lock().unwrap();
            let mark = coverage.entry(job).or_insert((0, 0));
            anyhow::ensure!(
                revision == mark.0 + 1,
                "WAL revision gap for {job}: durable watermark is {}, append claims {} — \
                 write a baseline snapshot (HubState::snapshot_to) before attaching \
                 storage to a pre-populated repository",
                mark.0,
                revision
            );
        }
        // TSV rows = lines minus the header (fields are tab/newline-free
        // by construction, so line count is exact).
        let rows = data_tsv.lines().count().saturating_sub(1);
        let mut wal = wal.lock().unwrap();
        // Under `Always`, a failed fsync rolls the frame back inside
        // append_durable — an unacknowledged record must not survive to
        // shadow the next acknowledged one at the same revision.
        wal.append_durable(revision, data_tsv, self.config.fsync == FsyncPolicy::Always)?;
        drop(wal);
        {
            let mut coverage = self.coverage.lock().unwrap();
            let mark = coverage.entry(job).or_insert((0, 0));
            *mark = (revision, mark.1 + rows);
        }
        self.appends_total.fetch_add(1, Ordering::Relaxed);
        self.appends_since_snapshot.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// fsync every WAL with unsynced bytes — the `Interval` flusher's
    /// tick, and the graceful-drain path on shutdown.
    pub fn sync(&self) -> crate::Result<()> {
        for wal in self.wals.values() {
            wal.lock().unwrap().sync()?;
        }
        Ok(())
    }

    /// Write a compacted snapshot of `repos` (each carrying its own
    /// revision watermark), publish it atomically, then drop the WAL
    /// records it covers. Serialized internally; appends may proceed
    /// concurrently — records past a repo's watermark are preserved.
    pub fn snapshot(&self, repos: &[RepoImage<'_>]) -> crate::Result<u64> {
        let mut latest = self.snapshots.lock().unwrap();
        let seq = *latest + 1;
        snapshot::write(&self.dir, seq, repos)?;
        *latest = seq;
        drop(latest);
        self.snapshots_taken.fetch_add(1, Ordering::Relaxed);
        self.appends_since_snapshot.store(0, Ordering::Relaxed);
        {
            // The snapshot establishes each repo's durable coverage —
            // unless a concurrent append already advanced past its
            // watermark, in which case the append's count stands.
            let mut coverage = self.coverage.lock().unwrap();
            for repo in repos {
                let mark = coverage.entry(repo.job).or_insert((0, 0));
                if repo.revision >= mark.0 {
                    *mark = (repo.revision, repo.data.len());
                }
            }
        }
        for repo in repos {
            if let Some(wal) = self.wals.get(&repo.job) {
                wal.lock().unwrap().compact(repo.revision)?;
            }
        }
        Ok(seq)
    }

    /// The durable coverage of `job`: `(revision watermark, records)`
    /// reconstructible from this store's snapshot + WAL, or `None` if the
    /// store has never seen the job. [`crate::hub::HubState::set_storage`]
    /// checks it against the live repository before attaching.
    pub fn coverage(&self, job: JobKind) -> Option<(u64, usize)> {
        self.coverage.lock().unwrap().get(&job).copied()
    }

    /// Read up to `max` WAL records with `revision > from_revision` —
    /// the leader side of log shipping (DESIGN.md §11). Holds the job's
    /// WAL lock for the read, so a page can never interleave with a
    /// concurrent append or compaction. `compacted` tells a follower its
    /// watermark fell behind the log's horizon (snapshot compaction
    /// dropped the records it still needs): the page cannot be applied
    /// contiguously and the follower must bootstrap from a snapshot
    /// instead.
    pub fn tail(
        &self,
        job: JobKind,
        from_revision: u64,
        max: usize,
    ) -> crate::Result<WalTailPage> {
        let wal = self
            .wals
            .get(&job)
            .with_context(|| format!("no WAL for {job}"))?;
        let records = {
            let wal = wal.lock().unwrap();
            wal::read_tail(wal.path(), from_revision, max)?
        };
        // Coverage advances just after the WAL lock drops, so a record we
        // read may be newer than the watermark; report whichever is ahead.
        let durable_revision = self
            .coverage(job)
            .map_or(0, |(rev, _)| rev)
            .max(records.last().map_or(0, |rec| rec.revision));
        // Contiguity check: the first shipped record must be exactly
        // `from_revision + 1`; with no records at all, a durable watermark
        // past the follower's proves the gap was compacted away.
        let compacted = match records.first() {
            Some(rec) => rec.revision > from_revision + 1,
            None => durable_revision > from_revision,
        };
        Ok(WalTailPage { records, durable_revision, compacted })
    }

    /// Whether the automatic snapshot threshold has been reached.
    pub fn should_snapshot(&self) -> bool {
        self.config.snapshot_every > 0
            && self.appends_since_snapshot.load(Ordering::Relaxed) >= self.config.snapshot_every
    }

    pub fn config(&self) -> &StorageConfig {
        &self.config
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Torn trailing records truncated during `open` (at most one per
    /// WAL file — the kill -9 signature).
    pub fn torn_tails(&self) -> u64 {
        self.torn_tails
    }

    pub fn stats(&self) -> StorageStats {
        StorageStats {
            wal_appends: self.appends_total.load(Ordering::Relaxed),
            snapshots: self.snapshots_taken.load(Ordering::Relaxed),
            pending: self.appends_since_snapshot.load(Ordering::Relaxed),
        }
    }
}

impl Drop for DurableStore {
    fn drop(&mut self) {
        // Release the data-dir lock — but only if it is still ours (a
        // multi-way takeover race can, in the worst case, have replaced
        // it with another owner's). If the process dies before this
        // runs, the next open detects the stale pid instead.
        let ours = fs::read_to_string(&self.lock_path)
            .map(|s| s.trim() == std::process::id().to_string())
            .unwrap_or(false);
        if ours {
            let _ = fs::remove_file(&self.lock_path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RunRecord;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("c3o_store_test_{}_{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn contribution(job: JobKind, base: u32) -> Dataset {
        let mut ds = Dataset::new(job);
        for k in 0..3u32 {
            ds.push(RunRecord {
                machine_type: "m5.xlarge".into(),
                scale_out: 2 + base + k,
                data_size_gb: 10.0 + (base + k) as f64,
                context: if job == JobKind::Grep { vec![0.01] } else { vec![] },
                runtime_s: 100.0 + (base + k) as f64 * 0.5,
            })
            .unwrap();
        }
        ds
    }

    fn tsv(ds: &Dataset) -> String {
        ds.to_table().unwrap().to_text().unwrap()
    }

    #[test]
    fn fresh_dir_recovers_nothing() {
        let dir = temp_dir("fresh");
        let (store, recovered) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        assert!(recovered.is_empty());
        assert_eq!(store.stats(), StorageStats::default());
        assert_eq!(store.torn_tails(), 0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wal_only_recovery_replays_in_revision_order() {
        let dir = temp_dir("walonly");
        {
            let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
            store.append(JobKind::Sort, 1, &tsv(&contribution(JobKind::Sort, 0))).unwrap();
            store.append(JobKind::Sort, 2, &tsv(&contribution(JobKind::Sort, 10))).unwrap();
            store.append(JobKind::Grep, 1, &tsv(&contribution(JobKind::Grep, 0))).unwrap();
            assert_eq!(store.stats().wal_appends, 3);
            assert_eq!(store.stats().pending, 3);
            // No sync, no snapshot: the process "dies" here.
        }
        let (_, mut recovered) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        recovered.sort_by_key(|r| r.job);
        assert_eq!(recovered.len(), 2);
        let sort = recovered.iter().find(|r| r.job == JobKind::Sort).unwrap();
        assert_eq!(sort.revision, 2);
        assert_eq!(sort.replayed, 2);
        assert_eq!(sort.data.len(), 6);
        assert!(sort.description.is_none(), "WAL-only recovery has no metadata");
        let grep = recovered.iter().find(|r| r.job == JobKind::Grep).unwrap();
        assert_eq!(grep.revision, 1);
        assert_eq!(grep.data.len(), 3);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_plus_wal_tail_recovery() {
        let dir = temp_dir("snapwal");
        let c1 = contribution(JobKind::Sort, 0);
        let c2 = contribution(JobKind::Sort, 10);
        {
            let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
            store.append(JobKind::Sort, 1, &tsv(&c1)).unwrap();
            // Snapshot at watermark 1 (the post-c1 state), compacting c1.
            let seq = store
                .snapshot(&[RepoImage {
                    job: JobKind::Sort,
                    revision: 1,
                    description: "sorting",
                    maintainer_machine: Some("m5.xlarge"),
                    data: &c1,
                }])
                .unwrap();
            assert_eq!(seq, 1);
            assert_eq!(store.stats().pending, 0);
            // One more contribution after the snapshot, then "crash".
            store.append(JobKind::Sort, 2, &tsv(&c2)).unwrap();
        }
        let (store, recovered) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        assert_eq!(recovered.len(), 1);
        let sort = &recovered[0];
        assert_eq!(sort.revision, 2, "snapshot watermark + replayed tail");
        assert_eq!(sort.replayed, 1, "only the post-snapshot record replays");
        assert_eq!(sort.data.len(), 6);
        assert_eq!(sort.description.as_deref(), Some("sorting"));
        assert_eq!(sort.maintainer_machine.as_deref(), Some("m5.xlarge"));
        assert_eq!(store.torn_tails(), 0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_published_but_compaction_skipped_is_not_double_applied() {
        // A WAL record whose revision is <= the snapshot watermark is the
        // "snapshot flipped, compaction never ran" crash window: replay
        // must skip it, not apply it twice.
        let dir = temp_dir("dup");
        let c1 = contribution(JobKind::Sort, 0);
        {
            let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
            store.append(JobKind::Sort, 1, &tsv(&c1)).unwrap();
            store.sync().unwrap();
            // Snapshot WITHOUT the store's compaction step, simulating the
            // crash between CURRENT flip and WAL rewrite.
            snapshot::write(
                &dir,
                1,
                &[RepoImage {
                    job: JobKind::Sort,
                    revision: 1,
                    description: "sorting",
                    maintainer_machine: None,
                    data: &c1,
                }],
            )
            .unwrap();
        }
        let (_, recovered) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        let sort = &recovered[0];
        assert_eq!(sort.revision, 1);
        assert_eq!(sort.replayed, 0, "covered record skipped");
        assert_eq!(sort.data.len(), 3, "not double-applied");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wal_gap_on_disk_refuses_recovery() {
        let dir = temp_dir("gap");
        {
            let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
            store.append(JobKind::Sort, 1, &tsv(&contribution(JobKind::Sort, 0))).unwrap();
        }
        // Forge a revision gap directly in the file (the store's append
        // guard refuses to create one through the API).
        let (mut wal, _) = Wal::open(&dir.join("wal").join("sort.wal")).unwrap();
        wal.append(3, &tsv(&contribution(JobKind::Sort, 10))).unwrap();
        wal.sync().unwrap();
        drop(wal);
        let err = DurableStore::open(&dir, StorageConfig::default()).unwrap_err();
        assert!(format!("{err:#}").contains("WAL gap"), "{err:#}");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_guard_requires_contiguous_revisions() {
        let dir = temp_dir("guard");
        let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        // Attaching storage to a pre-populated repo (revision already 1)
        // without a baseline snapshot: the very first append fails with
        // an actionable error instead of writing an unrecoverable WAL.
        let err = store
            .append(JobKind::Sort, 2, &tsv(&contribution(JobKind::Sort, 0)))
            .unwrap_err();
        assert!(format!("{err:#}").contains("revision gap"), "{err:#}");
        assert!(format!("{err:#}").contains("snapshot"), "{err:#}");

        // The failed append did not advance the watermark; the proper
        // sequence still works, and a skip after a success still fails.
        store.append(JobKind::Sort, 1, &tsv(&contribution(JobKind::Sort, 0))).unwrap();
        assert!(store.append(JobKind::Sort, 3, &tsv(&contribution(JobKind::Sort, 10))).is_err());
        store.append(JobKind::Sort, 2, &tsv(&contribution(JobKind::Sort, 10))).unwrap();

        // A snapshot fast-forwards the watermark (baseline for a
        // pre-populated Grep repo at revision 5).
        let grep = contribution(JobKind::Grep, 0);
        store
            .snapshot(&[RepoImage {
                job: JobKind::Grep,
                revision: 5,
                description: "grep base",
                maintainer_machine: None,
                data: &grep,
            }])
            .unwrap();
        assert!(store.append(JobKind::Grep, 5, &tsv(&contribution(JobKind::Grep, 10))).is_err());
        store.append(JobKind::Grep, 6, &tsv(&contribution(JobKind::Grep, 10))).unwrap();
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tail_pages_are_contiguous_with_the_watermark() {
        let dir = temp_dir("tailpage");
        let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        for rev in 1..=4u64 {
            store
                .append(JobKind::Sort, rev, &tsv(&contribution(JobKind::Sort, rev as u32 * 10)))
                .unwrap();
        }
        // A follower at revision 1 pages the rest, two records at a time.
        let page = store.tail(JobKind::Sort, 1, 2).unwrap();
        assert!(!page.compacted);
        assert_eq!(page.durable_revision, 4);
        assert_eq!(page.records.iter().map(|r| r.revision).collect::<Vec<_>>(), vec![2, 3]);
        let page = store.tail(JobKind::Sort, 3, 2).unwrap();
        assert_eq!(page.records.len(), 1);
        assert_eq!(page.records[0].revision, 4);
        // Caught up: empty page, not compacted.
        let page = store.tail(JobKind::Sort, 4, 2).unwrap();
        assert!(page.records.is_empty());
        assert!(!page.compacted);
        assert_eq!(page.durable_revision, 4);
        // A repo the store has never seen tails as an empty, fresh log.
        let page = store.tail(JobKind::Grep, 0, 10).unwrap();
        assert!(page.records.is_empty());
        assert!(!page.compacted);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tail_behind_the_compaction_horizon_reports_compacted() {
        let dir = temp_dir("tailhorizon");
        let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        let c1 = contribution(JobKind::Sort, 0);
        store.append(JobKind::Sort, 1, &tsv(&c1)).unwrap();
        store.append(JobKind::Sort, 2, &tsv(&contribution(JobKind::Sort, 10))).unwrap();
        let mut full = c1.clone();
        for r in contribution(JobKind::Sort, 10).records {
            full.push(r).unwrap();
        }
        store
            .snapshot(&[RepoImage {
                job: JobKind::Sort,
                revision: 2,
                description: "sorting",
                maintainer_machine: None,
                data: &full,
            }])
            .unwrap();
        store.append(JobKind::Sort, 3, &tsv(&contribution(JobKind::Sort, 20))).unwrap();
        // A follower at revision 0 or 1 needs records the compaction
        // dropped: the page says so instead of shipping a gapped tail.
        let page = store.tail(JobKind::Sort, 0, 10).unwrap();
        assert!(page.compacted);
        assert_eq!(page.records.first().map(|r| r.revision), Some(3));
        // A follower at the snapshot watermark tails contiguously.
        let page = store.tail(JobKind::Sort, 2, 10).unwrap();
        assert!(!page.compacted);
        assert_eq!(page.records.len(), 1);
        assert_eq!(page.durable_revision, 3);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_counted_and_survivors_recovered() {
        let dir = temp_dir("torn");
        {
            let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
            store.append(JobKind::Sort, 1, &tsv(&contribution(JobKind::Sort, 0))).unwrap();
            store.sync().unwrap();
        }
        let wal_path = dir.join("wal").join("sort.wal");
        let mut bytes = fs::read(&wal_path).unwrap();
        let clean_len = bytes.len() as u64;
        bytes.extend_from_slice(&[0x77; 9]); // half-written next record
        fs::write(&wal_path, &bytes).unwrap();
        let (store, recovered) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        assert_eq!(store.torn_tails(), 1);
        assert_eq!(recovered[0].data.len(), 3, "acknowledged records survive");
        assert_eq!(fs::metadata(&wal_path).unwrap().len(), clean_len, "tail truncated");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn second_open_on_a_live_dir_is_refused_until_release() {
        let dir = temp_dir("lock");
        let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        // Same pid is alive, so the lock must hold.
        let err = DurableStore::open(&dir, StorageConfig::default()).unwrap_err();
        assert!(format!("{err:#}").contains("is locked by process"), "{err:#}");
        // Releasing the store releases the dir.
        drop(store);
        let (_, recovered) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        assert!(recovered.is_empty());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_lock_from_dead_process_is_taken_over() {
        let dir = temp_dir("stalelock");
        fs::create_dir_all(&dir).unwrap();
        // A pid far beyond pid_max: definitely not running.
        fs::write(dir.join("LOCK"), "999999999\n").unwrap();
        let (store, _) = DurableStore::open(&dir, StorageConfig::default()).unwrap();
        let lock = fs::read_to_string(dir.join("LOCK")).unwrap();
        assert_eq!(lock.trim(), std::process::id().to_string());
        drop(store);
        assert!(!dir.join("LOCK").exists(), "drop releases the lock");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fsync_policy_parse_display_roundtrip() {
        for p in [FsyncPolicy::Always, FsyncPolicy::Interval, FsyncPolicy::Never] {
            assert_eq!(p.to_string().parse::<FsyncPolicy>().unwrap(), p);
        }
        assert!("sometimes".parse::<FsyncPolicy>().is_err());
    }
}
