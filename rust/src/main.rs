//! `c3o` — CLI for the C3O system.
//!
//! Subcommands:
//!   generate   — produce the 930-experiment shared runtime corpus (Table I)
//!   eval       — run the Table II / Fig. 5 harnesses
//!   serve      — run a C3O Hub speaking wire protocol v1 (DESIGN.md §4):
//!                repositories + server-side PredictionService with a
//!                fitted-model cache, served by a non-blocking reactor
//!                (every socket on one event loop) that dispatches frames
//!                to a bounded worker pool (--workers N CPU workers,
//!                --max-conns Q open sockets, --max-pipeline D in-flight
//!                requests per connection, --coalesce-window MS predict
//!                micro-batching; alias: `c3o hub`). Cold fits run on the
//!                fit-path engine: --fit-threads T CV workers (0 = all
//!                cores), --fit-budget SECS and/or --fit-points N
//!                selection budget (DESIGN.md §8).
//!                With --data-dir DIR the hub is *durable* (DESIGN.md §9):
//!                accepted contributions are WAL-logged before they are
//!                acknowledged, snapshots compact the logs
//!                (--snapshot-every N appends), crashes recover on the
//!                next start, and --fsync {always,interval,never} picks
//!                the durability/throughput trade-off.
//!                With --follow LEADER-ADDR the hub runs as a read-only
//!                *follower* (DESIGN.md §11): it bootstraps from the
//!                leader's snapshot, tails its WAL into the local state
//!                (and local --data-dir, making the follower itself
//!                durable), serves all read ops from the replicated
//!                corpus, and refuses submit_runs with a typed
//!                `not_leader` error naming the leader.
//!                Telemetry (DESIGN.md §13): --slow-ms N promotes requests
//!                slower than N ms end-to-end to a structured warn-level
//!                slow-request log line
//!   metrics    — fetch one telemetry snapshot from a running hub (the v1
//!                `metrics` op) and print it as Prometheus-style text:
//!                per-stage latency histograms (p50/p95/p99/max), cache and
//!                coalescing counters, transport gauges, replication lag
//!   configure  — pick a cluster configuration for a job (Fig. 4 workflow);
//!                fits locally from --data (same --fit-threads /
//!                --fit-budget / --fit-points knobs), or delegates to a
//!                hub with --hub ADDR (no local fit, served from the
//!                hub's cache). With --search-catalog the whole
//!                (machine type × scale-out) grid is searched — one
//!                fitted model per sufficiently-covered type — and the
//!                cost-optimal admissible configuration is returned with
//!                the ranked runtime/cost frontier (types below the data
//!                floor are reported as insufficient data)
//!   lint       — run the project-invariant static analyzer (DESIGN.md
//!                §12) over a source tree: lock-order with full-depth
//!                interprocedural propagation (L1), hot-path
//!                panic-freedom (L2), unsafe audit (L3), protocol
//!                exhaustiveness (L5), logging discipline (L6), wire
//!                taint tracking (L7), durability ordering incl. the
//!                old rename/sync_dir rule (L4/L8), allocation-free
//!                hot paths (L9). --fix-report appends per-rule
//!                remediation notes and the observed lock DAG;
//!                --format text|json|dot picks the output (json is the
//!                CI artifact, dot the Graphviz lock DAG).
//!                Exit 0 = clean; CI runs this blocking on rust/src
//!
//! Global flags: --log-level error|warn|info|debug sets the structured
//! logger's threshold (default info).
//!
//! Examples:
//!   c3o generate --out data/
//!   c3o eval table2 --splits 300
//!   c3o serve --addr 127.0.0.1:7033 --data data/
//!   c3o serve --addr 127.0.0.1:7033 --data-dir hub-state/ \
//!       --fsync interval --snapshot-every 64
//!   c3o serve --addr 127.0.0.1:7034 --data-dir follower-state/ \
//!       --follow 127.0.0.1:7033
//!   c3o configure --job kmeans --size 15 --ctx 5,0.001 \
//!       --deadline 900 --confidence 0.95 --data data/
//!   c3o configure --job kmeans --size 15 --ctx 5,0.001 \
//!       --deadline 900 --hub 127.0.0.1:7033
//!   c3o configure --job sort --size 15 --deadline 900 \
//!       --search-catalog --data data/
//!   c3o serve --addr 127.0.0.1:7033 --slow-ms 250 --log-level debug
//!   c3o metrics 127.0.0.1:7033
//!   c3o lint rust/src
//!   c3o lint --fix-report rust/src
//!   c3o lint --format json rust/src > lint-report.json
//!   c3o lint --format dot rust/src | dot -Tsvg > lock-dag.svg

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Context as _;

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::configurator::{
    configure_search, configure_with, CatalogSearch, ConfigChoice, TypeOutcome, UserGoals,
};
use c3o::cv::parallel::FitEngine;
use c3o::data::{Dataset, JobKind};
use c3o::eval::{self, Fig5Config, Table2Config};
use c3o::hub::{HubClient, HubServer, HubState, Repository, ServerConfig, ValidationPolicy};
use c3o::runtime::{Engine, FitBackend, NativeBackend};
use c3o::sim::{generate_all, GeneratorConfig, JobInput};
use c3o::storage::{DurableStore, StorageConfig};

fn parse_flags(args: &[String]) -> BTreeMap<String, String> {
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    flags
}

/// Pick the fit backend: PJRT artifacts when available, native otherwise.
fn backend(flags: &BTreeMap<String, String>) -> Arc<dyn FitBackend> {
    if flags.get("backend").map(|s| s.as_str()) == Some("native") {
        return Arc::new(NativeBackend::new());
    }
    match Engine::load_default() {
        Ok(e) => {
            eprintln!("[c3o] PJRT engine loaded from {}", e.artifact_dir().display());
            Arc::new(e)
        }
        Err(e) => {
            eprintln!("[c3o] PJRT artifacts unavailable ({e:#}); using native backend");
            Arc::new(NativeBackend::new())
        }
    }
}

/// Fit-path engine from `--fit-threads` / `--fit-budget` / `--fit-points`.
/// Default: all cores, unlimited budget.
fn fit_engine(flags: &BTreeMap<String, String>) -> anyhow::Result<FitEngine> {
    let mut engine = FitEngine::default();
    if let Some(t) = flags.get("fit-threads") {
        engine.threads = t.parse().context("--fit-threads")?;
    }
    if let Some(s) = flags.get("fit-budget") {
        engine.budget.max_seconds = Some(s.parse().context("--fit-budget")?);
    }
    if let Some(p) = flags.get("fit-points") {
        engine.budget.max_points = Some(p.parse().context("--fit-points")?);
    }
    Ok(engine)
}

fn load_datasets(dir: &Path) -> anyhow::Result<Vec<Dataset>> {
    let mut out = Vec::new();
    for job in JobKind::ALL {
        let path = dir.join(format!("{job}.tsv"));
        anyhow::ensure!(path.exists(), "missing {} — run `c3o generate` first", path.display());
        out.push(Dataset::load(job, &path)?);
    }
    Ok(out)
}

fn cmd_generate(flags: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let out: PathBuf = flags.get("out").cloned().unwrap_or_else(|| "data".into()).into();
    let seed = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0xC30);
    let cfg = GeneratorConfig { seed, ..Default::default() };
    let catalog = Catalog::aws_like();
    let datasets = generate_all(&cfg, &catalog)?;
    std::fs::create_dir_all(&out)?;
    println!("Table I census (930 unique experiments, median of 5 repetitions):");
    for ds in &datasets {
        ds.save(&out.join(format!("{}.tsv", ds.job)))?;
        println!(
            "  {:<9} {:>4} experiments, {} machine types, scale-outs {:?}",
            ds.job.to_string(),
            ds.len(),
            ds.machine_types().len(),
            ds.scale_outs()
        );
    }
    println!("wrote TSVs to {}", out.display());
    Ok(())
}

fn cmd_eval(args: &[String]) -> anyhow::Result<()> {
    let which = args.first().map(|s| s.as_str()).unwrap_or("table2");
    let flags = parse_flags(args);
    let backend = backend(&flags);
    let catalog = Catalog::aws_like();
    let raw: Vec<Dataset> = match flags.get("data") {
        Some(dir) => load_datasets(&PathBuf::from(dir))?,
        None => generate_all(&GeneratorConfig::default(), &catalog)?,
    };
    let datasets: Vec<Dataset> =
        raw.into_iter().map(|d| d.for_machine(eval::TARGET_MACHINE)).collect();
    match which {
        "table2" => {
            let splits = flags.get("splits").map(|s| s.parse()).transpose()?.unwrap_or(300);
            let cfg = Table2Config { splits, ..Default::default() };
            let result = eval::run_table2(&datasets, &cfg, &backend)?;
            println!("{}", eval::table2::render(&result));
        }
        "fig5" => {
            let splits = flags.get("splits").map(|s| s.parse()).transpose()?.unwrap_or(300);
            let cfg = Fig5Config { splits, ..Default::default() };
            for ds in &datasets {
                let r = eval::run_fig5(ds, &cfg, &backend)?;
                println!("{}", eval::fig5::render(&r));
            }
        }
        other => anyhow::bail!("unknown eval target: {other} (table2|fig5)"),
    }
    Ok(())
}

fn cmd_serve(flags: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7033".into());
    let state = Arc::new(HubState::new());
    for job in JobKind::ALL {
        let mut repo = Repository::new(job, &format!("standard Spark {job} implementation"));
        repo.maintainer_machine = Some(eval::TARGET_MACHINE.to_string());
        state.insert(repo);
    }
    // Durable mode (--data-dir): recover the latest snapshot + WAL tail,
    // then attach the store so every accepted submission is WAL-logged
    // before it is acknowledged (DESIGN.md §9).
    let mut store: Option<Arc<DurableStore>> = None;
    let mut recovered_jobs: Vec<JobKind> = Vec::new();
    if let Some(dir) = flags.get("data-dir") {
        let mut scfg = StorageConfig::default();
        if let Some(f) = flags.get("fsync") {
            scfg.fsync = f.parse()?;
        }
        if let Some(n) = flags.get("snapshot-every") {
            scfg.snapshot_every = n.parse().context("--snapshot-every")?;
        }
        let (s, recovered) = DurableStore::open(&PathBuf::from(dir), scfg)?;
        if s.torn_tails() > 0 {
            eprintln!(
                "[c3o] truncated {} torn WAL tail(s) left by a previous crash",
                s.torn_tails()
            );
        }
        for r in recovered {
            eprintln!(
                "[c3o] recovered {}: {} records at revision {} ({} WAL record(s) replayed)",
                r.job,
                r.data.len(),
                r.revision,
                r.replayed
            );
            // Only repos with real recovered state suppress TSV seeding:
            // a baseline snapshot of a still-empty revision-0 repo must
            // not block a later `--data` seed forever.
            if r.revision > 0 || !r.data.is_empty() {
                recovered_jobs.push(r.job);
            }
            state.install_recovered(r);
        }
        store = Some(Arc::new(s));
    }
    if let Some(dir) = flags.get("data") {
        // Seed TSVs fill only repos the durable store did not recover —
        // recovered state is newer than any seed by construction.
        let n = state.load_except(&PathBuf::from(dir), &recovered_jobs)?;
        eprintln!("[c3o] loaded {n} repositories from {dir}");
    }
    if let Some(store) = &store {
        // Baseline snapshot — but only when registration or seeding
        // actually produced state the store does not cover yet (the same
        // predicate set_storage refuses on). After a graceful shutdown
        // (final compacted snapshot) a restart would otherwise pay a
        // full-corpus rewrite for zero added durability.
        if state.first_uncovered(store).is_some() {
            state.snapshot_to(store)?;
        }
        state.set_storage(store.clone())?;
    }
    // Transport + fit-engine tuning: defaults derive from available
    // parallelism; --workers/--max-conns/--max-pipeline/--coalesce-window/
    // --fit-threads/--fit-budget/--fit-points override.
    let mut config = ServerConfig::default();
    if let Some(w) = flags.get("workers") {
        config.workers = w.parse().context("--workers")?;
    }
    if let Some(q) = flags.get("max-conns") {
        config.max_conns = q.parse().context("--max-conns")?;
    }
    if let Some(p) = flags.get("max-pipeline") {
        config.max_pipeline = p.parse().context("--max-pipeline")?;
    }
    if let Some(ms) = flags.get("coalesce-window") {
        config.coalesce_window =
            std::time::Duration::from_millis(ms.parse().context("--coalesce-window")?);
    }
    if let Some(ms) = flags.get("slow-ms") {
        config.slow_ms = ms.parse().context("--slow-ms")?;
    }
    let engine = fit_engine(flags)?;
    config.fit_threads = engine.threads;
    config.fit_budget = engine.budget;
    // `start_with` installs `config.fit_engine()` on the service.
    let service = Arc::new(PredictionService::new(
        state,
        Catalog::aws_like(),
        ValidationPolicy::default(),
        backend(flags),
    ));
    // Follower mode: mark the service read-only *before* serving, so no
    // submit can slip in ahead of the first replication pass.
    if let Some(leader) = flags.get("follow") {
        service.set_follower_of(leader.clone());
    }
    let mut server = HubServer::start_with(&addr, service, config.clone())?;
    if let Some(leader) = flags.get("follow") {
        let tailer = c3o::replication::Tailer::start(
            server.service().clone(),
            c3o::replication::FollowerConfig::new(leader.clone()),
        );
        server.attach_tailer(tailer);
    }
    // NOTE: keep the addr as the last token of the first line — clients
    // (and tests/cli_e2e.rs) parse it from there.
    println!("C3O Hub listening on {}", server.addr);
    println!(
        "transport: reactor ({}) + {} workers, {} open connections max, \
         pipeline depth {}, coalescing {}",
        c3o::hub::transport::Poller::default_backend_name(),
        config.workers,
        config.max_conns,
        config.max_pipeline,
        if config.coalesce_window.is_zero() {
            "off".to_string()
        } else {
            format!("{:?} window", config.coalesce_window)
        },
    );
    println!(
        "fit engine: {} CV threads, budget {}s / {} points",
        if config.fit_threads == 0 { "all".to_string() } else { config.fit_threads.to_string() },
        config
            .fit_budget
            .max_seconds
            .map_or_else(|| "∞".to_string(), |s| format!("{s}")),
        config
            .fit_budget
            .max_points
            .map_or_else(|| "∞".to_string(), |p| format!("{p}")),
    );
    match &store {
        Some(store) => println!(
            "durability: data dir {} (fsync {}, snapshot every {} appends)",
            store.dir().display(),
            store.config().fsync,
            match store.config().snapshot_every {
                0 => "∞".to_string(),
                n => n.to_string(),
            },
        ),
        None => println!("durability: OFF (in-memory only; pass --data-dir to persist)"),
    }
    match flags.get("follow") {
        Some(leader) => println!(
            "replication: FOLLOWER of {leader} (read-only; submit_runs → not_leader)"
        ),
        None => println!("replication: leader-capable (repl ops require --data-dir)"),
    }
    println!(
        "telemetry: stage histograms + request traces on (`c3o metrics {addr}`), \
         slow-request log {}",
        match config.slow_ms {
            0 => "off (pass --slow-ms N to enable)".to_string(),
            ms => format!("at {ms} ms"),
        },
    );
    println!(
        "ops (v1): list_repos | get_repo | submit_runs | catalog | stats | \
         metrics | predict | predict_batch | configure | configure_search | \
         repl_subscribe | repl_fetch | repl_snapshot | shutdown"
    );
    // Serve until stdin closes (or forever under a service manager).
    let mut buf = String::new();
    let _ = std::io::stdin().read_line(&mut buf);
    server.shutdown();
    Ok(())
}

fn cmd_configure(flags: &BTreeMap<String, String>) -> anyhow::Result<()> {
    let job: JobKind = flags
        .get("job")
        .ok_or_else(|| anyhow::anyhow!("--job required"))?
        .parse()?;
    let size: f64 = flags
        .get("size")
        .ok_or_else(|| anyhow::anyhow!("--size required (GB)"))?
        .parse()?;
    let ctx: Vec<f64> = match flags.get("ctx") {
        Some(s) if !s.is_empty() => s
            .split(',')
            .map(|p| p.parse::<f64>())
            .collect::<Result<_, _>>()?,
        _ => vec![],
    };
    let goals = UserGoals {
        deadline_s: flags.get("deadline").map(|s| s.parse()).transpose()?,
        confidence: flags.get("confidence").map(|s| s.parse()).transpose()?.unwrap_or(0.95),
    };

    // Catalog-wide mode: search the full (machine type × scale-out) grid
    // instead of pinning one §IV-A type (--machine is ignored here).
    if flags.contains_key("search-catalog") {
        let search = match flags.get("hub") {
            Some(addr) => {
                // The hub evaluates the grid from its fitted-model cache;
                // a warm hub answers the whole catalog with zero refits.
                let mut client = HubClient::connect(addr)?;
                client.configure_search(job, size, ctx, &goals)?
            }
            None => {
                let catalog = Catalog::aws_like();
                let shared = load_shared(flags, job, &catalog)?;
                let backend = backend(flags);
                let input = JobInput::new(job, size, ctx);
                configure_search(&catalog, &shared, &input, &goals, backend, &fit_engine(flags)?)?
            }
        };
        print_search(job, size, &search);
        return Ok(());
    }

    let choice = match flags.get("hub") {
        Some(addr) => {
            // Hub mode: the server answers from its fitted-model cache —
            // no runtime data is downloaded and nothing is fitted locally.
            let mut client = HubClient::connect(addr)?;
            client.configure(
                job,
                size,
                ctx,
                &goals,
                flags.get("machine").map(|s| s.as_str()),
            )?
        }
        None => {
            let catalog = Catalog::aws_like();
            let shared = load_shared(flags, job, &catalog)?;
            let backend = backend(flags);
            let input = JobInput::new(job, size, ctx);
            configure_with(
                &catalog,
                &shared,
                flags.get("machine").map(|s| s.as_str()).or(Some(eval::TARGET_MACHINE)),
                &input,
                &goals,
                backend,
                &fit_engine(flags)?,
            )?
        }
    };
    print_choice(job, size, &choice);
    Ok(())
}

/// The job's shared runtime dataset: `--data DIR/<job>.tsv`, or the
/// in-memory generated corpus when no directory is given.
fn load_shared(
    flags: &BTreeMap<String, String>,
    job: JobKind,
    catalog: &Catalog,
) -> anyhow::Result<Dataset> {
    match flags.get("data") {
        Some(dir) => Dataset::load(job, &PathBuf::from(dir).join(format!("{job}.tsv"))),
        None => {
            eprintln!("[c3o] no --data dir; generating the shared corpus in-memory");
            c3o::sim::generate_job(job, &GeneratorConfig::default(), catalog)
        }
    }
}

fn print_search(job: JobKind, size: f64, search: &CatalogSearch) {
    print_choice(job, size, &search.choice);
    println!("\n  per machine type (catalog-wide §IV grid):");
    for t in &search.types {
        match &t.outcome {
            TypeOutcome::Evaluated { model, options, pick } => match pick {
                Some(s) => {
                    let cost = options
                        .iter()
                        .find(|o| o.scale_out == *s)
                        .map_or(f64::NAN, |o| o.cost_usd);
                    println!(
                        "    {:<12} {model:<6} pick s={s:<3} cost ${cost:.3}",
                        t.machine_type
                    );
                }
                None => println!("    {:<12} no admissible scale-out", t.machine_type),
            },
            TypeOutcome::InsufficientData { required } => println!(
                "    {:<12} insufficient data ({} run(s), need {required})",
                t.machine_type, t.runs
            ),
            TypeOutcome::Failed { error } => {
                println!("    {:<12} failed: {error}", t.machine_type)
            }
        }
    }
    println!("\n  cost-ranked frontier (top 10 of {}):", search.frontier.len());
    for (i, f) in search.frontier.iter().take(10).enumerate() {
        println!(
            "    {:>2}. {:<12} s={:<3} t={:>7.0}s ucb={:>7.0}s cost=${:<8.3}{}",
            i + 1,
            f.machine_type,
            f.scale_out,
            f.predicted_runtime_s,
            f.runtime_ucb_s,
            f.cost_usd,
            if f.bottleneck { "  [memory bottleneck]" } else { "" },
        );
    }
}

fn print_choice(job: JobKind, size: f64, choice: &ConfigChoice) {
    println!("chosen configuration for {job} ({size} GB):");
    println!("  machine type : {}", choice.machine_type);
    println!("  scale-out    : {} nodes", choice.scale_out);
    println!(
        "  est. runtime : {:.0} s (UCB {:.0} s)",
        choice.predicted_runtime_s, choice.runtime_ucb_s
    );
    println!("  est. cost    : ${:.3}", choice.est_cost_usd);
    println!("\n  runtime/cost pairs per scale-out (§IV-B):");
    for o in &choice.options {
        println!(
            "    s={:<3} t={:>7.0}s ucb={:>7.0}s cost=${:<8.3}{}{}",
            o.scale_out,
            o.predicted_runtime_s,
            o.runtime_ucb_s,
            o.cost_usd,
            if o.bottleneck { "  [memory bottleneck]" } else { "" },
            match o.admissible {
                Some(true) => "  [admissible]",
                Some(false) => "",
                None => "",
            }
        );
    }
}

/// `c3o metrics [ADDR]` — fetch one telemetry snapshot from a running
/// hub (the v1 `metrics` op) and print it as Prometheus-style text.
fn cmd_metrics(rest: &[String], flags: &BTreeMap<String, String>) -> anyhow::Result<()> {
    // First positional arg, skipping `--flag value` pairs the same way
    // `parse_flags` consumes them.
    let positional = || {
        let mut i = 0;
        while i < rest.len() {
            let arg = &rest[i];
            if arg.starts_with("--") {
                let has_value = i + 1 < rest.len() && !rest[i + 1].starts_with("--");
                i += if has_value { 2 } else { 1 };
            } else {
                return Some(arg.clone());
            }
        }
        None
    };
    let addr = flags
        .get("addr")
        .cloned()
        .or_else(positional)
        .unwrap_or_else(|| "127.0.0.1:7033".into());
    let mut client = HubClient::connect(&addr)
        .with_context(|| format!("connecting to hub at {addr}"))?;
    let payload = client.metrics()?;
    print!("{}", payload.render_prometheus());
    Ok(())
}

/// `c3o lint [--fix-report] [--format text|json|dot] <src-dir>` — run
/// the project-invariant static analyzer (DESIGN.md §12) over a source
/// tree. Exits 0 when the tree is clean, 1 with findings otherwise
/// (`--format dot` always exits 0 — it is a graph dump, not a gate).
fn cmd_lint(rest: &[String]) -> anyhow::Result<()> {
    let mut fix_report = false;
    let mut format = "text";
    let mut dir: Option<&str> = None;
    let mut it = rest.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--fix-report" => fix_report = true,
            "--format" => {
                let v = it.next().context("--format needs text|json|dot")?;
                match v.as_str() {
                    "text" | "json" | "dot" => format = v.as_str(),
                    other => anyhow::bail!("unknown lint format {other} (text|json|dot)"),
                }
            }
            other if !other.starts_with("--") => dir = Some(other),
            other => anyhow::bail!("unknown lint flag {other}"),
        }
    }
    let root = PathBuf::from(dir.unwrap_or("rust/src"));
    let report = c3o::analysis::lint_dir(&root)
        .with_context(|| format!("linting {}", root.display()))?;
    match format {
        "json" => print!("{}", c3o::analysis::render_json(&report, &root)),
        "dot" => {
            print!("{}", c3o::analysis::render_dot(&report));
            return Ok(());
        }
        _ => print!("{}", c3o::analysis::render(&report, &root, fix_report)),
    }
    if !report.findings.is_empty() {
        std::process::exit(1);
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = args.iter().skip(1).cloned().collect();
    let flags = parse_flags(&rest);
    if let Some(lv) = flags.get("log-level") {
        match c3o::obs::log::Level::parse(lv) {
            Some(level) => c3o::obs::log::set_level(level),
            None => {
                eprintln!("error: --log-level must be error|warn|info|debug (got {lv})");
                std::process::exit(2);
            }
        }
    }
    let result = match cmd {
        "generate" => cmd_generate(&flags),
        "eval" => cmd_eval(&rest),
        "serve" | "hub" => cmd_serve(&flags),
        "configure" => cmd_configure(&flags),
        "metrics" => cmd_metrics(&rest, &flags),
        "lint" => cmd_lint(&rest),
        _ => {
            eprintln!(
                "usage: c3o <generate|eval|serve|configure|metrics|lint> [flags]\n\
                 see rust/src/main.rs header for examples"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
