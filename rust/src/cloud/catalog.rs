//! Machine-type catalog with EMR-like offerings.
//!
//! Coefficients are relative to a baseline "1.0" general-purpose node; the
//! workload simulator composes them into runtimes, so what matters is their
//! *ratios* (compute-heavy types run CPU-bound jobs faster, memory types
//! move the spill cliff, I/O types speed up scans), mirroring how machine
//! type choice behaves in the paper's data.

use anyhow::bail;

/// One virtual machine offering.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineType {
    /// Provider name, e.g. "m5.xlarge".
    pub name: String,
    pub vcpus: u32,
    pub memory_gb: f64,
    /// Relative CPU throughput per vcpu (baseline 1.0).
    pub cpu_factor: f64,
    /// Relative disk+network scan bandwidth (baseline 1.0).
    pub io_factor: f64,
    /// On-demand price per node-hour, USD.
    pub price_per_hour: f64,
    /// Marketing family: general | compute | memory | storage.
    pub family: &'static str,
}

impl MachineType {
    /// Price per node-second.
    pub fn price_per_second(&self) -> f64 {
        self.price_per_hour / 3600.0
    }
}

/// The catalog the configurator iterates over.
#[derive(Debug, Clone)]
pub struct Catalog {
    types: Vec<MachineType>,
    /// Cluster provisioning delay (paper: "seven or more minutes" on EMR).
    pub provisioning_delay_s: f64,
    /// Scale-outs offered to the configurator.
    pub scale_outs: Vec<u32>,
}

impl Catalog {
    /// The default EMR-like catalog used across the evaluation.
    pub fn aws_like() -> Catalog {
        let t = |name: &str, vcpus, memory_gb, cpu, io, price, family| MachineType {
            name: name.to_string(),
            vcpus,
            memory_gb,
            cpu_factor: cpu,
            io_factor: io,
            price_per_hour: price,
            family,
        };
        Catalog {
            types: vec![
                t("m5.xlarge", 4, 16.0, 1.00, 1.00, 0.192, "general"),
                t("m5.2xlarge", 8, 32.0, 1.00, 1.15, 0.384, "general"),
                t("c5.xlarge", 4, 8.0, 1.45, 1.00, 0.170, "compute"),
                t("c5.2xlarge", 8, 16.0, 1.45, 1.15, 0.340, "compute"),
                t("r5.xlarge", 4, 32.0, 1.00, 1.00, 0.252, "memory"),
                t("r5.2xlarge", 8, 64.0, 1.00, 1.15, 0.504, "memory"),
                t("i3.xlarge", 4, 30.5, 0.95, 2.10, 0.312, "storage"),
            ],
            provisioning_delay_s: 7.0 * 60.0,
            scale_outs: (2..=12).collect(),
        }
    }

    /// Build a custom catalog — alternative providers, or tests that need
    /// degenerate offerings (empty type lists, absurd prices) to exercise
    /// the configurator's error paths. `scale_outs` is the grid the
    /// configurator evaluates.
    pub fn custom(
        types: Vec<MachineType>,
        provisioning_delay_s: f64,
        scale_outs: Vec<u32>,
    ) -> Catalog {
        Catalog { types, provisioning_delay_s, scale_outs }
    }

    pub fn types(&self) -> &[MachineType] {
        &self.types
    }

    pub fn get(&self, name: &str) -> crate::Result<&MachineType> {
        match self.types.iter().find(|t| t.name == name) {
            Some(t) => Ok(t),
            None => bail!("unknown machine type: {name}"),
        }
    }

    /// General-purpose types — the §IV-A fallback when maintainers have not
    /// designated a machine type yet.
    pub fn general_purpose(&self) -> Vec<&MachineType> {
        self.types.iter().filter(|t| t.family == "general").collect()
    }

    /// Job cost for a (type, scale-out, runtime) triple: the paper's
    /// "operating cost x execution time x scale-out".
    pub fn job_cost(&self, mt: &MachineType, scale_out: u32, runtime_s: f64) -> f64 {
        mt.price_per_second() * scale_out as f64 * runtime_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_all_families() {
        let c = Catalog::aws_like();
        for fam in ["general", "compute", "memory", "storage"] {
            assert!(c.types().iter().any(|t| t.family == fam), "{fam}");
        }
    }

    #[test]
    fn lookup_and_missing() {
        let c = Catalog::aws_like();
        assert_eq!(c.get("m5.xlarge").unwrap().vcpus, 4);
        assert!(c.get("z9.mega").is_err());
    }

    #[test]
    fn provisioning_delay_at_least_seven_minutes() {
        // Paper §I: EMR provisioning delays of seven or more minutes.
        assert!(Catalog::aws_like().provisioning_delay_s >= 7.0 * 60.0);
    }

    #[test]
    fn price_scales_with_size_within_family() {
        let c = Catalog::aws_like();
        assert!(
            c.get("m5.2xlarge").unwrap().price_per_hour
                > c.get("m5.xlarge").unwrap().price_per_hour
        );
    }

    #[test]
    fn job_cost_formula() {
        let c = Catalog::aws_like();
        let mt = c.get("m5.xlarge").unwrap();
        // 10 nodes, 1 hour => 10 * hourly price.
        let cost = c.job_cost(mt, 10, 3600.0);
        assert!((cost - 1.92).abs() < 1e-9);
    }

    #[test]
    fn general_purpose_fallback_nonempty() {
        assert!(!Catalog::aws_like().general_purpose().is_empty());
    }

    #[test]
    fn custom_catalog_round_trips_fields() {
        let mt = MachineType {
            name: "x1.test".into(),
            vcpus: 2,
            memory_gb: 4.0,
            cpu_factor: 1.0,
            io_factor: 1.0,
            price_per_hour: 0.1,
            family: "general",
        };
        let c = Catalog::custom(vec![mt], 60.0, vec![2, 4]);
        assert_eq!(c.types().len(), 1);
        assert_eq!(c.get("x1.test").unwrap().vcpus, 2);
        assert_eq!(c.scale_outs, vec![2, 4]);
        assert_eq!(c.provisioning_delay_s, 60.0);
        let empty = Catalog::custom(vec![], 0.0, vec![]);
        assert!(empty.types().is_empty());
        assert!(empty.get("x1.test").is_err());
    }
}
