//! Public-cloud substrate: machine-type catalog, pricing, provisioning.
//!
//! Stands in for the AWS/EMR environment of the paper (§II-C): the
//! configurator consults the catalog for candidate machine types and
//! prices; the execution simulator charges per node-second and imposes the
//! multi-minute provisioning delay the paper's introduction calls out.

pub mod catalog;
pub mod cluster;

pub use catalog::{Catalog, MachineType};
pub use cluster::{ClusterConfig, ClusterLease, CloudProvider};
