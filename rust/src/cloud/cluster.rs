//! Cluster lifecycle against the simulated provider: reserve → run → tear
//! down, with per-node-second billing and provisioning delay. This is the
//! §II-C "co-located analytics cluster" whose lifecycle C3O streamlines.

use std::sync::Mutex;

use anyhow::bail;

use super::catalog::{Catalog, MachineType};

/// A requested cluster shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub machine_type: String,
    pub scale_out: u32,
}

/// A provisioned cluster (simulated). Dropping it without `tear_down` is a
/// bug the provider surfaces via `leaked_clusters`.
#[derive(Debug)]
pub struct ClusterLease {
    pub id: u64,
    pub config: ClusterConfig,
    pub provisioned_after_s: f64,
}

/// Simulated public-cloud provider: hands out leases and accumulates cost.
#[derive(Debug)]
pub struct CloudProvider {
    catalog: Catalog,
    state: Mutex<ProviderState>,
}

#[derive(Debug, Default)]
struct ProviderState {
    next_id: u64,
    active: Vec<u64>,
    total_cost_usd: f64,
    total_cluster_seconds: f64,
    leaked: u64,
}

impl CloudProvider {
    pub fn new(catalog: Catalog) -> Self {
        CloudProvider { catalog, state: Mutex::new(ProviderState::default()) }
    }

    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// Reserve a cluster. Fails on unknown machine type or zero nodes.
    pub fn provision(&self, config: &ClusterConfig) -> crate::Result<ClusterLease> {
        if config.scale_out == 0 {
            bail!("cannot provision a 0-node cluster");
        }
        self.catalog.get(&config.machine_type)?; // validate
        let mut st = self.state.lock().unwrap();
        st.next_id += 1;
        let id = st.next_id;
        st.active.push(id);
        Ok(ClusterLease {
            id,
            config: config.clone(),
            provisioned_after_s: self.catalog.provisioning_delay_s,
        })
    }

    /// Tear down after a run of `runtime_s`; returns the billed cost.
    /// Billing covers runtime plus the provisioning delay (EMR bills from
    /// cluster start, not job start).
    pub fn tear_down(&self, lease: ClusterLease, runtime_s: f64) -> crate::Result<f64> {
        let mt: &MachineType = self.catalog.get(&lease.config.machine_type)?;
        let billed_s = runtime_s + lease.provisioned_after_s;
        let cost = mt.price_per_second() * lease.config.scale_out as f64 * billed_s;
        let mut st = self.state.lock().unwrap();
        match st.active.iter().position(|&id| id == lease.id) {
            Some(pos) => {
                st.active.swap_remove(pos);
            }
            None => bail!("double tear-down of cluster {}", lease.id),
        }
        st.total_cost_usd += cost;
        st.total_cluster_seconds += billed_s * lease.config.scale_out as f64;
        Ok(cost)
    }

    /// Record a leaked lease (used by tests/failure injection).
    pub fn report_leak(&self) {
        self.state.lock().unwrap().leaked += 1;
    }

    pub fn active_clusters(&self) -> usize {
        self.state.lock().unwrap().active.len()
    }

    pub fn total_cost_usd(&self) -> f64 {
        self.state.lock().unwrap().total_cost_usd
    }

    pub fn total_cluster_seconds(&self) -> f64 {
        self.state.lock().unwrap().total_cluster_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn provider() -> CloudProvider {
        CloudProvider::new(Catalog::aws_like())
    }

    #[test]
    fn provision_and_teardown_bills_cost() {
        let p = provider();
        let lease = p
            .provision(&ClusterConfig { machine_type: "m5.xlarge".into(), scale_out: 4 })
            .unwrap();
        assert_eq!(p.active_clusters(), 1);
        let cost = p.tear_down(lease, 3600.0).unwrap();
        // 4 nodes x (3600 + 420) s x 0.192/3600 $/s
        let expect = 4.0 * (3600.0 + 420.0) * 0.192 / 3600.0;
        assert!((cost - expect).abs() < 1e-9, "cost={cost}");
        assert_eq!(p.active_clusters(), 0);
        assert!((p.total_cost_usd() - expect).abs() < 1e-9);
    }

    #[test]
    fn rejects_unknown_type_and_zero_nodes() {
        let p = provider();
        assert!(p
            .provision(&ClusterConfig { machine_type: "nope".into(), scale_out: 2 })
            .is_err());
        assert!(p
            .provision(&ClusterConfig { machine_type: "m5.xlarge".into(), scale_out: 0 })
            .is_err());
    }

    #[test]
    fn double_teardown_rejected() {
        let p = provider();
        let cfg = ClusterConfig { machine_type: "c5.xlarge".into(), scale_out: 2 };
        let lease = p.provision(&cfg).unwrap();
        let fake = ClusterLease { id: lease.id, config: cfg, provisioned_after_s: 0.0 };
        p.tear_down(lease, 10.0).unwrap();
        assert!(p.tear_down(fake, 10.0).is_err());
    }

    #[test]
    fn concurrent_provisioning_is_safe() {
        let p = std::sync::Arc::new(provider());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let lease = p
                        .provision(&ClusterConfig {
                            machine_type: "m5.xlarge".into(),
                            scale_out: 2,
                        })
                        .unwrap();
                    p.tear_down(lease, 60.0).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(p.active_clusters(), 0);
        // 8 threads x 50 runs, cost strictly positive and consistent.
        let one = 2.0 * (60.0 + 420.0) * 0.192 / 3600.0;
        assert!((p.total_cost_usd() - 400.0 * one).abs() < 1e-6);
    }
}
