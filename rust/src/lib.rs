//! # C3O — Collaborative Cluster Configuration Optimization
//!
//! Rust + JAX + Pallas reproduction of *"C3O: Collaborative Cluster
//! Configuration Optimization for Distributed Data Processing in Public
//! Clouds"* (Will et al., IEEE IC2E 2021).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): masked-Gram and
//!   batched-predict, the normal-equation hot spot behind cross-validation.
//! * **L2** — JAX estimator graphs (`python/compile/model.py`): batched
//!   ridge-OLS, batched NNLS, configurator prediction sweep; AOT-lowered to
//!   HLO text in `artifacts/`.
//! * **L3** — this crate: the C3O system itself. Runtime-data simulator
//!   (standing in for the paper's 930 Amazon-EMR Spark runs), the runtime
//!   predictor with dynamic model selection, the erf-confidence cluster
//!   configurator, and the collaborative C3O Hub with contribution
//!   validation. Python never runs on the request path: the [`runtime`]
//!   module executes the AOT artifacts through PJRT.
//!
//! See `DESIGN.md` for the module inventory and the experiment index.

pub mod analysis;
pub mod api;
pub mod bench;
pub mod cloud;
pub mod configurator;
pub mod cv;
pub mod data;
pub mod eval;
pub mod hub;
pub mod linalg;
pub mod models;
pub mod obs;
pub mod replication;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
