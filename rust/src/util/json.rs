//! Minimal JSON value + parser + writer for the hub wire protocol.
//!
//! The offline crate cache has no `serde`/`serde_json`; the hub speaks
//! newline-delimited JSON objects, so a compact recursive-descent parser is
//! all that is needed. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (sufficient for the protocol, which is ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::bail;

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> crate::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }
}

/// Compact single-line serialization (callers use the blanket
/// `ToString::to_string`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'n' => lit(b, pos, "null", Json::Null),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(v));
                    }
                    _ => bail!("expected ',' or ']' at byte {pos}", pos = *pos),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    bail!("expected ':' at byte {pos}", pos = *pos);
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => bail!("expected ',' or '}}' at byte {pos}", pos = *pos),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => bail!("unexpected byte {c:#x} at {pos}", pos = *pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> crate::Result<Json> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        bail!("invalid literal at byte {pos}", pos = *pos)
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> crate::Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at byte {pos}", pos = *pos);
    }
    *pos += 1;
    let mut s = String::new();
    loop {
        match b.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(s);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if *pos + 4 >= b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => bail!("bad escape at byte {pos}", pos = *pos),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar.
                let rest = std::str::from_utf8(&b[*pos..])?;
                let c = rest.chars().next().unwrap();
                s.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(text.parse::<f64>()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_object() {
        let j = Json::obj(vec![
            ("op", Json::Str("submit".into())),
            ("count", Json::Num(3.0)),
            ("ok", Json::Bool(true)),
            ("xs", Json::Arr(vec![Json::Num(1.5), Json::Null])),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":{"b":[1,2,{"c":null}]},"d":-3.5e2}"#).unwrap();
        assert_eq!(j.get("d").unwrap().as_f64(), Some(-350.0));
        assert!(j.get("a").unwrap().get("b").is_some());
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""line\nwith \"quotes\" and A""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nwith \"quotes\" and A"));
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn deterministic_key_order() {
        let j = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(j.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn unicode_content() {
        let j = Json::Str("ünïcödé ✓".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
