//! Scoped-thread parallel map (no rayon in the offline cache).
//!
//! Work-steals over an atomic index so uneven item costs balance out;
//! results land in order. Used by the evaluation harness (300 CV splits
//! per Table-II cell), the hub's validation pipeline, and the fit-path
//! execution engine (`cv::parallel`), which feeds it one flat
//! candidate × split task list so candidate- and split-level parallelism
//! share a single pool instead of nesting scopes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Parallel map with `threads` workers (0 ⇒ available parallelism).
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(n);

    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<U>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i, &items[i]);
                *out[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 4, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map(&items, 1, |_, &x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u8> = vec![];
        assert!(par_map(&items, 4, |_, &x| x).is_empty());
    }

    #[test]
    fn index_argument_matches_position() {
        let items = vec![10, 20, 30];
        let out = par_map(&items, 2, |i, &x| (i, x));
        assert_eq!(out, vec![(0, 10), (1, 20), (2, 30)]);
    }

    #[test]
    fn uneven_work_balances() {
        let items: Vec<usize> = (0..64).collect();
        let out = par_map(&items, 8, |_, &x| {
            if x % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            x
        });
        assert_eq!(out, items);
    }
}
