//! TSV codec. The paper (§VI-A) organizes runtime data as TSV with the
//! machine type and instance count first and job-specific context features
//! at the end; `crate::data` uses this module for the on-disk format.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context};

/// A parsed TSV table: one header row and data rows of equal arity.
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: Vec<String>) -> Self {
        Table { header, rows: Vec::new() }
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    pub fn push_row(&mut self, row: Vec<String>) -> crate::Result<()> {
        if row.len() != self.header.len() {
            bail!(
                "row arity {} != header arity {}",
                row.len(),
                self.header.len()
            );
        }
        self.rows.push(row);
        Ok(())
    }

    /// Parse TSV text. Lines starting with '#' are comments; blank lines
    /// are skipped. The first non-comment line is the header.
    pub fn parse(text: &str) -> crate::Result<Table> {
        let mut header: Option<Vec<String>> = None;
        let mut rows = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim_end_matches('\r');
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<String> =
                line.split('\t').map(|s| s.to_string()).collect();
            match &header {
                None => header = Some(fields),
                Some(h) => {
                    if fields.len() != h.len() {
                        bail!(
                            "line {}: arity {} != header arity {}",
                            lineno + 1,
                            fields.len(),
                            h.len()
                        );
                    }
                    rows.push(fields);
                }
            }
        }
        let header = header.context("empty TSV: no header")?;
        Ok(Table { header, rows })
    }

    /// Serialize back to TSV text (tab-free fields enforced).
    pub fn to_text(&self) -> crate::Result<String> {
        let mut out = String::new();
        for field in self.header.iter().chain(self.rows.iter().flatten()) {
            if field.contains('\t') || field.contains('\n') {
                bail!("TSV field contains tab/newline: {field:?}");
            }
        }
        out.push_str(&self.header.join("\t"));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        Ok(out)
    }

    pub fn read(path: &Path) -> crate::Result<Table> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Table::parse(&text)
    }

    pub fn write(&self, path: &Path) -> crate::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_text()?)
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Typed accessor: parse cell as f64.
    pub fn f64(&self, row: usize, col: usize) -> crate::Result<f64> {
        self.rows[row][col]
            .parse::<f64>()
            .with_context(|| format!("row {row} col {col}: not a number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let text = "a\tb\tc\n1\t2.5\tx\n3\t4\ty\n";
        let t = Table::parse(text).unwrap();
        assert_eq!(t.header, vec!["a", "b", "c"]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.to_text().unwrap(), text);
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let t = Table::parse("# hi\n\na\tb\n# mid\n1\t2\n").unwrap();
        assert_eq!(t.rows, vec![vec!["1", "2"]]);
    }

    #[test]
    fn arity_mismatch_rejected() {
        assert!(Table::parse("a\tb\n1\n").is_err());
    }

    #[test]
    fn empty_rejected() {
        assert!(Table::parse("# only comments\n").is_err());
    }

    #[test]
    fn col_lookup() {
        let t = Table::parse("x\ty\n1\t2\n").unwrap();
        assert_eq!(t.col("y"), Some(1));
        assert_eq!(t.col("z"), None);
    }

    #[test]
    fn tab_in_field_rejected_on_write() {
        let mut t = Table::new(vec!["a".into()]);
        t.push_row(vec!["has\ttab".into()]).unwrap();
        assert!(t.to_text().is_err());
    }

    #[test]
    fn typed_accessor() {
        let t = Table::parse("v\n2.25\n").unwrap();
        assert_eq!(t.f64(0, 0).unwrap(), 2.25);
    }
}
