//! Foundational utilities: deterministic PRNG, statistics, the Gauss error
//! function pair used by the configurator, TSV/JSON codecs, and a seeded
//! property-testing driver (the offline crate cache has no `rand`, `serde`
//! or `proptest`; these are small, tested, behaviour-compatible stand-ins —
//! see DESIGN.md §2).

pub mod erf;
pub mod json;
pub mod par;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod tsv;
