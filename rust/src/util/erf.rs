//! Gauss error function pair for the configurator's confidence math.
//!
//! Paper §IV-B:  ŝ = min { s | t_s + (μ + erf⁻¹(2c−1)·√2·σ) ≤ t_max }.
//! With c = 0.95 the multiplier erf⁻¹(2·0.95−1)·√2 = Φ⁻¹(0.95) ≈ 1.64485,
//! the rounded value the paper quotes — tested below.
//!
//! * `erf` — Abramowitz & Stegun 7.1.26-style rational approximation
//!   refined to double precision (max abs error < 1.2e-7 is A&S; we use the
//!   higher-order expansion with error < 1e-12 on |x| <= 6).
//! * `probit` — Acklam's inverse normal CDF with one Halley refinement step
//!   (relative error < 1e-9 over (0,1)).
//! * `erf_inv(x) = probit((x+1)/2) / √2`.

/// Error function, double precision.
///
/// Uses the complementary-error-function expansion of W. J. Cody's rational
/// approximations as popularized in Numerical Recipes (`erfc` with a
/// Chebyshev fit), accurate to ~1e-12 after symmetry reduction.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 2.0 / (2.0 + z);
    let ty = 4.0 * t - 2.0;
    // Chebyshev coefficients (Numerical Recipes 3rd ed., erfc_chebyshev).
    const COF: [f64; 28] = [
        -1.3026537197817094,
        6.4196979235649026e-1,
        1.9476473204185836e-2,
        -9.561514786808631e-3,
        -9.46595344482036e-4,
        3.66839497852761e-4,
        4.2523324806907e-5,
        -2.0278578112534e-5,
        -1.624290004647e-6,
        1.303655835580e-6,
        1.5626441722e-8,
        -8.5238095915e-8,
        6.529054439e-9,
        5.059343495e-9,
        -9.91364156e-10,
        -2.27365122e-10,
        9.6467911e-11,
        2.394038e-12,
        -6.886027e-12,
        8.94487e-13,
        3.13092e-13,
        -1.12708e-13,
        3.81e-16,
        7.106e-15,
        -1.523e-15,
        -9.4e-17,
        1.21e-16,
        -2.8e-17,
    ];
    let mut d = 0.0;
    let mut dd = 0.0;
    for &c in COF.iter().skip(1).rev() {
        let tmp = d;
        d = ty * d - dd + c;
        dd = tmp;
    }
    let ans = t * (-z * z + 0.5 * (COF[0] + ty * d) - dd).exp();
    if x >= 0.0 { ans } else { 2.0 - ans }
}

/// Inverse of the standard normal CDF (probit), Acklam's algorithm with a
/// Halley refinement step. Panics outside (0, 1).
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "probit: p={p} out of (0,1)");

    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // One Halley step against the exact CDF for ~1e-15 accuracy.
    let e = 0.5 * erfc(-x / std::f64::consts::SQRT_2) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Inverse error function via the probit identity.
pub fn erf_inv(x: f64) -> f64 {
    assert!(x > -1.0 && x < 1.0, "erf_inv: x={x} out of (-1,1)");
    probit((x + 1.0) / 2.0) / std::f64::consts::SQRT_2
}

/// The paper's confidence multiplier: erf⁻¹(2c−1)·√2 = Φ⁻¹(c).
///
/// `t_s + μ + confidence_multiplier(c)·σ ≤ t_max` is the §IV-B scale-out
/// admission rule.
pub fn confidence_multiplier(c: f64) -> f64 {
    assert!(c > 0.0 && c < 1.0, "confidence c={c} out of (0,1)");
    probit(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values (Mathematica).
        assert!((erf(0.0)).abs() < 1e-15);
        assert!((erf(0.5) - 0.5204998778130465).abs() < 1e-10);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 1e-10);
        assert!((erf(2.0) - 0.9953222650189527).abs() < 1e-10);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 1e-10);
    }

    #[test]
    fn erfc_symmetry() {
        for &x in &[0.1, 0.7, 1.3, 2.9] {
            assert!((erfc(x) + erfc(-x) - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn paper_rounded_multiplier_at_c95() {
        // Paper §IV-B: "t_s + mu + 1.64485 * sigma <= t_max (rounded)".
        let m = confidence_multiplier(0.95);
        assert!((m - 1.64485).abs() < 1e-5, "multiplier={m}");
    }

    #[test]
    fn probit_round_trips_cdf() {
        for &p in &[0.001, 0.025, 0.2, 0.5, 0.8, 0.95, 0.999] {
            let x = probit(p);
            let cdf = 0.5 * erfc(-x / std::f64::consts::SQRT_2);
            assert!((cdf - p).abs() < 1e-12, "p={p} cdf={cdf}");
        }
    }

    #[test]
    fn erf_inv_round_trips_erf() {
        for &x in &[-0.9, -0.5, -0.1, 0.0001, 0.3, 0.77, 0.999] {
            let y = erf_inv(x);
            assert!((erf(y) - x).abs() < 1e-10, "x={x}");
        }
    }

    #[test]
    fn probit_median_is_zero() {
        assert!(probit(0.5).abs() < 1e-12);
    }

    #[test]
    fn multiplier_monotone_in_confidence() {
        let cs = [0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99];
        let ms: Vec<f64> = cs.iter().map(|&c| confidence_multiplier(c)).collect();
        for w in ms.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(confidence_multiplier(0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn probit_rejects_zero() {
        probit(0.0);
    }
}
