//! Deterministic PRNG: PCG-XSH-RR 64/32 with explicit seeding.
//!
//! Every stochastic component of the system (simulator noise, train/test
//! splits, GBM subsampling) takes a `Pcg` seeded from a `u64`, so the whole
//! evaluation pipeline is reproducible bit-for-bit. Wall-clock entropy is
//! never used.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, and statistically solid
/// for simulation workloads.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn split(&mut self, stream: u64) -> Pcg {
        Pcg::new(self.next_u64(), stream.wrapping_mul(2654435761).wrapping_add(1))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random bits into the mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (single value; simple and exact
    /// enough for simulation noise).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal such that the *multiplicative* noise has median 1 and the
    /// given coefficient-of-variation-ish sigma (sigma of log).
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher-Yates: only the first k positions need settling.
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seed(42);
        let mut b = Pcg::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seed(1);
        let mut b = Pcg::seed(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg::seed(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg::seed(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seed(11);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut rng = Pcg::seed(13);
        let mut xs: Vec<f64> =
            (0..20_001).map(|_| rng.lognormal_noise(0.05)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        assert!((median - 1.0).abs() < 0.01, "median={median}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg::seed(17);
        for _ in 0..100 {
            let k = rng.range(1, 20);
            let s = rng.sample_indices(30, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k);
            assert!(sorted.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seed(19);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg::seed(23);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
