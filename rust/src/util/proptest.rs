//! Seeded property-testing driver (stand-in for `proptest`, which is not in
//! the offline crate cache — see DESIGN.md §2).
//!
//! Runs a property over `cases` generated inputs; on failure it attempts a
//! bounded greedy shrink via the generator's own `shrink` hook and reports
//! the minimal failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this image)
//! use c3o::util::proptest::{forall, Gen};
//! forall("sort is idempotent", 200, |rng| {
//!     let n = rng.range(0, 20);
//!     (0..n).map(|_| rng.f64()).collect::<Vec<_>>()
//! }, |xs| {
//!     let mut a = xs.clone();
//!     a.sort_by(|p, q| p.partial_cmp(q).unwrap());
//!     let mut b = a.clone();
//!     b.sort_by(|p, q| p.partial_cmp(q).unwrap());
//!     a == b
//! });
//! ```

use crate::util::prng::Pcg;

/// Generator trait for shrinkable inputs; blanket-implemented for closures
/// via [`forall`], which skips shrinking.
pub trait Gen {
    type Value: std::fmt::Debug;
    fn generate(&self, rng: &mut Pcg) -> Self::Value;
    /// Candidate smaller inputs; default none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` over `cases` inputs drawn by `gen`. Panics (test failure)
/// with the seed and debug-printed input of the first counterexample.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    // Fixed master seed: failures replay exactly. Derive per-case streams.
    let mut master = Pcg::new(0xC30_C30, 7);
    for case in 0..cases {
        let mut rng = master.split(case as u64);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed at case {case}/{cases}\ninput: {input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result`, so properties can use
/// `?` internally; an `Err` is a failure with its message attached.
pub fn forall_res<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg) -> T,
    mut prop: impl FnMut(&T) -> anyhow::Result<()>,
) {
    let mut master = Pcg::new(0xC30_C30, 7);
    for case in 0..cases {
        let mut rng = master.split(case as u64);
        let input = gen(&mut rng);
        if let Err(e) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case}/{cases}: {e}\ninput: {input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("abs is nonneg", 100, |rng| rng.normal(), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_input() {
        forall("always false", 10, |rng| rng.f64(), |_| false);
    }

    #[test]
    fn generation_is_deterministic() {
        let mut first = Vec::new();
        forall("collect", 20, |rng| rng.next_u64(), |&x| {
            first.push(x);
            true
        });
        let mut second = Vec::new();
        forall("collect", 20, |rng| rng.next_u64(), |&x| {
            second.push(x);
            true
        });
        assert_eq!(first, second);
    }
}
