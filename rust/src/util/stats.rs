//! Descriptive statistics used throughout the evaluation and the
//! configurator's error model.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (the paper's sigma over CV residuals).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (interpolating). Returns 0.0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolation percentile, p in [0, 100].
///
/// NaN-safe: ranks with `total_cmp` (NaNs sort to the extremes) instead
/// of panicking on `partial_cmp(..).unwrap()`. With NaN inputs, high
/// percentiles may return NaN — but monitoring a hub beats crashing it.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    if v.len() == 1 {
        return v[0];
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Mean absolute percentage error: mean(|pred - actual| / actual) * 100.
///
/// The paper's Table II / Fig. 5 metric. Entries with `actual == 0` are
/// skipped (cannot happen for runtimes, guarded anyway).
pub fn mape(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    let mut sum = 0.0;
    let mut n = 0usize;
    for (&p, &a) in pred.iter().zip(actual) {
        if a.abs() > f64::EPSILON {
            sum += ((p - a) / a).abs();
            n += 1;
        }
    }
    if n == 0 { 0.0 } else { 100.0 * sum / n as f64 }
}

/// Mean absolute error.
pub fn mae(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(actual)
        .map(|(p, a)| (p - a).abs())
        .sum::<f64>()
        / pred.len() as f64
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    if pred.is_empty() {
        return 0.0;
    }
    (pred.iter()
        .zip(actual)
        .map(|(p, a)| (p - a).powi(2))
        .sum::<f64>()
        / pred.len() as f64)
        .sqrt()
}

/// Welford online accumulator — used by the hub's running validation stats
/// and the bench harness.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn percentile_tolerates_nan_input() {
        // Regression: this panicked on `partial_cmp(..).unwrap()`.
        let xs = [3.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan());
        // Positive NaNs sort last: low/mid percentiles stay finite.
        assert_eq!(median(&[2.0, f64::NAN, 1.0, 3.0]), 2.5);
    }

    #[test]
    fn mape_basic() {
        let pred = [110.0, 90.0];
        let act = [100.0, 100.0];
        assert!((mape(&pred, &act) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn mape_skips_zero_actuals() {
        let pred = [1.0, 110.0];
        let act = [0.0, 100.0];
        assert!((mape(&pred, &act) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.5, -1.0, 8.0, 0.25];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), -1.0);
        assert_eq!(o.max(), 8.0);
        assert_eq!(o.count(), 6);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mape(&[], &[]), 0.0);
        assert_eq!(rmse(&[], &[]), 0.0);
    }
}
