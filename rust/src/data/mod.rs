//! Runtime-data schema, TSV codec and local/global context handling.
//!
//! A **run record** is one executed (job, cluster configuration, inputs)
//! tuple with its observed runtime — the unit of collaboration in C3O.
//! Following the paper (§VI-A) the on-disk layout is TSV: machine type and
//! instance count first, then the dataset/problem size, then job-specific
//! context features, then the runtime.

pub mod dataset;
pub mod jobs;

pub use dataset::{Dataset, FeatureMatrix, RecordFingerprint, RunRecord};
pub use jobs::JobKind;
