//! Run records and datasets: the shared runtime data of the paper.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{bail, Context};

use super::JobKind;
use crate::linalg::Matrix;
use crate::models::TrainData;
use crate::util::tsv::Table;

/// One executed (job, configuration, inputs) observation.
///
/// `context` holds the job-specific features in the order of
/// [`JobKind::context_feature_names`]; `data_size_gb` is the paper's
/// "dataset size / problem size" shared feature.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    pub machine_type: String,
    pub scale_out: u32,
    pub data_size_gb: f64,
    pub context: Vec<f64>,
    pub runtime_s: f64,
}

impl RunRecord {
    /// The full feature vector `[scale_out, data_size, context...]` used by
    /// the runtime models (machine type is held fixed per training set,
    /// paper §VI-C).
    pub fn features(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(2 + self.context.len());
        v.push(self.scale_out as f64);
        v.push(self.data_size_gb);
        v.extend_from_slice(&self.context);
        v
    }

    /// Bit-exact identity of this record, usable as a hash key — the
    /// hub's duplicate-replay gate compares records by it. Floats are
    /// keyed by `to_bits`; schema validation only admits finite positive
    /// values, so no NaN/-0.0 aliasing can make bit equality diverge
    /// from value equality.
    pub fn fingerprint(&self) -> RecordFingerprint {
        RecordFingerprint {
            machine_type: self.machine_type.clone(),
            scale_out: self.scale_out,
            data_size_bits: self.data_size_gb.to_bits(),
            runtime_bits: self.runtime_s.to_bits(),
            context_bits: self.context.iter().map(|c| c.to_bits()).collect(),
        }
    }
}

/// Hashable bit-exact record identity — see [`RunRecord::fingerprint`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RecordFingerprint {
    machine_type: String,
    scale_out: u32,
    data_size_bits: u64,
    runtime_bits: u64,
    context_bits: Vec<u64>,
}

/// A job's shared runtime dataset (the contents of a C3O repository's data
/// directory).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub job: JobKind,
    pub records: Vec<RunRecord>,
}

impl Dataset {
    pub fn new(job: JobKind) -> Dataset {
        Dataset { job, records: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Push with schema validation.
    pub fn push(&mut self, rec: RunRecord) -> crate::Result<()> {
        self.validate_record(&rec)?;
        self.records.push(rec);
        Ok(())
    }

    /// Schema check: context arity, positive runtime, sane scale-out.
    pub fn validate_record(&self, rec: &RunRecord) -> crate::Result<()> {
        if rec.context.len() != self.job.context_features() {
            bail!(
                "{}: expected {} context features, got {}",
                self.job,
                self.job.context_features(),
                rec.context.len()
            );
        }
        if !(rec.runtime_s.is_finite() && rec.runtime_s > 0.0) {
            bail!("runtime must be finite positive, got {}", rec.runtime_s);
        }
        if rec.scale_out == 0 {
            bail!("scale-out must be >= 1");
        }
        if !(rec.data_size_gb.is_finite() && rec.data_size_gb > 0.0) {
            bail!("data size must be finite positive");
        }
        if rec.context.iter().any(|c| !c.is_finite()) {
            bail!("context features must be finite");
        }
        Ok(())
    }

    /// Restrict to one machine type (the models only learn from the target
    /// type, §VI-C).
    pub fn for_machine(&self, machine_type: &str) -> Dataset {
        Dataset {
            job: self.job,
            records: self
                .records
                .iter()
                .filter(|r| r.machine_type == machine_type)
                .cloned()
                .collect(),
        }
    }

    /// Number of records on one machine type, without materializing a
    /// filtered dataset — the hub's machine-selection step runs on every
    /// `predict`, so the count must not clone records.
    pub fn count_machine(&self, machine_type: &str) -> usize {
        self.records.iter().filter(|r| r.machine_type == machine_type).count()
    }

    /// Build the columnar training views of this dataset — see
    /// [`FeatureMatrix`]. The hub builds this once per repository revision
    /// (`crate::hub::Repository::view`) and every fit against that revision
    /// reuses it; local mode builds it per `configure` call.
    pub fn feature_view(&self) -> FeatureMatrix {
        FeatureMatrix::build(self)
    }

    /// Machine types present, sorted.
    pub fn machine_types(&self) -> Vec<String> {
        let set: BTreeSet<String> =
            self.records.iter().map(|r| r.machine_type.clone()).collect();
        set.into_iter().collect()
    }

    /// Distinct context vectors present, sorted lexicographically — each is
    /// one "execution context" in the paper's sense. A *local* training
    /// dataset is all records sharing one of these.
    pub fn contexts(&self) -> Vec<Vec<f64>> {
        let mut ctxs: Vec<Vec<f64>> = Vec::new();
        for r in &self.records {
            if !ctxs.iter().any(|c| c == &r.context) {
                ctxs.push(r.context.clone());
            }
        }
        ctxs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ctxs
    }

    /// Records belonging to one context (a single-user "local" view).
    pub fn local_view(&self, context: &[f64]) -> Dataset {
        Dataset {
            job: self.job,
            records: self
                .records
                .iter()
                .filter(|r| r.context == context)
                .cloned()
                .collect(),
        }
    }

    /// TSV header for this job: paper layout — machine type and instance
    /// count first, context features at the end, runtime last.
    pub fn header(job: JobKind) -> Vec<String> {
        let mut h = vec![
            "machine_type".to_string(),
            "instance_count".to_string(),
            "data_size_gb".to_string(),
        ];
        h.extend(job.context_feature_names().iter().map(|s| s.to_string()));
        h.push("gross_runtime_s".to_string());
        h
    }

    pub fn to_table(&self) -> crate::Result<Table> {
        let mut t = Table::new(Self::header(self.job));
        for r in &self.records {
            let mut row = vec![
                r.machine_type.clone(),
                r.scale_out.to_string(),
                format!("{}", r.data_size_gb),
            ];
            row.extend(r.context.iter().map(|c| format!("{c}")));
            row.push(format!("{}", r.runtime_s));
            t.push_row(row)?;
        }
        Ok(t)
    }

    pub fn from_table(job: JobKind, t: &Table) -> crate::Result<Dataset> {
        let expect = Self::header(job);
        if t.header != expect {
            bail!(
                "{job}: header mismatch\n  expected {expect:?}\n  got      {:?}",
                t.header
            );
        }
        let nctx = job.context_features();
        let mut ds = Dataset::new(job);
        for (i, row) in t.rows.iter().enumerate() {
            let rec = RunRecord {
                machine_type: row[0].clone(),
                scale_out: row[1]
                    .parse()
                    .with_context(|| format!("row {i}: instance_count"))?,
                data_size_gb: row[2]
                    .parse()
                    .with_context(|| format!("row {i}: data_size_gb"))?,
                context: (0..nctx)
                    .map(|k| row[3 + k].parse::<f64>())
                    .collect::<Result<_, _>>()
                    .with_context(|| format!("row {i}: context"))?,
                runtime_s: row[3 + nctx]
                    .parse()
                    .with_context(|| format!("row {i}: runtime"))?,
            };
            ds.push(rec).with_context(|| format!("row {i}"))?;
        }
        Ok(ds)
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        self.to_table()?.write(path)
    }

    pub fn load(job: JobKind, path: &Path) -> crate::Result<Dataset> {
        Dataset::from_table(job, &Table::read(path)?)
    }

    /// Scale-outs present, sorted ascending.
    pub fn scale_outs(&self) -> Vec<u32> {
        let set: BTreeSet<u32> = self.records.iter().map(|r| r.scale_out).collect();
        set.into_iter().collect()
    }
}

/// Columnar training views of a dataset: the feature matrix
/// `[scale_out, data_size, context...]` and target vector of every machine
/// type's slice, materialized in one pass over the records.
///
/// This replaces the fit-time `for_machine(..)` + per-record `features()`
/// path, which cloned every matching record (including its machine-type
/// `String`) and allocated one `Vec` per row on every fit. A
/// `FeatureMatrix` is built once per dataset revision and shared by every
/// fit against that revision.
#[derive(Debug, Clone)]
pub struct FeatureMatrix {
    /// Feature arity: `2 + job.context_features()`.
    pub width: usize,
    groups: BTreeMap<String, TrainData>,
}

impl FeatureMatrix {
    /// Materialize the per-machine views. Record arity is guaranteed by
    /// [`Dataset::push`] (every constructor funnels through it), so the
    /// flat buffers are rectangular by construction.
    pub fn build(ds: &Dataset) -> FeatureMatrix {
        let width = 2 + ds.job.context_features();
        let mut flat: BTreeMap<String, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
        for rec in &ds.records {
            let (xs, ys) = flat.entry(rec.machine_type.clone()).or_default();
            xs.push(rec.scale_out as f64);
            xs.push(rec.data_size_gb);
            xs.extend_from_slice(&rec.context);
            ys.push(rec.runtime_s);
        }
        let mut groups = BTreeMap::new();
        for (machine, (xs, ys)) in flat {
            let x = Matrix::from_vec(ys.len(), width, xs)
                .expect("push-validated records are rectangular");
            let data = TrainData::new(x, ys).expect("one target per row");
            groups.insert(machine, data);
        }
        FeatureMatrix { width, groups }
    }

    /// The training view for one machine type (`None` if it has no runs).
    pub fn train_data(&self, machine_type: &str) -> Option<&TrainData> {
        self.groups.get(machine_type)
    }

    /// Number of records on one machine type.
    pub fn rows(&self, machine_type: &str) -> usize {
        self.groups.get(machine_type).map_or(0, TrainData::len)
    }

    /// Machine types with at least one record, sorted.
    pub fn machines(&self) -> impl Iterator<Item = &str> {
        self.groups.keys().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(m: &str, s: u32, d: f64, ctx: Vec<f64>, t: f64) -> RunRecord {
        RunRecord {
            machine_type: m.into(),
            scale_out: s,
            data_size_gb: d,
            context: ctx,
            runtime_s: t,
        }
    }

    #[test]
    fn push_validates_context_arity() {
        let mut ds = Dataset::new(JobKind::KMeans);
        assert!(ds.push(rec("m5", 4, 10.0, vec![5.0], 100.0)).is_err());
        assert!(ds.push(rec("m5", 4, 10.0, vec![5.0, 0.001], 100.0)).is_ok());
    }

    #[test]
    fn push_rejects_bad_values() {
        let mut ds = Dataset::new(JobKind::Sort);
        assert!(ds.push(rec("m5", 0, 10.0, vec![], 100.0)).is_err());
        assert!(ds.push(rec("m5", 4, -1.0, vec![], 100.0)).is_err());
        assert!(ds.push(rec("m5", 4, 10.0, vec![], 0.0)).is_err());
        assert!(ds.push(rec("m5", 4, 10.0, vec![], f64::NAN)).is_err());
    }

    #[test]
    fn tsv_round_trip() {
        let mut ds = Dataset::new(JobKind::Grep);
        ds.push(rec("m5.xlarge", 4, 12.5, vec![0.01], 321.5)).unwrap();
        ds.push(rec("c5.xlarge", 8, 20.0, vec![0.10], 123.0)).unwrap();
        let t = ds.to_table().unwrap();
        let back = Dataset::from_table(JobKind::Grep, &t).unwrap();
        assert_eq!(back.records, ds.records);
    }

    #[test]
    fn header_layout_matches_paper() {
        // §VI-A: machine type and instance count first, context last.
        let h = Dataset::header(JobKind::PageRank);
        assert_eq!(h[0], "machine_type");
        assert_eq!(h[1], "instance_count");
        assert_eq!(h[h.len() - 1], "gross_runtime_s");
        assert!(h.contains(&"page_ratio".to_string()));
    }

    #[test]
    fn local_view_filters_context() {
        let mut ds = Dataset::new(JobKind::KMeans);
        ds.push(rec("m5", 2, 10.0, vec![3.0, 0.001], 50.0)).unwrap();
        ds.push(rec("m5", 4, 10.0, vec![3.0, 0.001], 30.0)).unwrap();
        ds.push(rec("m5", 2, 10.0, vec![9.0, 0.001], 90.0)).unwrap();
        let ctxs = ds.contexts();
        assert_eq!(ctxs.len(), 2);
        assert_eq!(ds.local_view(&[3.0, 0.001]).len(), 2);
        assert_eq!(ds.local_view(&[9.0, 0.001]).len(), 1);
    }

    #[test]
    fn machine_filter() {
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec("m5", 2, 10.0, vec![], 10.0)).unwrap();
        ds.push(rec("c5", 2, 10.0, vec![], 12.0)).unwrap();
        assert_eq!(ds.for_machine("m5").len(), 1);
        assert_eq!(ds.machine_types(), vec!["c5", "m5"]);
        assert_eq!(ds.count_machine("m5"), 1);
        assert_eq!(ds.count_machine("r5"), 0);
    }

    #[test]
    fn feature_view_groups_by_machine() {
        let mut ds = Dataset::new(JobKind::Grep);
        ds.push(rec("m5.xlarge", 4, 12.5, vec![0.01], 321.5)).unwrap();
        ds.push(rec("c5.xlarge", 8, 20.0, vec![0.10], 123.0)).unwrap();
        ds.push(rec("m5.xlarge", 2, 10.0, vec![0.05], 200.0)).unwrap();
        let view = ds.feature_view();
        assert_eq!(view.width, 3);
        let m5 = view.train_data("m5.xlarge").unwrap();
        assert_eq!(m5.len(), 2);
        assert_eq!(m5.x.row(0), &[4.0, 12.5, 0.01]);
        assert_eq!(m5.x.row(1), &[2.0, 10.0, 0.05]);
        assert_eq!(m5.y, vec![321.5, 200.0]);
        assert_eq!(view.rows("c5.xlarge"), 1);
        assert!(view.train_data("r5.xlarge").is_none());
        assert_eq!(view.machines().collect::<Vec<_>>(), vec!["c5.xlarge", "m5.xlarge"]);
    }

    #[test]
    fn feature_view_matches_row_materialization() {
        // The columnar view must be bit-identical to the old
        // for_machine + features() path, so fits see the same numbers.
        let mut ds = Dataset::new(JobKind::KMeans);
        for (m, s) in [("m5", 2), ("c5", 4), ("m5", 6), ("m5", 8)] {
            ds.push(rec(m, s, 10.0 + s as f64, vec![5.0, 0.001], 100.0 / s as f64))
                .unwrap();
        }
        let view = ds.feature_view();
        for m in ds.machine_types() {
            let td = TrainData::from_dataset(&ds.for_machine(&m)).unwrap();
            let tv = view.train_data(&m).unwrap();
            assert_eq!(td.x.data(), tv.x.data(), "{m}");
            assert_eq!(td.y, tv.y, "{m}");
        }
    }

    #[test]
    fn features_layout() {
        let r = rec("m5", 6, 15.0, vec![0.5], 1.0);
        assert_eq!(r.features(), vec![6.0, 15.0, 0.5]);
    }

    #[test]
    fn header_mismatch_rejected() {
        let t = Table::parse("a\tb\n1\t2\n").unwrap();
        assert!(Dataset::from_table(JobKind::Sort, &t).is_err());
    }
}
