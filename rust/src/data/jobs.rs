//! The five evaluation jobs of the paper (Table I).

use std::fmt;
use std::str::FromStr;

use anyhow::bail;

/// The five Spark jobs from the paper's evaluation dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum JobKind {
    /// Sort lines of random characters. Features: 3+0.
    Sort,
    /// Grep for a keyword. Features: 3+1 (keyword-line ratio).
    Grep,
    /// SGD linear regression. Features: 3+2 (iterations, feature count).
    Sgd,
    /// K-Means clustering. Features: 3+2 (k, convergence criterion).
    KMeans,
    /// PageRank. Features: 3+2 (unique-page ratio, convergence criterion).
    PageRank,
}

impl JobKind {
    /// All jobs, in the paper's Table I order.
    pub const ALL: [JobKind; 5] = [
        JobKind::Sort,
        JobKind::Grep,
        JobKind::Sgd,
        JobKind::KMeans,
        JobKind::PageRank,
    ];

    /// Number of job-specific context features (the "+k" in Table I).
    pub fn context_features(self) -> usize {
        match self {
            JobKind::Sort => 0,
            JobKind::Grep => 1,
            JobKind::Sgd | JobKind::KMeans | JobKind::PageRank => 2,
        }
    }

    /// Names of context feature columns (order fixed; used in TSV headers).
    pub fn context_feature_names(self) -> &'static [&'static str] {
        match self {
            JobKind::Sort => &[],
            JobKind::Grep => &["keyword_ratio"],
            JobKind::Sgd => &["iterations", "features"],
            JobKind::KMeans => &["k", "convergence"],
            JobKind::PageRank => &["page_ratio", "convergence"],
        }
    }

    /// Unique experiment count in the paper's dataset (Table I, "Jobs").
    pub fn experiment_count(self) -> usize {
        match self {
            JobKind::Sort => 126,
            JobKind::Grep => 162,
            JobKind::Sgd => 180,
            JobKind::KMeans => 180,
            JobKind::PageRank => 282,
        }
    }

    /// Does this job iterate over the dataset (making it memory-cliff
    /// sensitive, §IV-B)?
    pub fn is_iterative(self) -> bool {
        matches!(self, JobKind::Sgd | JobKind::KMeans | JobKind::PageRank)
    }
}

impl fmt::Display for JobKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            JobKind::Sort => "sort",
            JobKind::Grep => "grep",
            JobKind::Sgd => "sgd",
            JobKind::KMeans => "kmeans",
            JobKind::PageRank => "pagerank",
        };
        f.write_str(s)
    }
}

impl FromStr for JobKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sort" => JobKind::Sort,
            "grep" => JobKind::Grep,
            "sgd" | "sgdlr" => JobKind::Sgd,
            "kmeans" | "k-means" => JobKind::KMeans,
            "pagerank" | "page-rank" => JobKind::PageRank,
            other => bail!("unknown job kind: {other}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn census_totals_930() {
        let total: usize = JobKind::ALL.iter().map(|j| j.experiment_count()).sum();
        assert_eq!(total, 930, "paper: 930 unique runtime experiments");
    }

    #[test]
    fn feature_counts_match_table1() {
        assert_eq!(JobKind::Sort.context_features(), 0);
        assert_eq!(JobKind::Grep.context_features(), 1);
        assert_eq!(JobKind::Sgd.context_features(), 2);
        assert_eq!(JobKind::KMeans.context_features(), 2);
        assert_eq!(JobKind::PageRank.context_features(), 2);
    }

    #[test]
    fn names_align_with_counts() {
        for j in JobKind::ALL {
            assert_eq!(j.context_feature_names().len(), j.context_features());
        }
    }

    #[test]
    fn round_trip_display_parse() {
        for j in JobKind::ALL {
            assert_eq!(j.to_string().parse::<JobKind>().unwrap(), j);
        }
    }

    #[test]
    fn parse_aliases() {
        assert_eq!("SGDLR".parse::<JobKind>().unwrap(), JobKind::Sgd);
        assert_eq!("K-Means".parse::<JobKind>().unwrap(), JobKind::KMeans);
        assert!("mapreduce".parse::<JobKind>().is_err());
    }
}
