//! Micro-bench harness (criterion substitute; the offline crate cache has
//! no `criterion` — see DESIGN.md §2).
//!
//! Provides warmup + timed iterations with mean/σ/min/max reporting and a
//! tabular writer used by the `benches/` binaries to print the paper's
//! tables next to the timing numbers.

use std::time::Instant;

use crate::util::stats::Online;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn per_iter_display(&self) -> String {
        fn fmt(s: f64) -> String {
            if s < 1e-6 {
                format!("{:.1} ns", s * 1e9)
            } else if s < 1e-3 {
                format!("{:.2} µs", s * 1e6)
            } else if s < 1.0 {
                format!("{:.2} ms", s * 1e3)
            } else {
                format!("{:.3} s", s)
            }
        }
        format!(
            "{:<44} {:>10}/iter  (±{} over {} iters, min {}, max {})",
            self.name,
            fmt(self.mean_s),
            fmt(self.std_s),
            self.iters,
            fmt(self.min_s),
            fmt(self.max_s),
        )
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut acc = Online::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        acc.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: iters.max(1),
        mean_s: acc.mean(),
        std_s: acc.std_dev(),
        min_s: acc.min(),
        max_s: acc.max(),
    }
}

/// Time a single invocation (for expensive end-to-end passes).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Fixed-width table printer for paper-style result tables.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(widths: Vec<usize>) -> Self {
        TablePrinter { widths }
    }

    pub fn row(&self, cells: &[String]) -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            line.push_str(&format!("{:<w$}", c, w = w));
        }
        line
    }

    pub fn sep(&self) -> String {
        "-".repeat(self.widths.iter().sum::<usize>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let r = bench("noop-ish", 2, 10, || (0..1000).sum::<usize>());
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s + 1e-12);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn display_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            mean_s: 0.0021,
            std_s: 1e-4,
            min_s: 0.002,
            max_s: 0.0022,
        };
        let s = r.per_iter_display();
        assert!(s.contains("ms"), "{s}");
    }

    #[test]
    fn table_printer_pads() {
        let t = TablePrinter::new(vec![8, 8]);
        let line = t.row(&["ab".into(), "cd".into()]);
        assert!(line.starts_with("ab      cd"));
    }
}
