//! Hub replication: leader-side log shipping + follower tailing
//! (DESIGN.md §11).
//!
//! The per-repo WAL (DESIGN.md §9) doubles as a replication log: every
//! accepted submission is one framed record carrying its commit revision,
//! so a follower hub replicates a leader by tailing each repository's log
//! and applying records through the validation-free fast path
//! ([`HubState::apply_replicated`]) — gap-free, in revision order, and
//! bit-identical (TSV round-trips `f64` via shortest representation, and
//! the fit path is deterministic, so a converged follower serves
//! bit-identical `predict_batch` answers).
//!
//! Protocol (all plain v1 ops, served by the leader's
//! [`PredictionService`]):
//!
//! * `repl_subscribe { job, from_revision }` — lag probe: the leader's
//!   current revision plus whether records right above `from_revision`
//!   are still in the WAL (`compacted: false`) or only reachable through
//!   a snapshot (`compacted: true`).
//! * `repl_fetch { job, from_revision, max }` — one page of WAL records
//!   with revisions in `(from_revision, from_revision + ..]`, oldest
//!   first.
//! * `repl_snapshot` — the leader's current corpus image per repository
//!   (a superset of its latest compacted snapshot), for cold bootstrap
//!   or for a follower that fell behind the compaction horizon.
//!
//! The follower side is [`Tailer`]: a poll/backoff loop owned by the
//! follower's `HubServer` that keeps its `HubState` converged with the
//! leader. Because applies reuse `DurableStore::append`, a follower is
//! itself durable: kill -9 it mid-tail, reopen the same data dir, and it
//! resumes from its own watermark with no gaps and no double-applies.
//!
//! Replication is transport-independent: the tailer is an ordinary
//! roundtrip [`HubClient`] of the leader, so the reactor transport
//! (DESIGN.md §7) required no changes here. The default
//! `poll_interval` (200 ms) keeps each tailer connection well inside
//! the leader's idle deadline (`idle_timeout`, default 10 s) while
//! caught up — and even a reaped connection only costs the tailer one
//! reconnect on its next poll.
//!
//! [`HubState::apply_replicated`]: crate::hub::HubState::apply_replicated

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Context;

use crate::api::PredictionService;
use crate::data::Dataset;
use crate::hub::HubClient;
use crate::storage::RecoveredRepo;
use crate::util::tsv::Table;

/// How a follower tails its leader.
#[derive(Debug, Clone)]
pub struct FollowerConfig {
    /// Leader hub address (`host:port`).
    pub leader: String,
    /// Idle delay between polls once caught up.
    pub poll_interval: Duration,
    /// Max records per `repl_fetch` page.
    pub max_batch: u64,
    /// Backoff ceiling after leader errors (exponential from
    /// `poll_interval` up to this cap; reset on the next success).
    pub max_backoff: Duration,
}

impl FollowerConfig {
    pub fn new(leader: impl Into<String>) -> FollowerConfig {
        FollowerConfig {
            leader: leader.into(),
            poll_interval: Duration::from_millis(200),
            max_batch: 256,
            max_backoff: Duration::from_secs(2),
        }
    }
}

/// One full catch-up pass against a connected leader: for every local
/// repository, page `repl_fetch` until the leader has nothing newer,
/// applying each record through the validation-free fast path. A page
/// flagged `compacted` (the follower fell behind the leader's compaction
/// horizon — or is cold-starting against a compacted log) triggers a
/// snapshot re-bootstrap, then the fetch loop resumes from the new
/// watermark. Returns the number of records applied.
pub fn sync_once(
    service: &PredictionService,
    client: &mut HubClient,
    max_batch: u64,
) -> crate::Result<u64> {
    let state = service.state().clone();
    let mut applied = 0u64;
    for job in state.jobs() {
        let mut bootstrapped = false;
        loop {
            let local = state.revision(job).unwrap_or(0);
            let page = client.repl_fetch(job, local, max_batch)?;
            // Every page carries the leader's current revision: remember
            // it so the follower's `stats`/`metrics` ops can report
            // replication lag (leader watermark minus applied revision).
            service.note_repl_progress(job, page.leader_revision);
            if page.compacted {
                // Records right above our watermark are gone from the
                // leader's WAL; a snapshot carries us past the horizon.
                anyhow::ensure!(
                    !bootstrapped,
                    "leader reports {job} compacted above revision {local} even \
                     after a snapshot bootstrap"
                );
                install_snapshot(service, client)?;
                bootstrapped = true;
                continue;
            }
            if page.records.is_empty() {
                break;
            }
            for rec in &page.records {
                service
                    .apply_replicated(job, rec.revision, &rec.data_tsv)
                    .with_context(|| format!("applying leader record for {job}"))?;
                applied += 1;
            }
        }
    }
    service.note_tail_success();
    Ok(applied)
}

/// Cold-bootstrap (or horizon-recovery) path: pull the leader's corpus
/// image and install every repository that is ahead of ours, exactly as
/// crash recovery installs a snapshot — data and revision watermark land
/// verbatim, so revisions stay monotone and the follower's model cache
/// goes stale by revision comparison. With a durable store attached, a
/// baseline snapshot is written afterwards so the store covers the
/// installed state and subsequent WAL appends stay contiguous. Returns
/// the number of repositories installed.
pub fn install_snapshot(
    service: &PredictionService,
    client: &mut HubClient,
) -> crate::Result<usize> {
    let snap = client.repl_snapshot()?;
    let state = service.state().clone();
    let mut installed = 0usize;
    for image in snap.repos {
        let local = state.revision(image.job).unwrap_or(0);
        if image.revision <= local {
            continue;
        }
        let data = Table::parse(&image.data_tsv)
            .and_then(|t| Dataset::from_table(image.job, &t))
            .with_context(|| {
                format!("parsing leader snapshot image for {}", image.job)
            })?;
        state.install_recovered(RecoveredRepo {
            job: image.job,
            revision: image.revision,
            description: Some(image.description),
            maintainer_machine: image.maintainer_machine,
            data,
            replayed: 0,
        });
        installed += 1;
    }
    if installed > 0 {
        if let Some(store) = state.storage() {
            state
                .snapshot_to(&store)
                .context("writing baseline snapshot after leader bootstrap")?;
        }
    }
    Ok(installed)
}

/// Background follower loop: connects to the leader, then alternates
/// [`sync_once`] with an idle sleep, backing off exponentially (up to
/// `max_backoff`) while the leader is unreachable and resetting on the
/// next successful pass. Dropping the `Tailer` stops the loop and joins
/// the thread.
#[derive(Debug)]
pub struct Tailer {
    stop: Arc<AtomicBool>,
    applied: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl Tailer {
    pub fn start(service: Arc<PredictionService>, config: FollowerConfig) -> Tailer {
        let stop = Arc::new(AtomicBool::new(false));
        let applied = Arc::new(AtomicU64::new(0));
        let handle = {
            let stop = stop.clone();
            let applied = applied.clone();
            std::thread::Builder::new()
                .name("c3o-repl-tailer".into())
                .spawn(move || run_loop(&service, &config, &stop, &applied))
                .expect("spawning replication tailer thread")
        };
        Tailer { stop, applied, handle: Some(handle) }
    }

    /// Total records applied by this tailer since it started.
    pub fn applied(&self) -> u64 {
        self.applied.load(Ordering::Relaxed)
    }
}

impl Drop for Tailer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            handle.join().ok();
        }
    }
}

fn run_loop(
    service: &PredictionService,
    config: &FollowerConfig,
    stop: &AtomicBool,
    applied: &AtomicU64,
) {
    let mut client: Option<HubClient> = None;
    let mut backoff = config.poll_interval;
    while !stop.load(Ordering::Relaxed) {
        let tick = (|| -> crate::Result<u64> {
            if client.is_none() {
                client = Some(HubClient::connect(&config.leader)?);
            }
            sync_once(service, client.as_mut().unwrap(), config.max_batch)
        })();
        match tick {
            Ok(n) => {
                applied.fetch_add(n, Ordering::Relaxed);
                backoff = config.poll_interval;
                // Caught up (or applied a page): brief idle before the
                // next poll. A page-full tick polls again immediately.
                if n == 0 {
                    sleep_checked(stop, config.poll_interval);
                }
            }
            Err(e) => {
                // Leader unreachable or mid-restart: drop the session and
                // retry with capped exponential backoff. The follower
                // keeps serving reads from its last-applied state.
                crate::obs::log::warn(
                    "replication",
                    "sync with leader failed",
                    &[("leader", config.leader.clone()), ("error", format!("{e:#}"))],
                );
                client = None;
                sleep_checked(stop, backoff);
                backoff = (backoff * 2).min(config.max_backoff);
            }
        }
    }
}

/// Sleep in small slices so a stop request interrupts promptly.
fn sleep_checked(stop: &AtomicBool, total: Duration) {
    let slice = Duration::from_millis(10);
    let mut remaining = total;
    while !stop.load(Ordering::Relaxed) && remaining > Duration::ZERO {
        let step = remaining.min(slice);
        std::thread::sleep(step);
        remaining = remaining.saturating_sub(step);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::hub::{HubState, Repository, ValidationPolicy};
    use crate::runtime::NativeBackend;

    fn idle_service() -> Arc<PredictionService> {
        let state = Arc::new(HubState::new());
        state.insert(Repository::new(crate::data::JobKind::Sort, "sort"));
        Arc::new(PredictionService::new(
            state,
            Catalog::aws_like(),
            ValidationPolicy::default(),
            Arc::new(NativeBackend::new()),
        ))
    }

    #[test]
    fn tailer_stops_promptly_while_leader_is_unreachable() {
        // Port 1 is reserved and refused immediately on loopback; the
        // tailer must stay in its backoff loop without panicking and
        // join as soon as it is dropped.
        let tailer =
            Tailer::start(idle_service(), FollowerConfig::new("127.0.0.1:1"));
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(tailer.applied(), 0);
        let started = std::time::Instant::now();
        drop(tailer);
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "drop must interrupt the backoff sleep"
        );
    }

    #[test]
    fn follower_config_defaults_are_sane() {
        let cfg = FollowerConfig::new("127.0.0.1:7033");
        assert_eq!(cfg.leader, "127.0.0.1:7033");
        assert!(cfg.max_batch > 0);
        assert!(cfg.max_backoff >= cfg.poll_interval);
    }
}
