//! Hub repositories: job metadata + shared runtime data.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::RwLock;

use anyhow::Context;

use crate::data::{Dataset, JobKind};

/// One C3O repository (paper Fig. 4, step 1-2): a common job, its
/// maintainer-designated machine type, and the shared runtime data.
#[derive(Debug, Clone)]
pub struct Repository {
    pub job: JobKind,
    /// Maintainer's machine-type designation (§IV-A), if made.
    pub maintainer_machine: Option<String>,
    /// Short human description shown in hub listings.
    pub description: String,
    pub data: Dataset,
}

impl Repository {
    pub fn new(job: JobKind, description: &str) -> Self {
        Repository {
            job,
            maintainer_machine: None,
            description: description.to_string(),
            data: Dataset::new(job),
        }
    }
}

/// Shared hub state: job → repository, behind a RwLock (reads dominate).
#[derive(Debug, Default)]
pub struct HubState {
    repos: RwLock<BTreeMap<JobKind, Repository>>,
    accepted: RwLock<u64>,
    rejected: RwLock<u64>,
    /// Serializes the validate-then-commit sequence of submissions.
    /// Without it two concurrent contributions both validate against the
    /// same snapshot and the second commit silently drops the first's
    /// records (lost update) — caught by
    /// `hub_e2e::concurrent_clients_consistent_state`.
    submit_lock: std::sync::Mutex<()>,
}

impl HubState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&self, repo: Repository) {
        self.repos.write().unwrap().insert(repo.job, repo);
    }

    pub fn jobs(&self) -> Vec<JobKind> {
        self.repos.read().unwrap().keys().copied().collect()
    }

    pub fn get(&self, job: JobKind) -> Option<Repository> {
        self.repos.read().unwrap().get(&job).cloned()
    }

    /// Replace a repo's dataset (post-validation commit).
    pub fn commit_data(&self, job: JobKind, data: Dataset) -> crate::Result<()> {
        let mut repos = self.repos.write().unwrap();
        let repo = repos
            .get_mut(&job)
            .with_context(|| format!("no repository for {job}"))?;
        repo.data = data;
        *self.accepted.write().unwrap() += 1;
        Ok(())
    }

    pub fn note_rejection(&self) {
        *self.rejected.write().unwrap() += 1;
    }

    /// Atomic submission: validate `contribution` against the *current*
    /// dataset and merge it in one critical section (§III-C-b gate).
    pub fn submit(
        &self,
        contribution: crate::data::Dataset,
        policy: &super::validate::ValidationPolicy,
    ) -> crate::Result<super::validate::Verdict> {
        let _guard = self.submit_lock.lock().unwrap();
        let existing = self
            .get(contribution.job)
            .with_context(|| format!("no repository for {}", contribution.job))?
            .data;
        let verdict = super::validate::validate_contribution(&existing, &contribution, policy)?;
        if verdict.accepted {
            let mut merged = existing;
            for rec in contribution.records {
                merged.push(rec)?;
            }
            self.commit_data(contribution.job, merged)?;
        } else {
            self.note_rejection();
        }
        Ok(verdict)
    }

    pub fn counters(&self) -> (u64, u64) {
        (*self.accepted.read().unwrap(), *self.rejected.read().unwrap())
    }

    /// Persist all repositories as TSV files under `dir`.
    pub fn save(&self, dir: &Path) -> crate::Result<()> {
        for (job, repo) in self.repos.read().unwrap().iter() {
            repo.data.save(&dir.join(format!("{job}.tsv")))?;
        }
        Ok(())
    }

    /// Load repositories from TSV files under `dir` (missing files skipped).
    pub fn load(&self, dir: &Path) -> crate::Result<usize> {
        let mut loaded = 0;
        for job in JobKind::ALL {
            let path = dir.join(format!("{job}.tsv"));
            if path.exists() {
                let data = Dataset::load(job, &path)?;
                let mut repos = self.repos.write().unwrap();
                let repo = repos
                    .entry(job)
                    .or_insert_with(|| Repository::new(job, "loaded from disk"));
                repo.data = data;
                loaded += 1;
            }
        }
        Ok(loaded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RunRecord;

    fn rec(s: u32) -> RunRecord {
        RunRecord {
            machine_type: "m5.xlarge".into(),
            scale_out: s,
            data_size_gb: 10.0,
            context: vec![],
            runtime_s: 100.0 / s as f64,
        }
    }

    #[test]
    fn insert_get_roundtrip() {
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "sort lines");
        repo.data.push(rec(2)).unwrap();
        hub.insert(repo);
        assert_eq!(hub.jobs(), vec![JobKind::Sort]);
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);
        assert!(hub.get(JobKind::Grep).is_none());
    }

    #[test]
    fn commit_updates_and_counts() {
        let hub = HubState::new();
        hub.insert(Repository::new(JobKind::Sort, ""));
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);
        assert_eq!(hub.counters(), (1, 0));
        hub.note_rejection();
        assert_eq!(hub.counters(), (1, 1));
    }

    #[test]
    fn commit_to_missing_repo_fails() {
        let hub = HubState::new();
        assert!(hub.commit_data(JobKind::Grep, Dataset::new(JobKind::Grep)).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("c3o_hub_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "");
        repo.data.push(rec(2)).unwrap();
        repo.data.push(rec(4)).unwrap();
        hub.insert(repo);
        hub.save(&dir).unwrap();

        let hub2 = HubState::new();
        let loaded = hub2.load(&dir).unwrap();
        assert_eq!(loaded, 1);
        assert_eq!(hub2.get(JobKind::Sort).unwrap().data.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
