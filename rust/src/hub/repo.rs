//! Hub repositories: job metadata + shared runtime data.
//!
//! Concurrency model (DESIGN.md §7): every committed dataset change
//! publishes a fresh immutable [`Repository`] snapshot behind an `Arc`, so
//! readers get the current snapshot with one `Arc` clone — never a deep
//! `Dataset` copy — and keep reading their snapshot while later commits
//! publish newer ones. Writes to *different* repositories serialize only
//! on their own per-job submit lock, so contributions to different jobs
//! validate and commit in parallel.
//!
//! Durability (DESIGN.md §9): with a [`DurableStore`] attached, an
//! accepted submission is appended to the repository's WAL *inside* the
//! submit critical section, before the copy-on-write publish — so an
//! acknowledged submit either survives a crash or was never acknowledged.

use std::collections::{BTreeMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use anyhow::Context;

use crate::data::{Dataset, FeatureMatrix, JobKind, RecordFingerprint};
use crate::storage::{DurableStore, RecoveredRepo, RepoImage};

/// One C3O repository (paper Fig. 4, step 1-2): a common job, its
/// maintainer-designated machine type, and the shared runtime data.
///
/// A `Repository` value is an immutable snapshot once published through
/// [`HubState`]; dataset changes build and publish a *new* snapshot with a
/// bumped revision (copy-on-write).
#[derive(Debug, Clone)]
pub struct Repository {
    pub job: JobKind,
    /// Maintainer's machine-type designation (§IV-A), if made.
    pub maintainer_machine: Option<String>,
    /// Short human description shown in hub listings.
    pub description: String,
    pub data: Dataset,
    /// Monotonic dataset revision: bumped on every committed dataset
    /// change, so the PredictionService's fitted-model cache can detect
    /// staleness with a single integer comparison.
    pub revision: u64,
    /// Columnar training view of `data`, built at most once per revision:
    /// the snapshot is immutable, so every fit against this revision
    /// reuses the same feature matrices (see [`FeatureMatrix`]).
    view: OnceLock<Arc<FeatureMatrix>>,
    /// Bit-exact record fingerprints of `data`, built at most once per
    /// revision: the §III-C-b duplicate-replay gate checks contributions
    /// against this set, so a submit hashes only the contribution — not
    /// the whole (ever-growing) corpus — once the cache is warm.
    fingerprints: OnceLock<Arc<HashSet<RecordFingerprint>>>,
}

impl Repository {
    pub fn new(job: JobKind, description: &str) -> Self {
        Repository {
            job,
            maintainer_machine: None,
            description: description.to_string(),
            data: Dataset::new(job),
            revision: 0,
            view: OnceLock::new(),
            fingerprints: OnceLock::new(),
        }
    }

    /// Copy-on-write step: the same repository metadata with `data`
    /// replaced and the revision bumped (the view cache starts empty and
    /// is rebuilt lazily for the new revision).
    fn with_data(&self, data: Dataset) -> Repository {
        Repository {
            job: self.job,
            maintainer_machine: self.maintainer_machine.clone(),
            description: self.description.clone(),
            data,
            revision: self.revision + 1,
            view: OnceLock::new(),
            fingerprints: OnceLock::new(),
        }
    }

    /// The columnar training view of this snapshot's data, built on first
    /// use and shared by every subsequent fit against this revision.
    pub fn view(&self) -> &Arc<FeatureMatrix> {
        self.view.get_or_init(|| Arc::new(self.data.feature_view()))
    }

    /// Bit-exact fingerprints of every record in this snapshot, built on
    /// first use and shared by every duplicate-replay check against this
    /// revision (see [`RunRecord::fingerprint`]).
    pub fn fingerprints(&self) -> &Arc<HashSet<RecordFingerprint>> {
        self.fingerprints.get_or_init(|| {
            Arc::new(self.data.records.iter().map(|r| r.fingerprint()).collect())
        })
    }
}

/// Per-repository cell: the current published snapshot plus the lock that
/// serializes this repository's validate-then-commit sequences.
#[derive(Debug)]
struct RepoCell {
    current: Arc<Repository>,
    /// Serializes the validate-then-commit sequence of submissions *to
    /// this repository*. Without it two concurrent contributions both
    /// validate against the same snapshot and the second commit silently
    /// drops the first's records (lost update) — caught by
    /// `hub_e2e::concurrent_clients_consistent_state`. Being per-job, it
    /// lets contributions to different repositories commit in parallel.
    submit_lock: Arc<Mutex<()>>,
}

impl RepoCell {
    fn new(repo: Repository) -> RepoCell {
        RepoCell { current: Arc::new(repo), submit_lock: Arc::new(Mutex::new(())) }
    }

    /// Publish a new snapshot with `data`; returns the new revision.
    fn publish(&mut self, data: Dataset) -> u64 {
        let next = self.current.with_data(data);
        let revision = next.revision;
        self.current = Arc::new(next);
        revision
    }
}

/// Shared hub state: job → published repository snapshot.
///
/// Lock ordering (must be respected by every method): a per-job
/// `submit_lock` is always taken *before* the `repos` map lock, and the
/// map lock is never held while waiting on a submit lock — the submit
/// path clones the lock handle out of the map first, then acquires it.
#[derive(Debug, Default)]
pub struct HubState {
    repos: RwLock<BTreeMap<JobKind, RepoCell>>,
    /// Durable store (WAL + snapshots), if attached — see
    /// [`HubState::set_storage`]. Behind a leaf lock read once per
    /// submit; never held across I/O.
    storage: RwLock<Option<Arc<DurableStore>>>,
    accepted: AtomicU64,
    rejected: AtomicU64,
}

impl HubState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a repository. Setup-time only: replacing a
    /// repo mid-traffic also replaces its submit lock.
    pub fn insert(&self, repo: Repository) {
        self.repos.write().unwrap().insert(repo.job, RepoCell::new(repo));
    }

    /// Attach a durable store: from now on every accepted submission is
    /// appended to its repository's WAL before the publish that makes it
    /// visible, so an acknowledged submit survives a crash
    /// ([`DurableStore::open`] replays it). Call at setup time, *after*
    /// installing any recovered repositories.
    ///
    /// Refuses to attach when a repository already holds state the store
    /// does not cover (records or a non-zero revision with no matching
    /// snapshot/WAL coverage): recovery rebuilds a repo *only* from the
    /// store, so attaching over uncovered state would silently lose it at
    /// the next restart. Write a baseline snapshot first
    /// ([`HubState::snapshot_to`]) — as `c3o serve` does at boot.
    pub fn set_storage(&self, store: Arc<DurableStore>) -> crate::Result<()> {
        if let Some(repo) = self.first_uncovered(&store) {
            anyhow::bail!(
                "repository {} holds {} records at revision {} that the durable \
                 store does not cover (store knows {:?}); write a baseline \
                 snapshot (HubState::snapshot_to) before attaching storage",
                repo.job,
                repo.data.len(),
                repo.revision,
                store.coverage(repo.job)
            );
        }
        *self.storage.write().unwrap() = Some(store);
        Ok(())
    }

    /// The first repository holding state `store` does not cover — the
    /// single predicate behind both the boot-time baseline snapshot
    /// decision (`c3o serve`) and [`HubState::set_storage`]'s refusal.
    /// `None` means every repository is either empty at revision 0
    /// (recovery would start it empty too) or exactly covered.
    pub fn first_uncovered(&self, store: &DurableStore) -> Option<Arc<Repository>> {
        let repos = self.repos.read().unwrap();
        for cell in repos.values() {
            let repo = &cell.current;
            if repo.data.is_empty() && repo.revision == 0 {
                continue; // nothing to lose
            }
            if store.coverage(repo.job) != Some((repo.revision, repo.data.len())) {
                return Some(cell.current.clone());
            }
        }
        None
    }

    /// The attached durable store, if any.
    pub fn storage(&self) -> Option<Arc<DurableStore>> {
        self.storage.read().unwrap().clone()
    }

    /// Detach the durable store, returning the handle. Subsequent
    /// submissions are no longer WAL-logged; dropping the returned `Arc`
    /// (all clones) releases the data dir's single-writer lock, letting
    /// another store open it — the controlled-handover path used by
    /// restart tests and maintenance flows.
    pub fn detach_storage(&self) -> Option<Arc<DurableStore>> {
        self.storage.write().unwrap().take()
    }

    /// Install one recovered repository (crash recovery): the recovered
    /// data and revision watermark replace the current snapshot, so
    /// revisions stay strictly monotone across the restart and the
    /// service's revision-keyed fitted-model cache can never serve a
    /// stale model. Metadata comes from the snapshot manifest when it
    /// captured any, and is otherwise kept from the already-registered
    /// repository.
    pub fn install_recovered(&self, rec: RecoveredRepo) {
        let mut repos = self.repos.write().unwrap();
        match repos.get_mut(&rec.job) {
            Some(cell) => {
                let next = Repository {
                    job: rec.job,
                    maintainer_machine: rec
                        .maintainer_machine
                        .or_else(|| cell.current.maintainer_machine.clone()),
                    description: rec
                        .description
                        .unwrap_or_else(|| cell.current.description.clone()),
                    data: rec.data,
                    revision: rec.revision,
                    view: OnceLock::new(),
                    fingerprints: OnceLock::new(),
                };
                cell.current = Arc::new(next);
            }
            None => {
                repos.insert(
                    rec.job,
                    RepoCell::new(Repository {
                        job: rec.job,
                        maintainer_machine: rec.maintainer_machine,
                        description: rec
                            .description
                            .unwrap_or_else(|| format!("recovered {} repository", rec.job)),
                        data: rec.data,
                        revision: rec.revision,
                        view: OnceLock::new(),
                        fingerprints: OnceLock::new(),
                    }),
                );
            }
        }
    }

    /// Write a compacted snapshot of every repository to `store`: TSV per
    /// repo plus the manifest carrying description / maintainer metadata
    /// and each repo's revision watermark. The store then compacts the
    /// WALs. Returns the published snapshot sequence.
    pub fn snapshot_to(&self, store: &DurableStore) -> crate::Result<u64> {
        // Capture the published snapshots first (one Arc clone each), so
        // the map lock is not held across snapshot I/O.
        let snaps: Vec<Arc<Repository>> = {
            let repos = self.repos.read().unwrap();
            repos.values().map(|cell| cell.current.clone()).collect()
        };
        let images: Vec<RepoImage<'_>> = snaps
            .iter()
            .map(|r| RepoImage {
                job: r.job,
                revision: r.revision,
                description: &r.description,
                maintainer_machine: r.maintainer_machine.as_deref(),
                data: &r.data,
            })
            .collect();
        store.snapshot(&images)
    }

    pub fn jobs(&self) -> Vec<JobKind> {
        self.repos.read().unwrap().keys().copied().collect()
    }

    /// Current snapshot of `job`'s repository: one `Arc` clone, no data
    /// copy. The snapshot stays valid (and immutable) while later commits
    /// publish newer ones.
    pub fn get(&self, job: JobKind) -> Option<Arc<Repository>> {
        self.repos.read().unwrap().get(&job).map(|cell| cell.current.clone())
    }

    /// Replace a repo's dataset (post-validation commit) by publishing a
    /// new snapshot. Bumps the repo's revision so cached fitted models
    /// keyed on the old revision go stale; returns the post-commit
    /// revision.
    pub fn commit_data(&self, job: JobKind, data: Dataset) -> crate::Result<u64> {
        let mut repos = self.repos.write().unwrap();
        let cell = repos
            .get_mut(&job)
            .with_context(|| format!("no repository for {job}"))?;
        let revision = cell.publish(data);
        self.accepted.fetch_add(1, Ordering::Relaxed);
        Ok(revision)
    }

    /// Current dataset revision of `job`'s repository.
    pub fn revision(&self, job: JobKind) -> Option<u64> {
        self.repos.read().unwrap().get(&job).map(|cell| cell.current.revision)
    }

    pub fn note_rejection(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Atomic submission: validate `contribution` against the *current*
    /// dataset and merge it in one critical section (§III-C-b gate).
    /// Returns the verdict together with the repository revision as of
    /// *this* submission — read inside the critical section, so a
    /// concurrent later submit cannot leak its revision into this reply.
    ///
    /// The critical section is per-repository: submissions to different
    /// jobs validate and commit fully in parallel.
    ///
    /// With a durable store attached, an accepted contribution is
    /// WAL-appended (carrying its commit revision) *before* the publish:
    /// a failed append returns an error with nothing committed — the
    /// submit is simply not acknowledged — while a crash after the append
    /// replays on recovery, so acknowledged submits are never lost.
    /// Rejected contributions touch neither the WAL nor the state.
    pub fn submit(
        &self,
        contribution: crate::data::Dataset,
        policy: &super::validate::ValidationPolicy,
    ) -> crate::Result<(super::validate::Verdict, u64)> {
        let job = contribution.job;
        // Clone the lock handle out of the map before acquiring it, so the
        // map lock is never held while a (potentially slow) validation of
        // another submission to the same job is in flight.
        let lock = {
            let repos = self.repos.read().unwrap();
            repos
                .get(&job)
                .with_context(|| format!("no repository for {job}"))?
                .submit_lock
                .clone()
        };
        let _guard = lock.lock().unwrap();
        let repo = self
            .get(job)
            .with_context(|| format!("no repository for {job}"))?;
        // The duplicate-replay gate gets this revision's cached
        // fingerprint set, so only the contribution is hashed per submit.
        let verdict = super::validate::validate_contribution_cached(
            &repo.data,
            repo.fingerprints(),
            &contribution,
            policy,
        )?;
        let revision = if verdict.accepted {
            let store = self.storage();
            // Serialize before the records are consumed by the merge: the
            // WAL logs exactly what was accepted.
            let wal_tsv = if store.is_some() {
                Some(contribution.to_table()?.to_text()?)
            } else {
                None
            };
            let mut merged = repo.data.clone();
            for rec in contribution.records {
                merged.push(rec)?;
            }
            if let (Some(store), Some(tsv)) = (&store, &wal_tsv) {
                store.append(job, repo.revision + 1, tsv)?;
            }
            self.commit_data(job, merged)?
        } else {
            self.note_rejection();
            repo.revision
        };
        Ok((verdict, revision))
    }

    /// Validation-free replication apply (DESIGN.md §11): install one
    /// leader-committed WAL record into this hub's state, bit-identical
    /// and gap-free. Used by follower hubs tailing a leader's log — the
    /// record already passed the leader's §III-C-b gate, so re-validating
    /// here could only *diverge* the replica (e.g. a policy difference
    /// rejecting what the leader accepted).
    ///
    /// Refuses any record that is not exactly `local revision + 1`: a gap
    /// means the follower fell behind the leader's compaction horizon (or
    /// the feed is corrupt) and must re-bootstrap from a snapshot instead
    /// of silently skipping revisions. With a durable store attached the
    /// record is WAL-appended before the publish, exactly like
    /// [`HubState::submit`] — so a follower is itself durable and a
    /// restart resumes from its own watermark. Returns the post-apply
    /// revision (always `revision`).
    ///
    /// The accepted counter advances like a local submit, mirroring the
    /// leader's count for the replicated records.
    pub fn apply_replicated(
        &self,
        job: JobKind,
        revision: u64,
        data_tsv: &str,
    ) -> crate::Result<u64> {
        // Same lock discipline as submit(): clone the per-job lock handle
        // out of the map, then acquire it — never hold the map lock while
        // waiting.
        let lock = {
            let repos = self.repos.read().unwrap();
            repos
                .get(&job)
                .with_context(|| format!("no repository for {job}"))?
                .submit_lock
                .clone()
        };
        let _guard = lock.lock().unwrap();
        let repo = self
            .get(job)
            .with_context(|| format!("no repository for {job}"))?;
        anyhow::ensure!(
            revision == repo.revision + 1,
            "replication gap for {job}: local revision {}, record claims {} — \
             refusing to apply out of order",
            repo.revision,
            revision
        );
        let contribution = crate::util::tsv::Table::parse(data_tsv)
            .and_then(|t| Dataset::from_table(job, &t))
            .with_context(|| format!("parsing replicated record {revision} for {job}"))?;
        // Durability before visibility, as in submit(): log the record
        // verbatim so the follower's own WAL stays byte-compatible with
        // the leader's.
        if let Some(store) = self.storage() {
            store.append(job, revision, data_tsv)?;
        }
        let mut merged = repo.data.clone();
        for rec in contribution.records {
            merged.push(rec)?;
        }
        self.commit_data(job, merged)
    }

    pub fn counters(&self) -> (u64, u64) {
        (self.accepted.load(Ordering::Relaxed), self.rejected.load(Ordering::Relaxed))
    }

    /// Persist all repositories as TSV files under `dir`.
    pub fn save(&self, dir: &Path) -> crate::Result<()> {
        for (job, cell) in self.repos.read().unwrap().iter() {
            cell.current.data.save(&dir.join(format!("{job}.tsv")))?;
        }
        Ok(())
    }

    /// Load repositories from TSV files under `dir` (missing files
    /// skipped). Like every committed dataset change, each load bumps the
    /// repo's revision so fitted models cached against the old data go
    /// stale.
    ///
    /// TSV dirs carry *data only*: an already-registered repository keeps
    /// its description and maintainer designation (only its dataset is
    /// replaced). Full metadata restoration is the storage manifest's job
    /// — see [`HubState::install_recovered`].
    pub fn load(&self, dir: &Path) -> crate::Result<usize> {
        self.load_except(dir, &[])
    }

    /// [`HubState::load`], skipping `skip` — the jobs a durable store
    /// already recovered, whose state must not be overwritten by stale
    /// seed TSVs.
    pub fn load_except(&self, dir: &Path, skip: &[JobKind]) -> crate::Result<usize> {
        let mut loaded = 0;
        for job in JobKind::ALL {
            if skip.contains(&job) {
                continue;
            }
            let path = dir.join(format!("{job}.tsv"));
            if path.exists() {
                let data = Dataset::load(job, &path)?;
                let mut repos = self.repos.write().unwrap();
                repos
                    .entry(job)
                    .or_insert_with(|| {
                        RepoCell::new(Repository::new(
                            job,
                            &format!("imported from {}", path.display()),
                        ))
                    })
                    .publish(data);
                loaded += 1;
            }
        }
        Ok(loaded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RunRecord;

    fn rec(s: u32) -> RunRecord {
        RunRecord {
            machine_type: "m5.xlarge".into(),
            scale_out: s,
            data_size_gb: 10.0,
            context: vec![],
            runtime_s: 100.0 / s as f64,
        }
    }

    #[test]
    fn insert_get_roundtrip() {
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "sort lines");
        repo.data.push(rec(2)).unwrap();
        hub.insert(repo);
        assert_eq!(hub.jobs(), vec![JobKind::Sort]);
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);
        assert!(hub.get(JobKind::Grep).is_none());
    }

    #[test]
    fn get_returns_shared_snapshot_not_deep_copy() {
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "");
        repo.data.push(rec(2)).unwrap();
        hub.insert(repo);

        // Two reads of the same revision share one allocation.
        let a = hub.get(JobKind::Sort).unwrap();
        let b = hub.get(JobKind::Sort).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "get() must hand out the published Arc");

        // A commit publishes a *new* snapshot; the old one is untouched.
        let mut ds = a.data.clone();
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        let c = hub.get(JobKind::Sort).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(a.revision, 0);
        assert_eq!(a.data.len(), 1, "held snapshot is immutable");
        assert_eq!(c.revision, 1);
        assert_eq!(c.data.len(), 2);
    }

    #[test]
    fn view_is_built_once_per_snapshot() {
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "");
        for s in [2, 4, 6, 8] {
            repo.data.push(rec(s)).unwrap();
        }
        hub.insert(repo);
        let snap = hub.get(JobKind::Sort).unwrap();
        let v1 = snap.view().clone();
        let v2 = hub.get(JobKind::Sort).unwrap().view().clone();
        assert!(Arc::ptr_eq(&v1, &v2), "same revision shares one view");
        assert_eq!(v1.rows("m5.xlarge"), 4);

        // A new revision gets a fresh view.
        let mut ds = snap.data.clone();
        ds.push(rec(10)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        let v3 = hub.get(JobKind::Sort).unwrap().view().clone();
        assert!(!Arc::ptr_eq(&v1, &v3));
        assert_eq!(v3.rows("m5.xlarge"), 5);
    }

    #[test]
    fn commit_updates_and_counts() {
        let hub = HubState::new();
        hub.insert(Repository::new(JobKind::Sort, ""));
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);
        assert_eq!(hub.counters(), (1, 0));
        hub.note_rejection();
        assert_eq!(hub.counters(), (1, 1));
    }

    #[test]
    fn commit_bumps_revision_per_repo() {
        let hub = HubState::new();
        hub.insert(Repository::new(JobKind::Sort, ""));
        hub.insert(Repository::new(JobKind::Grep, ""));
        assert_eq!(hub.revision(JobKind::Sort), Some(0));
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds.clone()).unwrap();
        assert_eq!(hub.revision(JobKind::Sort), Some(1));
        ds.push(rec(6)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        assert_eq!(hub.revision(JobKind::Sort), Some(2));
        // Other repositories are untouched.
        assert_eq!(hub.revision(JobKind::Grep), Some(0));
        assert_eq!(hub.revision(JobKind::KMeans), None);
    }

    #[test]
    fn commit_to_missing_repo_fails() {
        let hub = HubState::new();
        assert!(hub.commit_data(JobKind::Grep, Dataset::new(JobKind::Grep)).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("c3o_hub_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "");
        repo.data.push(rec(2)).unwrap();
        repo.data.push(rec(4)).unwrap();
        hub.insert(repo);
        hub.save(&dir).unwrap();

        let hub2 = HubState::new();
        let loaded = hub2.load(&dir).unwrap();
        assert_eq!(loaded, 1);
        assert_eq!(hub2.get(JobKind::Sort).unwrap().data.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_keeps_registered_metadata() {
        let dir = std::env::temp_dir()
            .join(format!("c3o_hub_meta_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "standard Spark sort");
        repo.maintainer_machine = Some("m5.xlarge".into());
        repo.data.push(rec(2)).unwrap();
        hub.insert(repo);
        hub.save(&dir).unwrap();

        // Reload into a hub that registered the repo with real metadata:
        // the TSV carries data only, the registration's intent stays.
        let hub2 = HubState::new();
        let mut registered = Repository::new(JobKind::Sort, "standard Spark sort");
        registered.maintainer_machine = Some("m5.xlarge".into());
        hub2.insert(registered);
        assert_eq!(hub2.load(&dir).unwrap(), 1);
        let loaded = hub2.get(JobKind::Sort).unwrap();
        assert_eq!(loaded.description, "standard Spark sort");
        assert_eq!(loaded.maintainer_machine.as_deref(), Some("m5.xlarge"));
        assert_eq!(loaded.data.len(), 1);
        assert_eq!(loaded.revision, 1, "a load is a committed dataset change");

        // load_except skips recovered jobs entirely.
        let hub3 = HubState::new();
        assert_eq!(hub3.load_except(&dir, &[JobKind::Sort]).unwrap(), 0);
        assert!(hub3.get(JobKind::Sort).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn install_recovered_sets_watermark_and_merges_metadata() {
        let hub = HubState::new();
        let mut registered = Repository::new(JobKind::Sort, "standard Spark sort");
        registered.maintainer_machine = Some("m5.xlarge".into());
        hub.insert(registered);

        // WAL-only recovery (no manifest metadata): data + revision land,
        // the registered metadata survives.
        let mut data = Dataset::new(JobKind::Sort);
        data.push(rec(2)).unwrap();
        data.push(rec(4)).unwrap();
        hub.install_recovered(crate::storage::RecoveredRepo {
            job: JobKind::Sort,
            revision: 5,
            description: None,
            maintainer_machine: None,
            data,
            replayed: 2,
        });
        let repo = hub.get(JobKind::Sort).unwrap();
        assert_eq!(repo.revision, 5, "recovered watermark installed verbatim");
        assert_eq!(repo.data.len(), 2);
        assert_eq!(repo.description, "standard Spark sort");
        assert_eq!(repo.maintainer_machine.as_deref(), Some("m5.xlarge"));

        // Manifest-backed recovery of an unregistered repo brings its own
        // metadata.
        hub.install_recovered(crate::storage::RecoveredRepo {
            job: JobKind::Grep,
            revision: 3,
            description: Some("grepping".into()),
            maintainer_machine: Some("c5.xlarge".into()),
            data: Dataset::new(JobKind::Grep),
            replayed: 0,
        });
        let repo = hub.get(JobKind::Grep).unwrap();
        assert_eq!(repo.revision, 3);
        assert_eq!(repo.description, "grepping");
        assert_eq!(repo.maintainer_machine.as_deref(), Some("c5.xlarge"));

        // Revisions keep climbing from the recovered watermark.
        let mut ds = hub.get(JobKind::Sort).unwrap().data.clone();
        ds.push(rec(6)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        assert_eq!(hub.revision(JobKind::Sort), Some(6));
    }

    #[test]
    fn apply_replicated_lands_exact_revision_bit_identical() {
        let leader = HubState::new();
        let follower = HubState::new();
        for hub in [&leader, &follower] {
            hub.insert(Repository::new(JobKind::Sort, "sort"));
        }
        // Two "submits" on the leader, shipped to the follower as TSV.
        for batch in 0..2u32 {
            let mut ds = Dataset::new(JobKind::Sort);
            ds.push(rec(2 + 4 * batch)).unwrap();
            ds.push(rec(4 + 4 * batch)).unwrap();
            let tsv = ds.to_table().unwrap().to_text().unwrap();
            let mut merged = leader.get(JobKind::Sort).unwrap().data.clone();
            for r in ds.records {
                merged.push(r).unwrap();
            }
            let rev = leader.commit_data(JobKind::Sort, merged).unwrap();
            let applied = follower.apply_replicated(JobKind::Sort, rev, &tsv).unwrap();
            assert_eq!(applied, rev, "replica lands exactly the leader's revision");
        }
        let l = leader.get(JobKind::Sort).unwrap();
        let f = follower.get(JobKind::Sort).unwrap();
        assert_eq!(l.revision, f.revision);
        assert_eq!(l.data.len(), f.data.len());
        for (a, b) in l.data.records.iter().zip(f.data.records.iter()) {
            assert_eq!(a.fingerprint(), b.fingerprint(), "bit-identical records");
        }
        // The accepted counter mirrors the leader's.
        assert_eq!(follower.counters(), (2, 0));
    }

    #[test]
    fn apply_replicated_refuses_gaps_and_replays() {
        let hub = HubState::new();
        hub.insert(Repository::new(JobKind::Sort, ""));
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec(2)).unwrap();
        let tsv = ds.to_table().unwrap().to_text().unwrap();

        // Gap: revision 3 onto revision 0.
        let err = hub.apply_replicated(JobKind::Sort, 3, &tsv).unwrap_err();
        assert!(err.to_string().contains("replication gap"), "{err}");
        assert_eq!(hub.revision(JobKind::Sort), Some(0), "nothing applied");

        // In-order apply lands.
        assert_eq!(hub.apply_replicated(JobKind::Sort, 1, &tsv).unwrap(), 1);

        // Replay of the same revision is refused (no double-apply).
        let err = hub.apply_replicated(JobKind::Sort, 1, &tsv).unwrap_err();
        assert!(err.to_string().contains("replication gap"), "{err}");
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);

        // Unknown repository is an error, not a panic.
        assert!(hub.apply_replicated(JobKind::KMeans, 1, &tsv).is_err());
    }

    #[test]
    fn parallel_submits_to_different_jobs_do_not_serialize_state() {
        // Submissions to different repositories take different locks; this
        // exercises the commit paths racing on the shared map without a
        // global submit lock. (Timing is not asserted — only safety.)
        let hub = Arc::new(HubState::new());
        for job in [JobKind::Sort, JobKind::Grep] {
            hub.insert(Repository::new(job, ""));
        }
        let mut handles = Vec::new();
        for job in [JobKind::Sort, JobKind::Grep] {
            let hub = hub.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..20u32 {
                    let snap = hub.get(job).unwrap();
                    let mut ds = snap.data.clone();
                    let mut r = rec(2 + (i % 10));
                    if job == JobKind::Grep {
                        r.context = vec![0.01];
                    }
                    ds.push(r).unwrap();
                    hub.commit_data(job, ds).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(hub.revision(JobKind::Sort), Some(20));
        assert_eq!(hub.revision(JobKind::Grep), Some(20));
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 20);
        assert_eq!(hub.get(JobKind::Grep).unwrap().data.len(), 20);
        assert_eq!(hub.counters().0, 40);
    }
}
