//! Hub repositories: job metadata + shared runtime data.
//!
//! Concurrency model (DESIGN.md §7): every committed dataset change
//! publishes a fresh immutable [`Repository`] snapshot behind an `Arc`, so
//! readers get the current snapshot with one `Arc` clone — never a deep
//! `Dataset` copy — and keep reading their snapshot while later commits
//! publish newer ones. Writes to *different* repositories serialize only
//! on their own per-job submit lock, so contributions to different jobs
//! validate and commit in parallel.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use anyhow::Context;

use crate::data::{Dataset, FeatureMatrix, JobKind};

/// One C3O repository (paper Fig. 4, step 1-2): a common job, its
/// maintainer-designated machine type, and the shared runtime data.
///
/// A `Repository` value is an immutable snapshot once published through
/// [`HubState`]; dataset changes build and publish a *new* snapshot with a
/// bumped revision (copy-on-write).
#[derive(Debug, Clone)]
pub struct Repository {
    pub job: JobKind,
    /// Maintainer's machine-type designation (§IV-A), if made.
    pub maintainer_machine: Option<String>,
    /// Short human description shown in hub listings.
    pub description: String,
    pub data: Dataset,
    /// Monotonic dataset revision: bumped on every committed dataset
    /// change, so the PredictionService's fitted-model cache can detect
    /// staleness with a single integer comparison.
    pub revision: u64,
    /// Columnar training view of `data`, built at most once per revision:
    /// the snapshot is immutable, so every fit against this revision
    /// reuses the same feature matrices (see [`FeatureMatrix`]).
    view: OnceLock<Arc<FeatureMatrix>>,
}

impl Repository {
    pub fn new(job: JobKind, description: &str) -> Self {
        Repository {
            job,
            maintainer_machine: None,
            description: description.to_string(),
            data: Dataset::new(job),
            revision: 0,
            view: OnceLock::new(),
        }
    }

    /// Copy-on-write step: the same repository metadata with `data`
    /// replaced and the revision bumped (the view cache starts empty and
    /// is rebuilt lazily for the new revision).
    fn with_data(&self, data: Dataset) -> Repository {
        Repository {
            job: self.job,
            maintainer_machine: self.maintainer_machine.clone(),
            description: self.description.clone(),
            data,
            revision: self.revision + 1,
            view: OnceLock::new(),
        }
    }

    /// The columnar training view of this snapshot's data, built on first
    /// use and shared by every subsequent fit against this revision.
    pub fn view(&self) -> &Arc<FeatureMatrix> {
        self.view.get_or_init(|| Arc::new(self.data.feature_view()))
    }
}

/// Per-repository cell: the current published snapshot plus the lock that
/// serializes this repository's validate-then-commit sequences.
#[derive(Debug)]
struct RepoCell {
    current: Arc<Repository>,
    /// Serializes the validate-then-commit sequence of submissions *to
    /// this repository*. Without it two concurrent contributions both
    /// validate against the same snapshot and the second commit silently
    /// drops the first's records (lost update) — caught by
    /// `hub_e2e::concurrent_clients_consistent_state`. Being per-job, it
    /// lets contributions to different repositories commit in parallel.
    submit_lock: Arc<Mutex<()>>,
}

impl RepoCell {
    fn new(repo: Repository) -> RepoCell {
        RepoCell { current: Arc::new(repo), submit_lock: Arc::new(Mutex::new(())) }
    }

    /// Publish a new snapshot with `data`; returns the new revision.
    fn publish(&mut self, data: Dataset) -> u64 {
        let next = self.current.with_data(data);
        let revision = next.revision;
        self.current = Arc::new(next);
        revision
    }
}

/// Shared hub state: job → published repository snapshot.
///
/// Lock ordering (must be respected by every method): a per-job
/// `submit_lock` is always taken *before* the `repos` map lock, and the
/// map lock is never held while waiting on a submit lock — the submit
/// path clones the lock handle out of the map first, then acquires it.
#[derive(Debug, Default)]
pub struct HubState {
    repos: RwLock<BTreeMap<JobKind, RepoCell>>,
    accepted: AtomicU64,
    rejected: AtomicU64,
}

impl HubState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a repository. Setup-time only: replacing a
    /// repo mid-traffic also replaces its submit lock.
    pub fn insert(&self, repo: Repository) {
        self.repos.write().unwrap().insert(repo.job, RepoCell::new(repo));
    }

    pub fn jobs(&self) -> Vec<JobKind> {
        self.repos.read().unwrap().keys().copied().collect()
    }

    /// Current snapshot of `job`'s repository: one `Arc` clone, no data
    /// copy. The snapshot stays valid (and immutable) while later commits
    /// publish newer ones.
    pub fn get(&self, job: JobKind) -> Option<Arc<Repository>> {
        self.repos.read().unwrap().get(&job).map(|cell| cell.current.clone())
    }

    /// Replace a repo's dataset (post-validation commit) by publishing a
    /// new snapshot. Bumps the repo's revision so cached fitted models
    /// keyed on the old revision go stale; returns the post-commit
    /// revision.
    pub fn commit_data(&self, job: JobKind, data: Dataset) -> crate::Result<u64> {
        let mut repos = self.repos.write().unwrap();
        let cell = repos
            .get_mut(&job)
            .with_context(|| format!("no repository for {job}"))?;
        let revision = cell.publish(data);
        self.accepted.fetch_add(1, Ordering::Relaxed);
        Ok(revision)
    }

    /// Current dataset revision of `job`'s repository.
    pub fn revision(&self, job: JobKind) -> Option<u64> {
        self.repos.read().unwrap().get(&job).map(|cell| cell.current.revision)
    }

    pub fn note_rejection(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Atomic submission: validate `contribution` against the *current*
    /// dataset and merge it in one critical section (§III-C-b gate).
    /// Returns the verdict together with the repository revision as of
    /// *this* submission — read inside the critical section, so a
    /// concurrent later submit cannot leak its revision into this reply.
    ///
    /// The critical section is per-repository: submissions to different
    /// jobs validate and commit fully in parallel.
    pub fn submit(
        &self,
        contribution: crate::data::Dataset,
        policy: &super::validate::ValidationPolicy,
    ) -> crate::Result<(super::validate::Verdict, u64)> {
        let job = contribution.job;
        // Clone the lock handle out of the map before acquiring it, so the
        // map lock is never held while a (potentially slow) validation of
        // another submission to the same job is in flight.
        let lock = {
            let repos = self.repos.read().unwrap();
            repos
                .get(&job)
                .with_context(|| format!("no repository for {job}"))?
                .submit_lock
                .clone()
        };
        let _guard = lock.lock().unwrap();
        let repo = self
            .get(job)
            .with_context(|| format!("no repository for {job}"))?;
        let verdict = super::validate::validate_contribution(&repo.data, &contribution, policy)?;
        let revision = if verdict.accepted {
            let mut merged = repo.data.clone();
            for rec in contribution.records {
                merged.push(rec)?;
            }
            self.commit_data(job, merged)?
        } else {
            self.note_rejection();
            repo.revision
        };
        Ok((verdict, revision))
    }

    pub fn counters(&self) -> (u64, u64) {
        (self.accepted.load(Ordering::Relaxed), self.rejected.load(Ordering::Relaxed))
    }

    /// Persist all repositories as TSV files under `dir`.
    pub fn save(&self, dir: &Path) -> crate::Result<()> {
        for (job, cell) in self.repos.read().unwrap().iter() {
            cell.current.data.save(&dir.join(format!("{job}.tsv")))?;
        }
        Ok(())
    }

    /// Load repositories from TSV files under `dir` (missing files
    /// skipped). Like every committed dataset change, each load bumps the
    /// repo's revision so fitted models cached against the old data go
    /// stale.
    pub fn load(&self, dir: &Path) -> crate::Result<usize> {
        let mut loaded = 0;
        for job in JobKind::ALL {
            let path = dir.join(format!("{job}.tsv"));
            if path.exists() {
                let data = Dataset::load(job, &path)?;
                let mut repos = self.repos.write().unwrap();
                repos
                    .entry(job)
                    .or_insert_with(|| RepoCell::new(Repository::new(job, "loaded from disk")))
                    .publish(data);
                loaded += 1;
            }
        }
        Ok(loaded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RunRecord;

    fn rec(s: u32) -> RunRecord {
        RunRecord {
            machine_type: "m5.xlarge".into(),
            scale_out: s,
            data_size_gb: 10.0,
            context: vec![],
            runtime_s: 100.0 / s as f64,
        }
    }

    #[test]
    fn insert_get_roundtrip() {
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "sort lines");
        repo.data.push(rec(2)).unwrap();
        hub.insert(repo);
        assert_eq!(hub.jobs(), vec![JobKind::Sort]);
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);
        assert!(hub.get(JobKind::Grep).is_none());
    }

    #[test]
    fn get_returns_shared_snapshot_not_deep_copy() {
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "");
        repo.data.push(rec(2)).unwrap();
        hub.insert(repo);

        // Two reads of the same revision share one allocation.
        let a = hub.get(JobKind::Sort).unwrap();
        let b = hub.get(JobKind::Sort).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "get() must hand out the published Arc");

        // A commit publishes a *new* snapshot; the old one is untouched.
        let mut ds = a.data.clone();
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        let c = hub.get(JobKind::Sort).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(a.revision, 0);
        assert_eq!(a.data.len(), 1, "held snapshot is immutable");
        assert_eq!(c.revision, 1);
        assert_eq!(c.data.len(), 2);
    }

    #[test]
    fn view_is_built_once_per_snapshot() {
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "");
        for s in [2, 4, 6, 8] {
            repo.data.push(rec(s)).unwrap();
        }
        hub.insert(repo);
        let snap = hub.get(JobKind::Sort).unwrap();
        let v1 = snap.view().clone();
        let v2 = hub.get(JobKind::Sort).unwrap().view().clone();
        assert!(Arc::ptr_eq(&v1, &v2), "same revision shares one view");
        assert_eq!(v1.rows("m5.xlarge"), 4);

        // A new revision gets a fresh view.
        let mut ds = snap.data.clone();
        ds.push(rec(10)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        let v3 = hub.get(JobKind::Sort).unwrap().view().clone();
        assert!(!Arc::ptr_eq(&v1, &v3));
        assert_eq!(v3.rows("m5.xlarge"), 5);
    }

    #[test]
    fn commit_updates_and_counts() {
        let hub = HubState::new();
        hub.insert(Repository::new(JobKind::Sort, ""));
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);
        assert_eq!(hub.counters(), (1, 0));
        hub.note_rejection();
        assert_eq!(hub.counters(), (1, 1));
    }

    #[test]
    fn commit_bumps_revision_per_repo() {
        let hub = HubState::new();
        hub.insert(Repository::new(JobKind::Sort, ""));
        hub.insert(Repository::new(JobKind::Grep, ""));
        assert_eq!(hub.revision(JobKind::Sort), Some(0));
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds.clone()).unwrap();
        assert_eq!(hub.revision(JobKind::Sort), Some(1));
        ds.push(rec(6)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        assert_eq!(hub.revision(JobKind::Sort), Some(2));
        // Other repositories are untouched.
        assert_eq!(hub.revision(JobKind::Grep), Some(0));
        assert_eq!(hub.revision(JobKind::KMeans), None);
    }

    #[test]
    fn commit_to_missing_repo_fails() {
        let hub = HubState::new();
        assert!(hub.commit_data(JobKind::Grep, Dataset::new(JobKind::Grep)).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("c3o_hub_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "");
        repo.data.push(rec(2)).unwrap();
        repo.data.push(rec(4)).unwrap();
        hub.insert(repo);
        hub.save(&dir).unwrap();

        let hub2 = HubState::new();
        let loaded = hub2.load(&dir).unwrap();
        assert_eq!(loaded, 1);
        assert_eq!(hub2.get(JobKind::Sort).unwrap().data.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parallel_submits_to_different_jobs_do_not_serialize_state() {
        // Submissions to different repositories take different locks; this
        // exercises the commit paths racing on the shared map without a
        // global submit lock. (Timing is not asserted — only safety.)
        let hub = Arc::new(HubState::new());
        for job in [JobKind::Sort, JobKind::Grep] {
            hub.insert(Repository::new(job, ""));
        }
        let mut handles = Vec::new();
        for job in [JobKind::Sort, JobKind::Grep] {
            let hub = hub.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..20u32 {
                    let snap = hub.get(job).unwrap();
                    let mut ds = snap.data.clone();
                    let mut r = rec(2 + (i % 10));
                    if job == JobKind::Grep {
                        r.context = vec![0.01];
                    }
                    ds.push(r).unwrap();
                    hub.commit_data(job, ds).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(hub.revision(JobKind::Sort), Some(20));
        assert_eq!(hub.revision(JobKind::Grep), Some(20));
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 20);
        assert_eq!(hub.get(JobKind::Grep).unwrap().data.len(), 20);
        assert_eq!(hub.counters().0, 40);
    }
}
