//! Hub repositories: job metadata + shared runtime data.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::RwLock;

use anyhow::Context;

use crate::data::{Dataset, JobKind};

/// One C3O repository (paper Fig. 4, step 1-2): a common job, its
/// maintainer-designated machine type, and the shared runtime data.
#[derive(Debug, Clone)]
pub struct Repository {
    pub job: JobKind,
    /// Maintainer's machine-type designation (§IV-A), if made.
    pub maintainer_machine: Option<String>,
    /// Short human description shown in hub listings.
    pub description: String,
    pub data: Dataset,
    /// Monotonic dataset revision: bumped on every committed dataset
    /// change, so the PredictionService's fitted-model cache can detect
    /// staleness with a single integer comparison.
    pub revision: u64,
}

impl Repository {
    pub fn new(job: JobKind, description: &str) -> Self {
        Repository {
            job,
            maintainer_machine: None,
            description: description.to_string(),
            data: Dataset::new(job),
            revision: 0,
        }
    }
}

/// Shared hub state: job → repository, behind a RwLock (reads dominate).
#[derive(Debug, Default)]
pub struct HubState {
    repos: RwLock<BTreeMap<JobKind, Repository>>,
    accepted: RwLock<u64>,
    rejected: RwLock<u64>,
    /// Serializes the validate-then-commit sequence of submissions.
    /// Without it two concurrent contributions both validate against the
    /// same snapshot and the second commit silently drops the first's
    /// records (lost update) — caught by
    /// `hub_e2e::concurrent_clients_consistent_state`.
    submit_lock: std::sync::Mutex<()>,
}

impl HubState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&self, repo: Repository) {
        self.repos.write().unwrap().insert(repo.job, repo);
    }

    pub fn jobs(&self) -> Vec<JobKind> {
        self.repos.read().unwrap().keys().copied().collect()
    }

    pub fn get(&self, job: JobKind) -> Option<Repository> {
        self.repos.read().unwrap().get(&job).cloned()
    }

    /// Replace a repo's dataset (post-validation commit). Bumps the repo's
    /// revision so cached fitted models keyed on the old revision go stale;
    /// returns the post-commit revision.
    pub fn commit_data(&self, job: JobKind, data: Dataset) -> crate::Result<u64> {
        let mut repos = self.repos.write().unwrap();
        let repo = repos
            .get_mut(&job)
            .with_context(|| format!("no repository for {job}"))?;
        repo.data = data;
        repo.revision += 1;
        *self.accepted.write().unwrap() += 1;
        Ok(repo.revision)
    }

    /// Current dataset revision of `job`'s repository.
    pub fn revision(&self, job: JobKind) -> Option<u64> {
        self.repos.read().unwrap().get(&job).map(|r| r.revision)
    }

    pub fn note_rejection(&self) {
        *self.rejected.write().unwrap() += 1;
    }

    /// Atomic submission: validate `contribution` against the *current*
    /// dataset and merge it in one critical section (§III-C-b gate).
    /// Returns the verdict together with the repository revision as of
    /// *this* submission — read inside the critical section, so a
    /// concurrent later submit cannot leak its revision into this reply.
    pub fn submit(
        &self,
        contribution: crate::data::Dataset,
        policy: &super::validate::ValidationPolicy,
    ) -> crate::Result<(super::validate::Verdict, u64)> {
        let _guard = self.submit_lock.lock().unwrap();
        let job = contribution.job;
        let repo = self
            .get(job)
            .with_context(|| format!("no repository for {job}"))?;
        let existing = repo.data;
        let verdict = super::validate::validate_contribution(&existing, &contribution, policy)?;
        let revision = if verdict.accepted {
            let mut merged = existing;
            for rec in contribution.records {
                merged.push(rec)?;
            }
            self.commit_data(job, merged)?
        } else {
            self.note_rejection();
            repo.revision
        };
        Ok((verdict, revision))
    }

    pub fn counters(&self) -> (u64, u64) {
        (*self.accepted.read().unwrap(), *self.rejected.read().unwrap())
    }

    /// Persist all repositories as TSV files under `dir`.
    pub fn save(&self, dir: &Path) -> crate::Result<()> {
        for (job, repo) in self.repos.read().unwrap().iter() {
            repo.data.save(&dir.join(format!("{job}.tsv")))?;
        }
        Ok(())
    }

    /// Load repositories from TSV files under `dir` (missing files
    /// skipped). Like every committed dataset change, each load bumps the
    /// repo's revision so fitted models cached against the old data go
    /// stale.
    pub fn load(&self, dir: &Path) -> crate::Result<usize> {
        let mut loaded = 0;
        for job in JobKind::ALL {
            let path = dir.join(format!("{job}.tsv"));
            if path.exists() {
                let data = Dataset::load(job, &path)?;
                let mut repos = self.repos.write().unwrap();
                let repo = repos
                    .entry(job)
                    .or_insert_with(|| Repository::new(job, "loaded from disk"));
                repo.data = data;
                repo.revision += 1;
                loaded += 1;
            }
        }
        Ok(loaded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::RunRecord;

    fn rec(s: u32) -> RunRecord {
        RunRecord {
            machine_type: "m5.xlarge".into(),
            scale_out: s,
            data_size_gb: 10.0,
            context: vec![],
            runtime_s: 100.0 / s as f64,
        }
    }

    #[test]
    fn insert_get_roundtrip() {
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "sort lines");
        repo.data.push(rec(2)).unwrap();
        hub.insert(repo);
        assert_eq!(hub.jobs(), vec![JobKind::Sort]);
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);
        assert!(hub.get(JobKind::Grep).is_none());
    }

    #[test]
    fn commit_updates_and_counts() {
        let hub = HubState::new();
        hub.insert(Repository::new(JobKind::Sort, ""));
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        assert_eq!(hub.get(JobKind::Sort).unwrap().data.len(), 1);
        assert_eq!(hub.counters(), (1, 0));
        hub.note_rejection();
        assert_eq!(hub.counters(), (1, 1));
    }

    #[test]
    fn commit_bumps_revision_per_repo() {
        let hub = HubState::new();
        hub.insert(Repository::new(JobKind::Sort, ""));
        hub.insert(Repository::new(JobKind::Grep, ""));
        assert_eq!(hub.revision(JobKind::Sort), Some(0));
        let mut ds = Dataset::new(JobKind::Sort);
        ds.push(rec(4)).unwrap();
        hub.commit_data(JobKind::Sort, ds.clone()).unwrap();
        assert_eq!(hub.revision(JobKind::Sort), Some(1));
        ds.push(rec(6)).unwrap();
        hub.commit_data(JobKind::Sort, ds).unwrap();
        assert_eq!(hub.revision(JobKind::Sort), Some(2));
        // Other repositories are untouched.
        assert_eq!(hub.revision(JobKind::Grep), Some(0));
        assert_eq!(hub.revision(JobKind::KMeans), None);
    }

    #[test]
    fn commit_to_missing_repo_fails() {
        let hub = HubState::new();
        assert!(hub.commit_data(JobKind::Grep, Dataset::new(JobKind::Grep)).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("c3o_hub_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let hub = HubState::new();
        let mut repo = Repository::new(JobKind::Sort, "");
        repo.data.push(rec(2)).unwrap();
        repo.data.push(rec(4)).unwrap();
        hub.insert(repo);
        hub.save(&dir).unwrap();

        let hub2 = HubState::new();
        let loaded = hub2.load(&dir).unwrap();
        assert_eq!(loaded, 1);
        assert_eq!(hub2.get(JobKind::Sort).unwrap().data.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
