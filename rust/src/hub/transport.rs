//! Readiness polling for the hub's event-loop transport (DESIGN.md §7).
//!
//! The offline crate cache has neither `mio` nor `libc`, so the OS
//! interface is hand-rolled `extern "C"` FFI against the C runtime std
//! already links: **epoll(7)** on Linux (the fast path — one O(ready)
//! syscall regardless of how many connections are registered) and
//! portable **poll(2)** everywhere else on unix (O(registered) per wait,
//! fine for the fallback). Both backends compile on Linux so tests
//! exercise the portable path too.
//!
//! The abstraction is deliberately tiny — register/modify/deregister a
//! raw fd with a `u64` token and level-triggered [`Interest`], then
//! [`Poller::wait`] for [`Event`]s — because the reactor in
//! [`crate::hub::server`] owns all buffering and framing itself.
//!
//! [`Waker`] is a nonblocking socketpair (`UnixStream::pair`): worker
//! threads write one byte to interrupt a parked `wait`, the reactor
//! drains it. No FFI needed there.

#[cfg(not(unix))]
compile_error!(
    "the c3o hub transport requires a unix platform (epoll on Linux, poll(2) elsewhere)"
);

use std::io::{self, Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::AtomicU64;
use std::time::Duration;

/// Level-triggered readiness interest for one registered fd.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    /// Read-only interest (the common case for a parked connection).
    pub const READ: Interest = Interest { readable: true, writable: false };
}

/// One readiness event. `hangup` covers error/peer-closed conditions
/// (`EPOLLERR|EPOLLHUP|EPOLLRDHUP`, `POLLERR|POLLHUP|POLLNVAL`); callers
/// should attempt a final read — pending bytes may still be buffered —
/// and let the read path discover the EOF.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

/// Transport-layer counters, shared with the prediction service so the
/// `stats` op can report them (additive v1 fields).
#[derive(Debug, Default)]
pub struct TransportStats {
    /// Currently open (accepted and registered) connections.
    pub open_connections: AtomicU64,
    /// Highwater mark of requests in flight on any single connection —
    /// the deepest pipelining any client actually used.
    pub peak_pipeline_depth: AtomicU64,
    /// Connections refused at capacity since start.
    pub refused_connections: AtomicU64,
    /// Refusal frames that could not be written to the refused peer
    /// (previously silently ignored; now counted and logged).
    pub refusal_write_failures: AtomicU64,
    /// Connections dropped because their bounded write queue overflowed
    /// (a peer that stopped reading while replies kept accumulating).
    pub slow_reader_disconnects: AtomicU64,
    /// Idle connections reaped by the sweep after `idle_timeout` with
    /// nothing in flight and nothing buffered.
    pub idle_reaped_connections: AtomicU64,
}

// ---------------------------------------------------------------------------
// epoll backend (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys_epoll {
    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    use super::{Event, Interest};

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    /// `O_CLOEXEC`: 0o2000000 on every Linux arch this crate targets.
    const EPOLL_CLOEXEC: c_int = 0o2000000;

    /// Kernel ABI: `struct epoll_event` is packed on x86-64 only.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    fn cvt(ret: c_int) -> io::Result<c_int> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    pub struct EpollPoller {
        epfd: RawFd,
        buf: Vec<EpollEvent>,
    }

    impl EpollPoller {
        pub fn new() -> io::Result<EpollPoller> {
            // SAFETY: epoll_create1 takes no pointers; EPOLL_CLOEXEC is
            // the only documented flag. A negative return is routed to
            // io::Error by cvt before the fd is ever used.
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(EpollPoller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
        }

        fn ctl(&self, op: c_int, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let mut bits = EPOLLRDHUP;
            if interest.readable {
                bits |= EPOLLIN;
            }
            if interest.writable {
                bits |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events: bits, data: token };
            // SAFETY: `ev` is a live stack value for the whole call and
            // matches the kernel's struct epoll_event ABI (repr above);
            // self.epfd was obtained from epoll_create1 and lives until
            // Drop. The kernel only reads `ev` during the syscall.
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) }).map(|_| ())
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            // Pre-2.6.9 kernels required a non-null event for DEL; passing
            // one is free and keeps the call portable.
            let mut ev = EpollEvent { events: 0, data: 0 };
            // SAFETY: same contract as `ctl` — `ev` outlives the call
            // and self.epfd is a valid epoll fd; DEL ignores the event
            // except on pre-2.6.9 kernels, which only require it
            // non-null (it is: a stack address).
            cvt(unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) }).map(|_| ())
        }

        pub fn wait(
            &mut self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            let ms: c_int = match timeout {
                None => -1,
                Some(d) => d.as_millis().min(c_int::MAX as u128) as c_int,
            };
            // SAFETY: the pointer/len pair describes self.buf's owned,
            // initialized allocation (1024 elements, never resized while
            // borrowed); the kernel writes at most `maxevents` entries
            // into it and epoll_wait returns how many. self.epfd is
            // valid until Drop.
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, ms)
            };
            let n = match cvt(n) {
                Ok(n) => n as usize,
                // A signal interrupting the wait is a spurious wakeup, not
                // an error.
                Err(e) if e.kind() == io::ErrorKind::Interrupted => 0,
                Err(e) => return Err(e),
            };
            // `n <= buf.len()`: epoll_wait never reports more events
            // than maxevents, so take(n) covers exactly the entries the
            // kernel wrote.
            for raw in self.buf.iter().take(n) {
                // Copy out of the (possibly packed) struct before use.
                let bits = raw.events;
                let token = raw.data;
                events.push(Event {
                    token,
                    readable: bits & EPOLLIN != 0,
                    writable: bits & EPOLLOUT != 0,
                    hangup: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for EpollPoller {
        fn drop(&mut self) {
            // SAFETY: self.epfd came from epoll_create1, is owned
            // exclusively by this poller, and is closed exactly once
            // (Drop runs once; no other path closes it).
            unsafe { close(self.epfd) };
        }
    }
}

// ---------------------------------------------------------------------------
// poll(2) backend (portable unix fallback)
// ---------------------------------------------------------------------------

mod sys_poll {
    use std::io;
    use std::os::raw::{c_int, c_short};
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    use super::{Event, Interest};

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;
    const POLLNVAL: c_short = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    #[cfg(target_os = "macos")]
    type NfdsT = std::os::raw::c_uint;
    #[cfg(not(target_os = "macos"))]
    type NfdsT = std::os::raw::c_ulong;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    }

    /// poll(2) rebuilds its fd array per wait from a linear registry —
    /// O(registered) per call, acceptable for a fallback measured in
    /// hundreds of connections.
    pub struct PollPoller {
        registered: Vec<(RawFd, u64, Interest)>,
        scratch: Vec<PollFd>,
    }

    impl PollPoller {
        pub fn new() -> PollPoller {
            PollPoller { registered: Vec::new(), scratch: Vec::new() }
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            if self.registered.iter().any(|&(f, _, _)| f == fd) {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    format!("fd {fd} already registered"),
                ));
            }
            self.registered.push((fd, token, interest));
            Ok(())
        }

        pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            for entry in &mut self.registered {
                if entry.0 == fd {
                    *entry = (fd, token, interest);
                    return Ok(());
                }
            }
            Err(io::Error::new(io::ErrorKind::NotFound, format!("fd {fd} not registered")))
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            let before = self.registered.len();
            self.registered.retain(|&(f, _, _)| f != fd);
            if self.registered.len() == before {
                return Err(io::Error::new(
                    io::ErrorKind::NotFound,
                    format!("fd {fd} not registered"),
                ));
            }
            Ok(())
        }

        pub fn wait(
            &mut self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            self.scratch.clear();
            for &(fd, _, interest) in &self.registered {
                let mut bits: c_short = 0;
                if interest.readable {
                    bits |= POLLIN;
                }
                if interest.writable {
                    bits |= POLLOUT;
                }
                self.scratch.push(PollFd { fd, events: bits, revents: 0 });
            }
            let ms: c_int = match timeout {
                None => -1,
                Some(d) => d.as_millis().min(c_int::MAX as u128) as c_int,
            };
            // SAFETY: scratch was just rebuilt above, so the pointer/len
            // pair describes its owned, initialized allocation; poll(2)
            // only mutates the revents field of those entries, which
            // PollFd declares with the kernel's layout.
            let n = unsafe { poll(self.scratch.as_mut_ptr(), self.scratch.len() as NfdsT, ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (slot, &(_, token, _)) in self.scratch.iter().zip(&self.registered) {
                let r = slot.revents;
                if r == 0 {
                    continue;
                }
                events.push(Event {
                    token,
                    readable: r & POLLIN != 0,
                    writable: r & POLLOUT != 0,
                    hangup: r & (POLLERR | POLLHUP | POLLNVAL) != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Backend-selecting facade
// ---------------------------------------------------------------------------

/// Readiness poller: epoll on Linux, poll(2) elsewhere. Construct the
/// default backend with [`Poller::new`]; [`Poller::poll_fallback`] forces
/// the portable backend (tests exercise it on Linux too).
pub enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(sys_epoll::EpollPoller),
    Poll(sys_poll::PollPoller),
}

impl Poller {
    /// The platform-default backend.
    #[cfg(target_os = "linux")]
    pub fn new() -> io::Result<Poller> {
        Ok(Poller::Epoll(sys_epoll::EpollPoller::new()?))
    }

    /// The platform-default backend.
    #[cfg(not(target_os = "linux"))]
    pub fn new() -> io::Result<Poller> {
        Ok(Poller::Poll(sys_poll::PollPoller::new()))
    }

    /// Force the portable poll(2) backend.
    pub fn poll_fallback() -> Poller {
        Poller::Poll(sys_poll::PollPoller::new())
    }

    /// Which backend this poller runs on ("epoll" or "poll").
    pub fn backend_name(&self) -> &'static str {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(_) => "epoll",
            Poller::Poll(_) => "poll",
        }
    }

    /// The backend [`Poller::new`] would pick on this platform.
    pub fn default_backend_name() -> &'static str {
        if cfg!(target_os = "linux") {
            "epoll"
        } else {
            "poll"
        }
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.register(fd, token, interest),
            Poller::Poll(p) => p.register(fd, token, interest),
        }
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.modify(fd, token, interest),
            Poller::Poll(p) => p.modify(fd, token, interest),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.deregister(fd),
            Poller::Poll(p) => p.deregister(fd),
        }
    }

    /// Block for up to `timeout` (forever when `None`) and append ready
    /// [`Event`]s. A signal-interrupted wait returns cleanly with no
    /// events — callers already loop.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(events, timeout),
            Poller::Poll(p) => p.wait(events, timeout),
        }
    }
}

// ---------------------------------------------------------------------------
// Cross-thread wakeup
// ---------------------------------------------------------------------------

/// Write half of the reactor wakeup channel. Cheaply cloneable across
/// worker threads; `wake` is async-signal-ish safe: one nonblocking
/// one-byte write, and a full pipe simply means a wakeup is already
/// pending.
pub struct Waker {
    tx: UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1]);
    }
}

impl Clone for Waker {
    fn clone(&self) -> Waker {
        // Falling back to a second pair would silently disconnect the
        // waker; try_clone on a socketpair only fails under fd
        // exhaustion, where the process is lost anyway.
        // lint: allow(panics, reason = "dup(2) fails only on fd exhaustion; a waker that cannot clone must not silently disconnect")
        Waker { tx: self.tx.try_clone().expect("cloning waker fd") }
    }
}

/// Read half of the wakeup channel: register `fd()` with the poller and
/// `drain()` on readiness.
pub struct WakeReceiver {
    rx: UnixStream,
}

impl WakeReceiver {
    pub fn fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Consume every pending wakeup byte.
    pub fn drain(&mut self) {
        let mut buf = [0u8; 64];
        while matches!(self.rx.read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// A connected (waker, receiver) pair, both ends nonblocking.
pub fn wake_channel() -> io::Result<(Waker, WakeReceiver)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { tx }, WakeReceiver { rx }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn backends() -> Vec<Poller> {
        vec![Poller::new().unwrap(), Poller::poll_fallback()]
    }

    #[test]
    fn accept_readiness_is_reported_with_the_right_token() {
        for mut poller in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            listener.set_nonblocking(true).unwrap();
            poller.register(listener.as_raw_fd(), 7, Interest::READ).unwrap();

            // Nothing pending: a short wait yields no events.
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(events.is_empty(), "[{}] {events:?}", poller.backend_name());

            let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
            assert!(
                events.iter().any(|e| e.token == 7 && e.readable),
                "[{}] {events:?}",
                poller.backend_name()
            );
        }
    }

    #[test]
    fn write_interest_and_modify_and_deregister() {
        for mut poller in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            client.set_nonblocking(true).unwrap();
            let fd = client.as_raw_fd();

            // A fresh connection with read-only interest is quiet...
            poller.register(fd, 1, Interest::READ).unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(events.is_empty(), "[{}] {events:?}", poller.backend_name());

            // ...and immediately writable once write interest is added.
            poller.modify(fd, 1, Interest { readable: true, writable: true }).unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
            assert!(
                events.iter().any(|e| e.token == 1 && e.writable),
                "[{}] {events:?}",
                poller.backend_name()
            );

            poller.deregister(fd).unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(events.is_empty(), "[{}] {events:?}", poller.backend_name());
        }
    }

    #[test]
    fn waker_interrupts_a_parked_wait() {
        for mut poller in backends() {
            let (waker, mut rx) = wake_channel().unwrap();
            poller.register(rx.fd(), 2, Interest::READ).unwrap();
            let t = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                waker.wake();
            });
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
            assert!(
                events.iter().any(|e| e.token == 2 && e.readable),
                "[{}] {events:?}",
                poller.backend_name()
            );
            rx.drain();
            // Drained: the next wait is quiet again.
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(events.is_empty(), "[{}] {events:?}", poller.backend_name());
            t.join().unwrap();
        }
    }

    #[test]
    fn cloned_wakers_share_the_channel() {
        let (waker, mut rx) = wake_channel().unwrap();
        let w2 = waker.clone();
        w2.wake();
        waker.wake();
        let mut poller = Poller::new().unwrap();
        poller.register(rx.fd(), 3, Interest::READ).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 3 && e.readable), "{events:?}");
        rx.drain();
    }
}
