//! Contribution validation (paper §III-C-b).
//!
//! "A possible solution … is to retrain the prediction models while
//! incorporating the new training data and then evaluating the runtime
//! predictor accuracy on a test dataset consisting of previously existing
//! datapoints. Should the evaluation exhibit a significant increase in
//! prediction errors, then the new runtime data contribution will be
//! rejected."
//!
//! Concretely: the existing data is split (deterministically per repo
//! size) into train/holdout; a reference model fitted on `train` scores a
//! baseline MAPE on `holdout`; a candidate model fitted on
//! `train ∪ contribution` is scored on the *same* holdout. The
//! contribution is accepted iff the candidate error does not exceed
//! `baseline × tolerance` (+ an absolute slack for noise at tiny sizes).

use std::collections::HashSet;

use crate::data::{Dataset, RecordFingerprint};
use crate::models::{Gbm, GbmParams, RuntimeModel, TrainData};
use crate::util::prng::Pcg;
use crate::util::stats;

/// Validation knobs.
#[derive(Debug, Clone)]
pub struct ValidationPolicy {
    /// Accept iff candidate MAPE <= baseline MAPE * tolerance + slack.
    pub tolerance: f64,
    /// Absolute slack in MAPE percentage points.
    pub slack_pp: f64,
    /// Holdout fraction of the existing data.
    pub holdout_frac: f64,
    /// Below this many existing records, schema-validate only (there is
    /// nothing meaningful to retrain against yet).
    pub min_existing: usize,
    /// Largest plausible cluster size: records claiming more instances
    /// are rejected outright (the paper's corpus tops out at 12; no
    /// public-cloud Spark job in this problem class runs thousands of
    /// nodes, so such a record is corruption or fabrication).
    pub max_scale_out: u32,
    pub seed: u64,
}

impl Default for ValidationPolicy {
    fn default() -> Self {
        ValidationPolicy {
            tolerance: 1.25,
            slack_pp: 1.0,
            holdout_frac: 0.3,
            min_existing: 12,
            max_scale_out: 512,
            seed: 0x5EED,
        }
    }
}

/// The gate's decision, with its evidence.
#[derive(Debug, Clone)]
pub struct Verdict {
    pub accepted: bool,
    pub reason: String,
    pub baseline_mape: Option<f64>,
    pub candidate_mape: Option<f64>,
}

/// Validate `contribution` against `existing` (same job).
///
/// Runtime models are per-machine-type (§VI-C), so the retrain-eval runs
/// once per machine type the contribution touches; the contribution is
/// accepted iff **every** touched slice passes. A slice whose existing
/// data is below `min_existing` bootstrap-accepts (nothing meaningful to
/// retrain against yet).
pub fn validate_contribution(
    existing: &Dataset,
    contribution: &Dataset,
    policy: &ValidationPolicy,
) -> crate::Result<Verdict> {
    let fingerprints: HashSet<RecordFingerprint> =
        existing.records.iter().map(|r| r.fingerprint()).collect();
    validate_contribution_cached(existing, &fingerprints, contribution, policy)
}

/// [`validate_contribution`] with the existing corpus's fingerprint set
/// supplied by the caller — the hub passes the per-revision cached set
/// ([`crate::hub::Repository::fingerprints`]) so each submit hashes only
/// the contribution, not the whole ever-growing corpus.
pub fn validate_contribution_cached(
    existing: &Dataset,
    existing_fingerprints: &HashSet<RecordFingerprint>,
    contribution: &Dataset,
    policy: &ValidationPolicy,
) -> crate::Result<Verdict> {
    anyhow::ensure!(existing.job == contribution.job, "job mismatch");
    if contribution.is_empty() {
        return Ok(Verdict {
            accepted: false,
            reason: "empty contribution".into(),
            baseline_mape: None,
            candidate_mape: None,
        });
    }
    // Schema re-validation (defense in depth — the wire layer parses, but
    // the gate must hold even for locally constructed datasets).
    for rec in &contribution.records {
        if let Err(e) = contribution.validate_record(rec) {
            return Ok(Verdict {
                accepted: false,
                reason: format!("schema violation: {e}"),
                baseline_mape: None,
                candidate_mape: None,
            });
        }
        if rec.scale_out > policy.max_scale_out {
            return Ok(Verdict {
                accepted: false,
                reason: format!(
                    "scale-out out of range: {} > {} instances",
                    rec.scale_out, policy.max_scale_out
                ),
                baseline_mape: None,
                candidate_mape: None,
            });
        }
    }

    // Replay defense: an exact duplicate — of an existing record, or of
    // another record in the same contribution — carries no information,
    // so resubmitting a captured contribution (or padding one with
    // copies) cannot inflate the corpus or skew the models toward one
    // observation. Real observations never collide exactly: runtimes are
    // continuous measurements. Only the contribution is hashed here; the
    // corpus side is the caller-supplied (hub: revision-cached) set.
    let mut fresh: HashSet<RecordFingerprint> = HashSet::new();
    for rec in &contribution.records {
        let fp = rec.fingerprint();
        if existing_fingerprints.contains(&fp) || !fresh.insert(fp) {
            return Ok(Verdict {
                accepted: false,
                reason: format!(
                    "duplicate record: {} x{} ({} GB, {} s) is already present",
                    rec.machine_type, rec.scale_out, rec.data_size_gb, rec.runtime_s
                ),
                baseline_mape: None,
                candidate_mape: None,
            });
        }
    }

    let mut worst: Option<(f64, f64)> = None; // (baseline, candidate) of worst slice
    let mut bootstrap_only = true;
    for mt in contribution.machine_types() {
        let slice_existing = existing.for_machine(&mt);
        let slice_contrib = contribution.for_machine(&mt);
        if slice_existing.len() < policy.min_existing {
            continue; // bootstrap slice
        }
        bootstrap_only = false;
        let (baseline, candidate) =
            retrain_eval(&slice_existing, &slice_contrib, policy)?;
        let limit = baseline * policy.tolerance + policy.slack_pp;
        if candidate > limit {
            return Ok(Verdict {
                accepted: false,
                reason: format!(
                    "prediction error degraded on {mt}: {candidate:.2}% > {limit:.2}% (baseline {baseline:.2}%)"
                ),
                baseline_mape: Some(baseline),
                candidate_mape: Some(candidate),
            });
        }
        if worst.map_or(true, |(b, c)| candidate - baseline > c - b) {
            worst = Some((baseline, candidate));
        }
    }

    if bootstrap_only {
        return Ok(Verdict {
            accepted: true,
            reason: format!(
                "bootstrap: fewer than {} existing records on the touched machine types",
                policy.min_existing
            ),
            baseline_mape: None,
            candidate_mape: None,
        });
    }
    let (baseline, candidate) = worst.expect("non-bootstrap path has a slice");
    Ok(Verdict {
        accepted: true,
        reason: format!(
            "retrain-eval ok: {candidate:.2}% <= {:.2}% (baseline {baseline:.2}%)",
            baseline * policy.tolerance + policy.slack_pp
        ),
        baseline_mape: Some(baseline),
        candidate_mape: Some(candidate),
    })
}

/// One slice's retrain-eval: returns (baseline MAPE, candidate MAPE) on a
/// deterministic holdout of the existing data.
fn retrain_eval(
    existing: &Dataset,
    contribution: &Dataset,
    policy: &ValidationPolicy,
) -> crate::Result<(f64, f64)> {
    let n = existing.len();
    let holdout_n = ((n as f64 * policy.holdout_frac).round() as usize).clamp(3, n - 6);
    let mut rng = Pcg::new(policy.seed ^ n as u64, 0xDA7A);
    let idx = rng.sample_indices(n, n);
    let (holdout_idx, train_idx) = idx.split_at(holdout_n);

    let all = TrainData::from_dataset(existing)?;
    let train = all.subset(train_idx);
    let holdout = all.subset(holdout_idx);

    // Candidate training set: train ∪ contribution.
    let contrib = TrainData::from_dataset(contribution)?;
    let mut cand_rows: Vec<Vec<f64>> =
        (0..train.len()).map(|i| train.x.row(i).to_vec()).collect();
    cand_rows.extend((0..contrib.len()).map(|i| contrib.x.row(i).to_vec()));
    let mut cand_y = train.y.clone();
    cand_y.extend_from_slice(&contrib.y);
    let cand = TrainData::new(crate::linalg::Matrix::from_rows(&cand_rows)?, cand_y)?;

    let params = GbmParams { n_estimators: 60, ..Default::default() };
    let mut base_model = Gbm::new(params);
    base_model.fit(&train)?;
    let baseline = stats::mape(&base_model.predict(&holdout.x)?, &holdout.y);

    let mut cand_model = Gbm::new(params);
    cand_model.fit(&cand)?;
    let candidate = stats::mape(&cand_model.predict(&holdout.x)?, &holdout.y);
    Ok((baseline, candidate))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::data::{JobKind, RunRecord};
    use crate::sim::{generate_job, GeneratorConfig};
    use crate::util::prng::Pcg;

    fn base_dataset() -> Dataset {
        generate_job(JobKind::Sort, &GeneratorConfig::default(), &Catalog::aws_like())
            .unwrap()
            .for_machine("m5.xlarge")
    }

    /// Honest new observations from the same workload model.
    fn honest_contribution(n: usize, seed: u64) -> Dataset {
        let catalog = Catalog::aws_like();
        let model = crate::sim::WorkloadModel::default();
        let mt = catalog.get("m5.xlarge").unwrap();
        let mut rng = Pcg::seed(seed);
        let mut ds = Dataset::new(JobKind::Sort);
        for _ in 0..n {
            let s = rng.range(2, 13) as u32;
            let d = rng.range_f64(10.0, 20.0);
            let input = crate::sim::JobInput::new(JobKind::Sort, d, vec![]);
            ds.push(model.observe(mt, s, &input, &mut rng)).unwrap();
        }
        ds
    }

    #[test]
    fn honest_data_accepted() {
        let existing = base_dataset();
        let contrib = honest_contribution(10, 1);
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(v.accepted, "{}", v.reason);
    }

    #[test]
    fn fabricated_data_rejected() {
        let existing = base_dataset();
        // Malicious: absurd runtimes (1000x) poison the model.
        let mut contrib = Dataset::new(JobKind::Sort);
        let mut rng = Pcg::seed(2);
        for _ in 0..25 {
            let s = rng.range(2, 13) as u32;
            contrib
                .push(RunRecord {
                    machine_type: "m5.xlarge".into(),
                    scale_out: s,
                    data_size_gb: rng.range_f64(10.0, 20.0),
                    context: vec![],
                    runtime_s: 1e6 + rng.f64() * 1e5,
                })
                .unwrap();
        }
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(!v.accepted, "{}", v.reason);
        assert!(v.candidate_mape.unwrap() > v.baseline_mape.unwrap());
    }

    #[test]
    fn corrupted_schema_rejected() {
        let existing = base_dataset();
        let mut contrib = Dataset::new(JobKind::Sort);
        // Bypass push-validation to emulate wire corruption.
        contrib.records.push(RunRecord {
            machine_type: "m5.xlarge".into(),
            scale_out: 4,
            data_size_gb: 15.0,
            context: vec![],
            runtime_s: f64::NAN,
        });
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(!v.accepted);
        assert!(v.reason.contains("schema"), "{}", v.reason);
    }

    #[test]
    fn empty_contribution_rejected() {
        let existing = base_dataset();
        let v = validate_contribution(
            &existing,
            &Dataset::new(JobKind::Sort),
            &ValidationPolicy::default(),
        )
        .unwrap();
        assert!(!v.accepted);
    }

    #[test]
    fn bootstrap_accepts_when_repo_is_young() {
        let mut existing = Dataset::new(JobKind::Sort);
        for r in base_dataset().records.into_iter().take(5) {
            existing.push(r).unwrap();
        }
        let contrib = honest_contribution(5, 3);
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(v.accepted);
        assert!(v.reason.contains("bootstrap"));
    }

    #[test]
    fn job_mismatch_is_an_error() {
        let existing = base_dataset();
        let contrib = Dataset::new(JobKind::Grep);
        assert!(
            validate_contribution(&existing, &contrib, &ValidationPolicy::default()).is_err()
        );
    }

    #[test]
    fn duplicate_of_existing_record_rejected() {
        let existing = base_dataset();
        // Replay attack: resubmit records already in the corpus verbatim.
        let mut contrib = Dataset::new(JobKind::Sort);
        for r in existing.records.iter().take(3).cloned() {
            contrib.push(r).unwrap();
        }
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(!v.accepted);
        assert!(v.reason.contains("duplicate"), "{}", v.reason);
        assert!(v.baseline_mape.is_none(), "rejected before any retrain");
    }

    #[test]
    fn duplicate_within_contribution_rejected() {
        let existing = base_dataset();
        let mut contrib = honest_contribution(4, 21);
        // Pad the contribution with a copy of its own first record.
        let first = contrib.records[0].clone();
        contrib.push(first).unwrap();
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(!v.accepted);
        assert!(v.reason.contains("duplicate"), "{}", v.reason);
    }

    #[test]
    fn duplicates_rejected_even_in_bootstrap_regime() {
        // The replay defense must not wait for the retrain gate to arm.
        let existing = Dataset::new(JobKind::Sort);
        let mut contrib = honest_contribution(3, 22);
        let first = contrib.records[0].clone();
        contrib.push(first).unwrap();
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(!v.accepted);
        assert!(v.reason.contains("duplicate"), "{}", v.reason);
    }

    #[test]
    fn out_of_range_scale_out_rejected() {
        let existing = base_dataset();
        let mut contrib = honest_contribution(5, 23);
        contrib.records[2].scale_out = 100_000;
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(!v.accepted);
        assert!(v.reason.contains("out of range"), "{}", v.reason);

        // scale_out 0 is a schema violation (caught even though `push`
        // was bypassed).
        let mut contrib = honest_contribution(5, 24);
        contrib.records[0].scale_out = 0;
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(!v.accepted);
        assert!(v.reason.contains("schema"), "{}", v.reason);
    }

    #[test]
    fn property_corrupt_records_always_rejected() {
        // Property: whatever single corruption a contribution carries,
        // the gate rejects the whole contribution and never errors out.
        let existing = base_dataset();
        let policy = ValidationPolicy::default();
        let mut rng = Pcg::seed(0xBAD5EED);
        for case in 0..24u64 {
            let mut contrib = honest_contribution(6, 1000 + case);
            let idx = rng.below(contrib.records.len());
            match case % 6 {
                0 => contrib.records[idx].runtime_s = f64::NAN,
                1 => contrib.records[idx].runtime_s = f64::INFINITY,
                2 => contrib.records[idx].runtime_s = -5.0,
                3 => contrib.records[idx].scale_out = 0,
                4 => contrib.records[idx].scale_out = policy.max_scale_out + 1,
                5 => contrib.records[idx].data_size_gb = -1.0,
                _ => unreachable!(),
            }
            let v = validate_contribution(&existing, &contrib, &policy).unwrap();
            assert!(!v.accepted, "case {case} accepted: {}", v.reason);
            assert!(
                v.baseline_mape.is_none() && v.candidate_mape.is_none(),
                "case {case}: corruption must be rejected before any retrain"
            );
        }
    }

    #[test]
    fn subtly_biased_data_small_amounts_tolerated() {
        // A 10% optimistic bias on a handful of points shouldn't trip the
        // gate (the paper wants to catch corruption/fabrication, not
        // honest variance).
        let existing = base_dataset();
        let mut contrib = honest_contribution(5, 4);
        for r in &mut contrib.records {
            r.runtime_s *= 0.9;
        }
        let v = validate_contribution(&existing, &contrib, &ValidationPolicy::default())
            .unwrap();
        assert!(v.accepted, "{}", v.reason);
    }
}
