//! C3O Hub (paper §III): the collaborative side of the system.
//!
//! The hub hosts *repositories* — one per common dataflow job — each
//! bundling the job's metadata (the algorithm, the maintainer's designated
//! machine type) with the shared runtime data contributed by users, exactly
//! like the paper's code-plus-runtime-data repositories.
//!
//! * [`repo`] — repository state and on-disk layout (TSV, §VI-A); with a
//!   [`crate::storage::DurableStore`] attached, every accepted
//!   contribution is WAL-logged before it is published and snapshots
//!   capture compacted state (crash recovery, DESIGN.md §9).
//! * [`validate`] — the §III-C-b contribution gate: retrain with the new
//!   data and reject it if held-out prediction error degrades (plus
//!   schema and duplicate-replay defenses).
//! * [`transport`] — hand-rolled readiness polling (epoll on Linux,
//!   poll(2) elsewhere; the offline crate cache has no tokio or mio, see
//!   DESIGN.md §2 and §7) plus the reactor wake channel and transport
//!   counters.
//! * [`server`] / [`client`] — newline-delimited-JSON transport over TCP:
//!   one non-blocking reactor thread owns every socket (frame assembly,
//!   buffered writes, pipelining, idle reaping) and dispatches decoded
//!   frames to a bounded worker pool, so CPU-heavy fits never stall I/O.
//!   All frames are typed by [`crate::api::proto`] (wire protocol v1) and
//!   served by [`crate::api::service::PredictionService`]. The server
//!   also owns the durability thread (interval fsync, automatic
//!   snapshots) and flushes everything on graceful drain.
//!
//! Protocol v1 ops: `list_repos`, `get_repo`, `submit_runs`, `catalog`,
//! `stats`, `metrics`, `predict`, `predict_batch`, `configure`,
//! `configure_search`, `repl_subscribe`, `repl_fetch`, `repl_snapshot`,
//! `shutdown` — specified in DESIGN.md §4. The `repl_*` ops ship the WAL
//! to follower hubs ([`crate::replication`], DESIGN.md §11); `metrics`
//! snapshots the telemetry registry ([`crate::obs`], DESIGN.md §13).

pub mod client;
pub mod repo;
pub mod server;
pub mod transport;
pub mod validate;

pub use client::{HubClient, PipelinedClient};
pub use repo::{HubState, Repository};
pub use server::{HubServer, ServerConfig};
pub use validate::{
    validate_contribution, validate_contribution_cached, ValidationPolicy, Verdict,
};
