//! Hub client: the user-side half of the Fig. 4 workflow.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::Context;

use crate::data::{Dataset, JobKind};
use crate::util::json::Json;
use crate::util::tsv::Table;

/// Listing entry returned by `list_repos`.
#[derive(Debug, Clone)]
pub struct RepoInfo {
    pub job: JobKind,
    pub description: String,
    pub records: usize,
    pub maintainer_machine: Option<String>,
}

/// Fetched repository (Fig. 4 step 2: job + runtime data + metadata).
#[derive(Debug, Clone)]
pub struct FetchedRepo {
    pub job: JobKind,
    pub description: String,
    pub maintainer_machine: Option<String>,
    pub data: Dataset,
}

/// Blocking hub client over one TCP connection.
pub struct HubClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl HubClient {
    pub fn connect(addr: &str) -> crate::Result<HubClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to hub at {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(HubClient { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    fn call(&mut self, req: Json) -> crate::Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            anyhow::bail!("hub closed the connection");
        }
        let reply = Json::parse(line.trim())?;
        if reply.get("ok").and_then(|j| j.as_bool()) != Some(true) {
            let msg = reply
                .get("error")
                .and_then(|j| j.as_str())
                .unwrap_or("unknown hub error");
            anyhow::bail!("hub error: {msg}");
        }
        Ok(reply)
    }

    /// Fig. 4 step 1: browse available jobs.
    pub fn list_repos(&mut self) -> crate::Result<Vec<RepoInfo>> {
        let reply = self.call(Json::obj(vec![("op", Json::Str("list_repos".into()))]))?;
        let mut out = Vec::new();
        for item in reply.get("repos").and_then(|j| j.as_arr()).unwrap_or(&[]) {
            out.push(RepoInfo {
                job: item
                    .get("job")
                    .and_then(|j| j.as_str())
                    .context("repo missing job")?
                    .parse()?,
                description: item
                    .get("description")
                    .and_then(|j| j.as_str())
                    .unwrap_or("")
                    .to_string(),
                records: item
                    .get("records")
                    .and_then(|j| j.as_u64())
                    .unwrap_or(0) as usize,
                maintainer_machine: item
                    .get("maintainer_machine")
                    .and_then(|j| j.as_str())
                    .map(|s| s.to_string()),
            });
        }
        Ok(out)
    }

    /// Fig. 4 step 2: download job + associated runtime data.
    pub fn get_repo(&mut self, job: JobKind) -> crate::Result<FetchedRepo> {
        let reply = self.call(Json::obj(vec![
            ("op", Json::Str("get_repo".into())),
            ("job", Json::Str(job.to_string())),
        ]))?;
        let tsv = reply
            .get("data_tsv")
            .and_then(|j| j.as_str())
            .context("reply missing data_tsv")?;
        let data = Dataset::from_table(job, &Table::parse(tsv)?)?;
        Ok(FetchedRepo {
            job,
            description: reply
                .get("description")
                .and_then(|j| j.as_str())
                .unwrap_or("")
                .to_string(),
            maintainer_machine: reply
                .get("maintainer_machine")
                .and_then(|j| j.as_str())
                .map(|s| s.to_string()),
            data,
        })
    }

    /// Fig. 4 step 6: contribute newly generated runtime data.
    /// Returns (accepted, reason).
    pub fn submit_runs(&mut self, data: &Dataset) -> crate::Result<(bool, String)> {
        let reply = self.call(Json::obj(vec![
            ("op", Json::Str("submit_runs".into())),
            ("job", Json::Str(data.job.to_string())),
            ("data_tsv", Json::Str(data.to_table()?.to_text()?)),
        ]))?;
        Ok((
            reply.get("accepted").and_then(|j| j.as_bool()).unwrap_or(false),
            reply
                .get("reason")
                .and_then(|j| j.as_str())
                .unwrap_or("")
                .to_string(),
        ))
    }

    /// Hub stats: (accepted, rejected, repos).
    pub fn stats(&mut self) -> crate::Result<(u64, u64, u64)> {
        let reply = self.call(Json::obj(vec![("op", Json::Str("stats".into()))]))?;
        Ok((
            reply.get("accepted").and_then(|j| j.as_u64()).unwrap_or(0),
            reply.get("rejected").and_then(|j| j.as_u64()).unwrap_or(0),
            reply.get("repos").and_then(|j| j.as_u64()).unwrap_or(0),
        ))
    }

    /// Ask the server to stop accepting connections.
    pub fn shutdown(&mut self) -> crate::Result<()> {
        self.call(Json::obj(vec![("op", Json::Str("shutdown".into()))]))?;
        Ok(())
    }
}
