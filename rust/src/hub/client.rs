//! Hub client: the user-side half of the Fig. 4 workflow, plus the v1
//! server-side ops (`predict`, `predict_batch`, `configure`).
//!
//! Every call goes through the typed [`crate::api::proto`] layer: the
//! client assigns a fresh correlation id per request, and rejects replies
//! whose `id` or protocol version do not match.
//!
//! [`HubClient`] is strictly request-per-roundtrip. [`PipelinedClient`]
//! keeps many requests in flight on one connection and matches replies by
//! correlation id, tolerating out-of-order completion — the server
//! answers cheap warm-cache frames ahead of expensive cold fits.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::TcpStream;

use crate::api::proto::{
    self, BatchPrediction, CatalogPayload, HubStats, MetricsPayload, Op, Prediction,
    ReplHandshake, ReplPage, ReplSnapshotPayload, Request, Response, SubmitOutcome,
};
use crate::configurator::{CatalogSearch, ConfigChoice, UserGoals};
use crate::data::{Dataset, JobKind};
use crate::util::json::Json;
use crate::util::prng::Pcg;
use crate::util::tsv::Table;

/// Listing entry returned by `list_repos` (the wire payload type).
pub type RepoInfo = proto::RepoSummary;

/// Fetched repository (Fig. 4 step 2: job + runtime data + metadata).
#[derive(Debug, Clone)]
pub struct FetchedRepo {
    pub job: JobKind,
    pub description: String,
    pub maintainer_machine: Option<String>,
    /// Dataset revision at fetch time.
    pub revision: u64,
    pub data: Dataset,
}

/// Blocking hub client over one TCP connection.
pub struct HubClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

/// Initial-connect retry budget: a hub that is still binding its listener
/// (CLI `--hub` races, follower tailing a just-started leader) refuses the
/// first attempt; a short bounded retry absorbs that without masking a
/// genuinely absent hub.
const CONNECT_ATTEMPTS: u32 = 3;

impl HubClient {
    /// Connect to a hub, retrying transient connect failures up to
    /// [`CONNECT_ATTEMPTS`] times with jittered exponential backoff
    /// (~50/100 ms between attempts). Only the initial TCP connect is
    /// retried — an established session that later fails surfaces its
    /// error immediately, so callers never see silently replayed ops.
    pub fn connect(addr: &str) -> crate::Result<HubClient> {
        // Deterministic jitter (the crate never draws wall-clock entropy,
        // DESIGN.md §2): seed from the target address, stream by process,
        // so concurrent clients of one hub still spread their retries.
        let seed = addr.bytes().fold(0xC30u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
        let mut rng = Pcg::new(seed, std::process::id() as u64);
        let mut last = None;
        for attempt in 0..CONNECT_ATTEMPTS {
            if attempt > 0 {
                let base = 50u64 << (attempt - 1);
                let jitter = rng.below((base / 2 + 1) as usize) as u64;
                std::thread::sleep(std::time::Duration::from_millis(base + jitter));
            }
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    return Ok(HubClient {
                        reader: BufReader::new(stream.try_clone()?),
                        writer: stream,
                        next_id: 1,
                    });
                }
                Err(e) => last = Some(e),
            }
        }
        Err(anyhow::Error::new(last.expect("at least one connect attempt ran"))
            .context(format!("connecting to hub at {addr} ({CONNECT_ATTEMPTS} attempts)")))
    }

    /// Send one op, await its reply, verify the envelope (version, id,
    /// ok flag) and return the payload.
    ///
    /// Every way a hub teardown can surface mid-call — clean EOF, broken
    /// pipe on write, or a reset when the hub closed just before our
    /// frame arrived — reports the same "hub closed the connection"
    /// error, so callers need not care which side of the race they hit.
    fn call(&mut self, op: Op) -> crate::Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::new(id, op);
        let reader = &mut self.reader;
        let writer = &mut self.writer;
        let mut io = move || -> std::io::Result<String> {
            writer.write_all(req.to_line().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                return Err(ErrorKind::UnexpectedEof.into());
            }
            Ok(line)
        };
        let line = match io() {
            Ok(line) => line,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::UnexpectedEof
                        | ErrorKind::BrokenPipe
                        | ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                ) =>
            {
                anyhow::bail!("hub closed the connection")
            }
            Err(e) => return Err(e.into()),
        };
        Response::parse(&line)?.payload(id)
    }

    /// Fig. 4 step 1: browse available jobs.
    pub fn list_repos(&mut self) -> crate::Result<Vec<RepoInfo>> {
        let payload = self.call(Op::ListRepos)?;
        Ok(proto::RepoList::from_json(&payload)?.repos)
    }

    /// Fig. 4 step 2: download job + associated runtime data.
    pub fn get_repo(&mut self, job: JobKind) -> crate::Result<FetchedRepo> {
        let payload = self.call(Op::GetRepo { job })?;
        let repo = proto::RepoPayload::from_json(&payload)?;
        let data = Dataset::from_table(job, &Table::parse(&repo.data_tsv)?)?;
        Ok(FetchedRepo {
            job,
            description: repo.description,
            maintainer_machine: repo.maintainer_machine,
            revision: repo.revision,
            data,
        })
    }

    /// Fig. 4 step 6: contribute newly generated runtime data.
    pub fn submit_runs(&mut self, data: &Dataset) -> crate::Result<SubmitOutcome> {
        let payload = self.call(Op::SubmitRuns {
            job: data.job,
            data_tsv: data.to_table()?.to_text()?,
        })?;
        SubmitOutcome::from_json(&payload)
    }

    /// The hub's machine-type catalog.
    pub fn catalog(&mut self) -> crate::Result<CatalogPayload> {
        let payload = self.call(Op::Catalog)?;
        CatalogPayload::from_json(&payload)
    }

    /// Hub + prediction-service counters.
    pub fn stats(&mut self) -> crate::Result<HubStats> {
        let payload = self.call(Op::Stats)?;
        HubStats::from_json(&payload)
    }

    /// Full telemetry snapshot (DESIGN.md §13): per-stage latency
    /// histograms, counters and gauges, renderable as Prometheus text
    /// via [`MetricsPayload::render_prometheus`].
    pub fn metrics(&mut self) -> crate::Result<MetricsPayload> {
        let payload = self.call(Op::Metrics)?;
        MetricsPayload::from_json(&payload)
    }

    /// Server-side prediction for one feature row
    /// `[scale_out, data_size_gb, context...]`.
    pub fn predict(
        &mut self,
        job: JobKind,
        machine_type: Option<&str>,
        features: &[f64],
    ) -> crate::Result<Prediction> {
        let payload = self.call(Op::Predict {
            job,
            machine_type: machine_type.map(|s| s.to_string()),
            features: features.to_vec(),
        })?;
        Prediction::from_json(&payload)
    }

    /// Server-side batch prediction: many rows, one fitted model.
    pub fn predict_batch(
        &mut self,
        job: JobKind,
        machine_type: Option<&str>,
        rows: &[Vec<f64>],
    ) -> crate::Result<BatchPrediction> {
        let payload = self.call(Op::PredictBatch {
            job,
            machine_type: machine_type.map(|s| s.to_string()),
            rows: rows.to_vec(),
        })?;
        BatchPrediction::from_json(&payload)
    }

    /// Full §IV configuration on the hub: machine type + scale-out under
    /// the user's deadline/confidence goals. Returns the same
    /// [`ConfigChoice`] local mode produces.
    pub fn configure(
        &mut self,
        job: JobKind,
        data_size_gb: f64,
        context: Vec<f64>,
        goals: &UserGoals,
        machine_type: Option<&str>,
    ) -> crate::Result<ConfigChoice> {
        let payload = self.call(Op::Configure {
            job,
            data_size_gb,
            context,
            deadline_s: goals.deadline_s,
            confidence: goals.confidence,
            machine_type: machine_type.map(|s| s.to_string()),
        })?;
        proto::config_choice_from_json(&payload)
    }

    /// Catalog-wide configuration search on the hub: every machine type's
    /// scale-out grid, answered from the hub's fitted-model cache, with
    /// the cost-optimal admissible configuration, the ranked runtime/cost
    /// frontier, and per-type outcomes (`insufficient_data` types are
    /// reported, not silently skipped).
    pub fn configure_search(
        &mut self,
        job: JobKind,
        data_size_gb: f64,
        context: Vec<f64>,
        goals: &UserGoals,
    ) -> crate::Result<CatalogSearch> {
        let payload = self.call(Op::ConfigureSearch {
            job,
            data_size_gb,
            context,
            deadline_s: goals.deadline_s,
            confidence: goals.confidence,
        })?;
        proto::catalog_search_from_json(&payload)
    }

    /// Replication lag probe (DESIGN.md §11): the leader's current
    /// revision for `job` and whether records right above `from_revision`
    /// are still WAL-reachable (`compacted: false`).
    pub fn repl_subscribe(
        &mut self,
        job: JobKind,
        from_revision: u64,
    ) -> crate::Result<ReplHandshake> {
        let payload = self.call(Op::ReplSubscribe { job, from_revision })?;
        ReplHandshake::from_json(&payload)
    }

    /// One page of the leader's WAL for `job`: up to `max` records with
    /// revisions strictly above `from_revision`, oldest first.
    pub fn repl_fetch(
        &mut self,
        job: JobKind,
        from_revision: u64,
        max: u64,
    ) -> crate::Result<ReplPage> {
        let payload = self.call(Op::ReplFetch { job, from_revision, max })?;
        ReplPage::from_json(&payload)
    }

    /// The leader's current corpus image per repository, for follower
    /// cold bootstrap (or recovery from behind the compaction horizon).
    pub fn repl_snapshot(&mut self) -> crate::Result<ReplSnapshotPayload> {
        let payload = self.call(Op::ReplSnapshot)?;
        ReplSnapshotPayload::from_json(&payload)
    }

    /// Ask the server to stop accepting connections.
    pub fn shutdown(&mut self) -> crate::Result<()> {
        self.call(Op::Shutdown)?;
        Ok(())
    }

    /// Switch this connection into pipelined mode: many requests in
    /// flight, replies matched by correlation id.
    pub fn pipelined(self) -> PipelinedClient {
        PipelinedClient {
            reader: self.reader,
            writer: self.writer,
            next_id: self.next_id,
            stash: HashMap::new(),
            outstanding: HashSet::new(),
        }
    }
}

/// Pipelined hub client: [`PipelinedClient::send`] fires a request
/// without waiting, [`PipelinedClient::wait`] blocks for one specific
/// reply — stashing any other replies that arrive first, so out-of-order
/// completion on the server (warm hits overtaking a cold fit) is
/// transparent to callers.
pub struct PipelinedClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    /// Replies that arrived while waiting for a different id.
    stash: HashMap<u64, Response>,
    /// Ids sent but not yet returned by `wait`.
    outstanding: HashSet<u64>,
}

impl PipelinedClient {
    /// Connect in pipelined mode (same retry policy as
    /// [`HubClient::connect`]).
    pub fn connect(addr: &str) -> crate::Result<PipelinedClient> {
        Ok(HubClient::connect(addr)?.pipelined())
    }

    /// Requests sent but not yet waited for.
    pub fn in_flight(&self) -> usize {
        self.outstanding.len()
    }

    /// Whether `id`'s reply has already been *received* (while waiting
    /// for another id). Purely local — never touches the socket — so a
    /// `false` after other replies were waited out proves the server
    /// really answered those first.
    pub fn has_reply(&self, id: u64) -> bool {
        self.stash.contains_key(&id)
    }

    /// Fire one op without waiting for its reply; returns the
    /// correlation id to later [`PipelinedClient::wait`] on.
    pub fn send(&mut self, op: Op) -> crate::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::new(id, op);
        let io = (|| -> std::io::Result<()> {
            self.writer.write_all(req.to_line().as_bytes())?;
            self.writer.write_all(b"\n")?;
            self.writer.flush()
        })();
        match io {
            Ok(()) => {
                self.outstanding.insert(id);
                Ok(id)
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::BrokenPipe
                        | ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                ) =>
            {
                anyhow::bail!("hub closed the connection")
            }
            Err(e) => Err(e.into()),
        }
    }

    /// Block until `id`'s reply arrives (or is already stashed), verify
    /// the envelope, and return its payload. Replies for *other*
    /// outstanding ids that arrive meanwhile are stashed for their own
    /// `wait`.
    pub fn wait(&mut self, id: u64) -> crate::Result<Json> {
        anyhow::ensure!(
            self.outstanding.contains(&id) || self.stash.contains_key(&id),
            "correlation id {id} is not in flight (never sent, or already waited)"
        );
        loop {
            if let Some(resp) = self.stash.remove(&id) {
                self.outstanding.remove(&id);
                return resp.payload(id);
            }
            let resp = self.read_reply()?;
            if resp.id == 0 {
                // Connection-scoped error channel (flood refusal,
                // oversized frame): surface it — the connection is dead.
                if let Err(e) = &resp.result {
                    anyhow::bail!("hub error {e}");
                }
                continue;
            }
            if self.outstanding.contains(&resp.id) {
                self.stash.insert(resp.id, resp);
            }
            // Replies for unknown ids are dropped: correlation already
            // failed once for them (or the caller abandoned the id).
        }
    }

    fn read_reply(&mut self) -> crate::Result<Response> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => anyhow::bail!("hub closed the connection"),
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::UnexpectedEof
                        | ErrorKind::BrokenPipe
                        | ErrorKind::ConnectionReset
                        | ErrorKind::ConnectionAborted
                ) =>
            {
                anyhow::bail!("hub closed the connection")
            }
            Err(e) => return Err(e.into()),
        }
        Response::parse(&line)
    }

    /// Typed `predict` send: one feature row, reply via
    /// [`PipelinedClient::wait_predict`].
    pub fn send_predict(
        &mut self,
        job: JobKind,
        machine_type: Option<&str>,
        features: &[f64],
    ) -> crate::Result<u64> {
        self.send(Op::Predict {
            job,
            machine_type: machine_type.map(|s| s.to_string()),
            features: features.to_vec(),
        })
    }

    pub fn wait_predict(&mut self, id: u64) -> crate::Result<Prediction> {
        let payload = self.wait(id)?;
        Prediction::from_json(&payload)
    }

    /// Typed `stats` send, for transport-counter probes that ride an
    /// existing pipeline.
    pub fn send_stats(&mut self) -> crate::Result<u64> {
        self.send(Op::Stats)
    }

    pub fn wait_stats(&mut self, id: u64) -> crate::Result<HubStats> {
        let payload = self.wait(id)?;
        HubStats::from_json(&payload)
    }

    /// Typed `metrics` send, so telemetry snapshots can ride an existing
    /// pipeline (the bench uses this after its herd phase).
    pub fn send_metrics(&mut self) -> crate::Result<u64> {
        self.send(Op::Metrics)
    }

    pub fn wait_metrics(&mut self, id: u64) -> crate::Result<MetricsPayload> {
        let payload = self.wait(id)?;
        MetricsPayload::from_json(&payload)
    }
}
