//! Hub server: newline-delimited JSON over TCP, served by a **bounded
//! worker pool** (DESIGN.md §7).
//!
//! The accept thread only enqueues connections; `workers` threads each
//! own one connection at a time and serve its requests to completion.
//! At most `max_conns` accepted connections may wait for a free worker —
//! beyond that the hub answers a structured `unavailable` error frame and
//! closes, so a connection flood cannot exhaust the process with one OS
//! thread per socket.
//!
//! This layer only frames lines. Every request is parsed, dispatched and
//! answered by [`PredictionService::handle_line`] through the typed
//! [`crate::api::proto`] v1 protocol — no ad-hoc JSON is built here.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::api::proto::{ErrorCode, Response, WireError};
use crate::api::service::PredictionService;
use crate::cv::parallel::{FitEngine, SelectionBudget};
use crate::storage::{DurableStore, FsyncPolicy};

use super::repo::HubState;

/// How often a parked worker re-checks the stop flag — bounds both
/// shutdown-drain latency and the stop-observation delay of an idle
/// connection.
const POLL_INTERVAL: Duration = Duration::from_millis(100);

/// Per-syscall response-write timeout. A peer that stops reading (full
/// receive window, no progress) errors the write and frees the worker;
/// since shutdown joins workers, an unbounded write would otherwise let
/// one never-reading client wedge `HubServer::shutdown`/`Drop` forever.
/// Slow-but-reading peers are unaffected: the timeout applies per write
/// call, and partial progress restarts it.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Transport tuning for [`HubServer`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Worker threads. Each worker serves one connection at a time, so
    /// this bounds the number of concurrently served clients.
    pub workers: usize,
    /// Accepted connections allowed to queue for a free worker. Beyond
    /// this the hub refuses with an `unavailable` error frame.
    pub max_conns: usize,
    /// How long a connection may sit idle (no request in flight) while
    /// other connections are queued for a worker, before it is closed to
    /// free its worker. Only enforced under queue pressure — with free
    /// capacity, idle connections live forever — so `workers` silent
    /// sockets cannot starve the pool.
    pub idle_timeout: Duration,
    /// CV worker threads for one cold fit's candidate × split fan-out
    /// (`c3o serve --fit-threads N`; 0 ⇒ available parallelism). Several
    /// concurrent cold fits may oversubscribe briefly — acceptable, since
    /// cold fits are rare by construction (single-flight + cache).
    pub fit_threads: usize,
    /// Selection budget applied to every cold fit (`--fit-budget SECS`,
    /// `--fit-points N`). Unlimited by default; `--fit-budget 30` matches
    /// the paper's §VI-C 10–30 s selection envelope.
    pub fit_budget: SelectionBudget,
    /// Cadence of the durability thread (only spawned when the service's
    /// `HubState` has a [`DurableStore`] attached): WAL fsync under
    /// `FsyncPolicy::Interval`, and snapshot-threshold checks.
    pub flush_interval: Duration,
}

impl ServerConfig {
    /// The fit-path execution engine this config describes.
    /// [`HubServer::start_with`] installs it on the service, so the
    /// server config is authoritative for cold-fit execution.
    pub fn fit_engine(&self) -> FitEngine {
        FitEngine { threads: self.fit_threads, budget: self.fit_budget }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        // At least 4 workers even on small hosts, so a handful of
        // interactive clients never queue behind each other.
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(4, 64);
        ServerConfig {
            workers,
            max_conns: 128,
            idle_timeout: Duration::from_secs(10),
            fit_threads: 0,
            fit_budget: SelectionBudget::default(),
            flush_interval: Duration::from_millis(200),
        }
    }
}

/// Accepted-but-unserved connections, handed from the accept thread to
/// the workers.
struct ConnQueue {
    pending: Mutex<VecDeque<TcpStream>>,
    ready: Condvar,
}

/// A running hub server.
pub struct HubServer {
    pub addr: SocketAddr,
    service: Arc<PredictionService>,
    stop: Arc<AtomicBool>,
    queue: Arc<ConnQueue>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    durability_thread: Option<JoinHandle<()>>,
    /// Follower mode (DESIGN.md §11): the replication tailer keeping this
    /// hub converged with its leader. Stopped (and joined) first during
    /// shutdown, so no apply races the final drain flush.
    tailer: Option<crate::replication::Tailer>,
    /// Set once `stop_and_join` completed, so an explicit `shutdown`
    /// followed by `Drop` does not drain (or snapshot) twice.
    drained: bool,
}

impl HubServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral test port) and serve
    /// the v1 protocol from `service` with default transport tuning — and
    /// the default fit engine: like [`HubServer::start_with`], this
    /// installs the config's (here: default) `fit_engine()` on the
    /// service, replacing anything set via `with_engine`/`set_engine`.
    /// To serve a non-default engine, pass a `ServerConfig` carrying it.
    pub fn start(addr: &str, service: Arc<PredictionService>) -> crate::Result<HubServer> {
        HubServer::start_with(addr, service, ServerConfig::default())
    }

    /// [`HubServer::start`] with explicit worker-pool tuning.
    pub fn start_with(
        addr: &str,
        service: Arc<PredictionService>,
        config: ServerConfig,
    ) -> crate::Result<HubServer> {
        anyhow::ensure!(config.workers >= 1, "server needs at least one worker");
        // The server config is authoritative for cold-fit execution:
        // install its engine so `fit_threads`/`fit_budget` take effect
        // however the service was constructed.
        service.set_engine(config.fit_engine());
        let listener = TcpListener::bind(addr).context("binding hub listener")?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(ConnQueue {
            pending: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
        });

        let mut workers = Vec::with_capacity(config.workers);
        for _ in 0..config.workers {
            let svc = service.clone();
            let stp = stop.clone();
            let q = queue.clone();
            let idle_timeout = config.idle_timeout;
            workers.push(std::thread::spawn(move || {
                worker_loop(&q, &svc, &stp, idle_timeout)
            }));
        }

        // Durability thread: periodic WAL fsync (Interval policy) and
        // automatic snapshots once the append threshold is reached. The
        // *final* flush is not here — `stop_and_join` runs it after the
        // workers drained, so it covers every committed submission.
        let durability_thread = service.state().storage().map(|store| {
            let state = service.state().clone();
            let stp = stop.clone();
            let interval = config.flush_interval;
            std::thread::spawn(move || durability_loop(&state, &store, &stp, interval))
        });

        let t_stop = stop.clone();
        let t_queue = queue.clone();
        let max_conns = config.max_conns.max(1);
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => enqueue(&t_queue, s, max_conns),
                    // Accept errors are transient (ECONNABORTED from a
                    // peer that reset while queued, EMFILE under fd
                    // pressure — exactly the flood this pool defends
                    // against). Back off briefly and keep accepting
                    // instead of going permanently deaf.
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            }
            // Wake parked workers so they observe the stop flag promptly.
            t_queue.ready.notify_all();
        });

        Ok(HubServer {
            addr: local,
            service,
            stop,
            queue,
            accept_thread: Some(accept_thread),
            workers,
            durability_thread,
            tailer: None,
            drained: false,
        })
    }

    /// Attach the replication tailer that keeps this (follower) hub
    /// converged with its leader; the server owns it from here and stops
    /// it first during shutdown.
    pub fn attach_tailer(&mut self, tailer: crate::replication::Tailer) {
        self.tailer = Some(tailer);
    }

    pub fn service(&self) -> &Arc<PredictionService> {
        &self.service
    }

    pub fn state(&self) -> &Arc<HubState> {
        self.service.state()
    }

    /// Graceful drain: stop accepting, join the accept loop, then join
    /// every worker. In-flight connections see the flag at their next
    /// request boundary (or within [`POLL_INTERVAL`] when idle) and
    /// close; queued-but-unserved connections are dropped (peer sees
    /// EOF). With a durable store attached, the drain ends with a WAL
    /// fsync plus a final compacted snapshot, so a clean shutdown leaves
    /// nothing to replay.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.drained {
            return;
        }
        // Stop tailing before draining: the final flush below must cover
        // the last applied record, with no apply landing after it.
        drop(self.tailer.take());
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so `incoming()` returns.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        self.queue.ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.durability_thread.take() {
            let _ = h.join();
        }
        // Graceful-drain flush, after every worker quiesced: all committed
        // submissions are fsynced, and — only if any append is not yet
        // snapshot-covered — captured in one final compacted snapshot. A
        // read-only session must not pay a full-corpus rewrite at every
        // shutdown.
        if let Some(store) = self.service.state().storage() {
            if let Err(e) = store.sync() {
                eprintln!("[hub] shutdown WAL flush failed: {e:#}");
            }
            if store.stats().pending > 0 {
                if let Err(e) = self.service.state().snapshot_to(&store) {
                    eprintln!("[hub] shutdown snapshot failed: {e:#}");
                }
            }
        }
        self.drained = true;
    }
}

impl Drop for HubServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Hand a fresh connection to the pool, or refuse it when `max_conns`
/// connections are already waiting.
fn enqueue(queue: &ConnQueue, stream: TcpStream, max_conns: usize) {
    let mut pending = queue.pending.lock().unwrap();
    if pending.len() >= max_conns {
        drop(pending);
        refuse(stream);
        return;
    }
    pending.push_back(stream);
    drop(pending);
    queue.ready.notify_one();
}

/// Best-effort structured refusal: flood control answers with a normal v1
/// error frame, so well-behaved clients see `unavailable` instead of a
/// silent hangup. Bounded write timeout — a peer that never reads cannot
/// stall the accept thread.
fn refuse(stream: TcpStream) {
    let mut stream = stream;
    let _ = stream.set_write_timeout(Some(POLL_INTERVAL));
    let reply = Response::err(
        0,
        WireError::new(ErrorCode::Unavailable, "hub at connection capacity, retry later"),
    );
    let _ = stream.write_all(reply.to_line().as_bytes());
    let _ = stream.write_all(b"\n");
}

/// Background durability pass (DESIGN.md §9): under
/// [`FsyncPolicy::Interval`] fsync dirty WALs every `interval`, and write
/// a compacted snapshot whenever the store's append threshold is reached.
/// Errors are reported and retried next tick — durability degrades to the
/// last good flush instead of killing the serving path.
fn durability_loop(
    state: &HubState,
    store: &DurableStore,
    stop: &AtomicBool,
    interval: Duration,
) {
    let mut last_flush = Instant::now();
    while !stop.load(Ordering::SeqCst) {
        // Short sleeps so shutdown is observed within POLL_INTERVAL even
        // under long flush intervals.
        std::thread::sleep(POLL_INTERVAL.min(interval));
        if store.config().fsync == FsyncPolicy::Interval && last_flush.elapsed() >= interval {
            last_flush = Instant::now();
            if let Err(e) = store.sync() {
                eprintln!("[hub] WAL fsync failed: {e:#}");
            }
        }
        if store.should_snapshot() {
            if let Err(e) = state.snapshot_to(store) {
                eprintln!("[hub] automatic snapshot failed: {e:#}");
            }
        }
    }
}

/// Worker: pop one connection at a time and serve it to completion. Exits
/// as soon as the stop flag is set; connections still queued are dropped.
fn worker_loop(
    queue: &ConnQueue,
    service: &PredictionService,
    stop: &AtomicBool,
    idle_timeout: Duration,
) {
    loop {
        let conn = {
            let mut pending = queue.pending.lock().unwrap();
            loop {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(s) = pending.pop_front() {
                    break s;
                }
                // Timed wait so a lost wakeup can never stall shutdown.
                let (guard, _) = queue
                    .ready
                    .wait_timeout(pending, POLL_INTERVAL)
                    .unwrap();
                pending = guard;
            }
        };
        let _ = serve_conn(conn, service, stop, queue, idle_timeout);
    }
}

fn serve_conn(
    stream: TcpStream,
    service: &PredictionService,
    stop: &AtomicBool,
    queue: &ConnQueue,
    idle_timeout: Duration,
) -> crate::Result<()> {
    stream.set_nodelay(true).ok();
    // Bounded read timeout: a worker parked on an idle connection must
    // re-check the stop flag instead of blocking shutdown forever.
    stream.set_read_timeout(Some(POLL_INTERVAL)).ok();
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    let mut last_activity = Instant::now();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // peer closed
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                // Partial data read before the timeout stays buffered in
                // `line`; the next read_line appends the rest.
                if stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
                // Under queue pressure, yield this worker: an idle peer
                // (no request even started) must not starve connections
                // waiting for a worker. With free capacity, idle
                // connections live on.
                if line.is_empty()
                    && last_activity.elapsed() >= idle_timeout
                    && !queue.pending.lock().unwrap().is_empty()
                {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        last_activity = Instant::now();
        // Check per request, not just at accept time: once `shutdown` is
        // requested, in-flight connections must quiesce instead of serving
        // forever (closing drops the request; the peer sees EOF).
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let reply = service.handle_line(&line, stop);
        writer.write_all(reply.to_line().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        // The request we just served may itself have been `shutdown`.
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        line.clear();
    }
}
