//! Hub server: newline-delimited JSON over TCP, thread per connection.
//!
//! This layer only frames lines. Every request is parsed, dispatched and
//! answered by [`PredictionService::handle_line`] through the typed
//! [`crate::api::proto`] v1 protocol — no ad-hoc JSON is built here.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Context;

use crate::api::service::PredictionService;

use super::repo::HubState;

/// A running hub server.
pub struct HubServer {
    pub addr: SocketAddr,
    service: Arc<PredictionService>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl HubServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral test port) and serve
    /// the v1 protocol from `service`.
    pub fn start(addr: &str, service: Arc<PredictionService>) -> crate::Result<HubServer> {
        let listener = TcpListener::bind(addr).context("binding hub listener")?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        let t_service = service.clone();
        let t_stop = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let svc = t_service.clone();
                        let stp = t_stop.clone();
                        std::thread::spawn(move || {
                            let _ = serve_conn(s, &svc, &stp);
                        });
                    }
                    Err(_) => break,
                }
            }
        });

        Ok(HubServer { addr: local, service, stop, accept_thread: Some(accept_thread) })
    }

    pub fn service(&self) -> &Arc<PredictionService> {
        &self.service
    }

    pub fn state(&self) -> &Arc<HubState> {
        self.service.state()
    }

    /// Stop accepting and join the accept loop. In-flight connections see
    /// the flag on their next request and close.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so `incoming()` returns.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HubServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(
    stream: TcpStream,
    service: &PredictionService,
    stop: &AtomicBool,
) -> crate::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // peer closed
        }
        // Check per request, not just at accept time: once `shutdown` is
        // requested, in-flight connections must quiesce instead of serving
        // forever (closing drops the request; the peer sees EOF).
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let reply = service.handle_line(&line, stop);
        writer.write_all(reply.to_line().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        // The request we just served may itself have been `shutdown`.
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}
