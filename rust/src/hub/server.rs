//! Hub server: newline-delimited JSON over TCP, thread per connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Context;

use crate::cloud::Catalog;
use crate::data::{Dataset, JobKind};
use crate::util::json::Json;

use super::repo::HubState;
use super::validate::ValidationPolicy;

/// A running hub server.
pub struct HubServer {
    pub addr: SocketAddr,
    state: Arc<HubState>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl HubServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral test port) and serve.
    pub fn start(
        addr: &str,
        state: Arc<HubState>,
        catalog: Catalog,
        policy: ValidationPolicy,
    ) -> crate::Result<HubServer> {
        let listener = TcpListener::bind(addr).context("binding hub listener")?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        let t_state = state.clone();
        let t_stop = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let st = t_state.clone();
                        let cat = catalog.clone();
                        let pol = policy.clone();
                        let stp = t_stop.clone();
                        std::thread::spawn(move || {
                            let _ = serve_conn(s, &st, &cat, &pol, &stp);
                        });
                    }
                    Err(_) => break,
                }
            }
        });

        Ok(HubServer { addr: local, state, stop, accept_thread: Some(accept_thread) })
    }

    pub fn state(&self) -> &Arc<HubState> {
        &self.state
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so `incoming()` returns.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HubServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(
    stream: TcpStream,
    state: &HubState,
    catalog: &Catalog,
    policy: &ValidationPolicy,
    stop: &AtomicBool,
) -> crate::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // peer closed
        }
        let reply = match handle_request(&line, state, catalog, policy, stop) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(format!("{e:#}"))),
            ]),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

fn handle_request(
    line: &str,
    state: &HubState,
    catalog: &Catalog,
    policy: &ValidationPolicy,
    stop: &AtomicBool,
) -> crate::Result<Json> {
    let req = Json::parse(line.trim())?;
    let op = req.get("op").and_then(|j| j.as_str()).context("missing op")?;
    match op {
        "list_repos" => {
            let repos: Vec<Json> = state
                .jobs()
                .into_iter()
                .filter_map(|job| state.get(job))
                .map(|r| {
                    Json::obj(vec![
                        ("job", Json::Str(r.job.to_string())),
                        ("description", Json::Str(r.description.clone())),
                        ("records", Json::Num(r.data.len() as f64)),
                        (
                            "maintainer_machine",
                            match &r.maintainer_machine {
                                Some(m) => Json::Str(m.clone()),
                                None => Json::Null,
                            },
                        ),
                    ])
                })
                .collect();
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("repos", Json::Arr(repos))]))
        }
        "get_repo" => {
            let job: JobKind = req
                .get("job")
                .and_then(|j| j.as_str())
                .context("missing job")?
                .parse()?;
            let repo = state.get(job).with_context(|| format!("no repository for {job}"))?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("job", Json::Str(repo.job.to_string())),
                ("description", Json::Str(repo.description.clone())),
                (
                    "maintainer_machine",
                    match &repo.maintainer_machine {
                        Some(m) => Json::Str(m.clone()),
                        None => Json::Null,
                    },
                ),
                ("data_tsv", Json::Str(repo.data.to_table()?.to_text()?)),
            ]))
        }
        "submit_runs" => {
            let job: JobKind = req
                .get("job")
                .and_then(|j| j.as_str())
                .context("missing job")?
                .parse()?;
            let tsv = req
                .get("data_tsv")
                .and_then(|j| j.as_str())
                .context("missing data_tsv")?;
            let table = crate::util::tsv::Table::parse(tsv)?;
            let contribution = Dataset::from_table(job, &table)?;
            // Atomic validate+merge — see HubState::submit for the race
            // this prevents.
            let verdict = state.submit(contribution, policy)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("accepted", Json::Bool(verdict.accepted)),
                ("reason", Json::Str(verdict.reason)),
            ]))
        }
        "catalog" => {
            let types: Vec<Json> = catalog
                .types()
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("name", Json::Str(t.name.clone())),
                        ("vcpus", Json::Num(t.vcpus as f64)),
                        ("memory_gb", Json::Num(t.memory_gb)),
                        ("price_per_hour", Json::Num(t.price_per_hour)),
                        ("family", Json::Str(t.family.to_string())),
                    ])
                })
                .collect();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("types", Json::Arr(types)),
                ("provisioning_delay_s", Json::Num(catalog.provisioning_delay_s)),
            ]))
        }
        "stats" => {
            let (acc, rej) = state.counters();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("accepted", Json::Num(acc as f64)),
                ("rejected", Json::Num(rej as f64)),
                ("repos", Json::Num(state.jobs().len() as f64)),
            ]))
        }
        "shutdown" => {
            stop.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => anyhow::bail!("unknown op: {other}"),
    }
}
