//! Hub server: newline-delimited JSON over TCP, served by a
//! **non-blocking reactor + bounded worker pool** (DESIGN.md §7).
//!
//! One reactor thread owns every socket: it accepts connections,
//! registers them non-blocking with the [`super::transport`] readiness
//! poller (epoll on Linux, poll(2) elsewhere), assembles frames from
//! partial reads with [`FrameDecoder`], and buffers replies through
//! bounded per-connection write queues. Decoded frames are dispatched to
//! `workers` CPU threads, so an expensive cold fit never stalls I/O —
//! warm-cache replies for other frames (even on the *same* connection,
//! when the client pipelines) overtake it. At most `max_conns`
//! connections may be open; beyond that the hub answers a structured
//! `unavailable` error frame and closes. Idle connections are reaped
//! after `idle_timeout` unconditionally.
//!
//! This layer only frames lines. Every request is parsed, dispatched and
//! answered by [`PredictionService::handle_line`] through the typed
//! [`crate::api::proto`] v1 protocol — no ad-hoc JSON is built here.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::api::proto::{ErrorCode, FrameDecoder, Response, WireError};
use crate::api::service::PredictionService;
use crate::cv::parallel::{FitEngine, SelectionBudget};
use crate::obs::{self, log, Span, Stage};
use crate::storage::{DurableStore, FsyncPolicy};

use super::repo::HubState;
use super::transport::{wake_channel, Event, Interest, Poller, TransportStats, WakeReceiver, Waker};

/// Upper bound on parked waits everywhere (reactor poll, worker condvar,
/// durability sleeps) — bounds shutdown-observation latency.
const POLL_INTERVAL: Duration = Duration::from_millis(100);

/// How long the shutdown drain keeps trying to deliver already-computed
/// replies to peers that have stopped reading. Slow-but-reading peers
/// drain long before this; a dead one cannot wedge `shutdown`/`Drop`.
const WRITE_GRACE: Duration = Duration::from_secs(5);

/// Per-connection write-queue cap: a peer that stops reading while
/// pipelined replies accumulate is disconnected once this much reply
/// data is buffered, instead of growing the queue without bound.
const MAX_WRITE_BUFFER: usize = 64 << 20;

/// Read-syscall chunk size for the reactor's shared read buffer.
const READ_CHUNK: usize = 64 * 1024;

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const TOKEN_BASE: u64 = 2;

/// Transport tuning for [`HubServer`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Worker threads executing decoded frames (fits, predictions,
    /// submits). I/O is not bounded by this — the reactor multiplexes
    /// every connection — so it sizes for CPU, not for concurrency.
    pub workers: usize,
    /// Open connections allowed at once. Beyond this the hub refuses
    /// with an `unavailable` error frame and closes. (Under the old
    /// blocking transport this bounded connections *queued for a
    /// worker*; the reactor has no such queue, so it now bounds open
    /// sockets directly.)
    pub max_conns: usize,
    /// A connection idle (no request in flight, nothing buffered) for
    /// this long is closed — unconditionally. The blocking transport
    /// reaped idle connections only while others queued for a worker;
    /// with a reactor a parked socket costs one fd and nothing else, but
    /// unconditional reaping keeps fd accounting predictable and frees
    /// abandoned peers promptly.
    pub idle_timeout: Duration,
    /// Deepest request pipeline served per connection: frames beyond
    /// this many in flight stay buffered (and eventually push back on
    /// the socket) until replies drain.
    pub max_pipeline: usize,
    /// Micro-batch window for concurrent `predict` frames of the same
    /// `(job, machine_type)`: the first arrival waits this long for
    /// company, then answers everyone through one batched prediction.
    /// Zero (default) disables coalescing.
    pub coalesce_window: Duration,
    /// CV worker threads for one cold fit's candidate × split fan-out
    /// (`c3o serve --fit-threads N`; 0 ⇒ available parallelism). Several
    /// concurrent cold fits may oversubscribe briefly — acceptable, since
    /// cold fits are rare by construction (single-flight + cache).
    pub fit_threads: usize,
    /// Selection budget applied to every cold fit (`--fit-budget SECS`,
    /// `--fit-points N`). Unlimited by default; `--fit-budget 30` matches
    /// the paper's §VI-C 10–30 s selection envelope.
    pub fit_budget: SelectionBudget,
    /// Cadence of the durability thread (only spawned when the service's
    /// `HubState` has a [`DurableStore`] attached): WAL fsync under
    /// `FsyncPolicy::Interval`, and snapshot-threshold checks.
    pub flush_interval: Duration,
    /// Slow-request threshold (`c3o serve --slow-ms N`): a request whose
    /// end-to-end time reaches this many milliseconds is promoted to a
    /// structured warn-level log line with its stage breakdown. Zero
    /// (default) disables the slow-request log; traces are still
    /// retained in the in-memory ring either way (DESIGN.md §13).
    pub slow_ms: u64,
}

impl ServerConfig {
    /// The fit-path execution engine this config describes.
    /// [`HubServer::start_with`] installs it on the service, so the
    /// server config is authoritative for cold-fit execution.
    pub fn fit_engine(&self) -> FitEngine {
        FitEngine { threads: self.fit_threads, budget: self.fit_budget }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        // At least 4 workers even on small hosts, so a handful of
        // interactive clients never queue behind each other.
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(4, 64);
        ServerConfig {
            workers,
            max_conns: 128,
            idle_timeout: Duration::from_secs(10),
            max_pipeline: 32,
            coalesce_window: Duration::ZERO,
            fit_threads: 0,
            fit_budget: SelectionBudget::default(),
            flush_interval: Duration::from_millis(200),
            slow_ms: 0,
        }
    }
}

/// One decoded frame on its way to a worker.
struct Job {
    token: u64,
    gen: u64,
    line: String,
    /// [`obs::now_us`] when the reactor began extracting this frame.
    recv_us: u64,
    /// Frame extraction time in the reactor (µs).
    decode_us: u64,
    /// [`obs::now_us`] when the job entered the dispatch queue.
    enqueued_us: u64,
}

/// Reactor → workers: decoded frames awaiting execution. `in_flight`
/// counts dispatched jobs whose replies have not yet reached the outbox;
/// workers push the reply *before* decrementing, so once the reactor
/// reads zero, one final outbox drain observes every reply.
struct JobQueue {
    jobs: Mutex<VecDeque<Job>>,
    ready: Condvar,
    in_flight: AtomicU64,
}

/// Workers → reactor: completed reply frames, matched back to their
/// connection by `(token, gen)` — `gen` disambiguates a reused slot.
struct Reply {
    token: u64,
    gen: u64,
    bytes: Vec<u8>,
    /// Trace span under construction: stages through `service` are
    /// filled in by the worker; the reactor adds dispatch/reply/total
    /// when the reply bytes reach the socket.
    span: Span,
    /// [`obs::now_us`] when the worker pushed this reply — outbox
    /// residency (the `dispatch` stage) is measured from here.
    pushed_us: u64,
}

struct Outbox {
    replies: Mutex<Vec<Reply>>,
}

/// Decrements the dispatch counter on drop, so a panicking request
/// cannot leave the shutdown drain waiting forever.
struct InFlightGuard<'a>(&'a AtomicU64);

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running hub server.
pub struct HubServer {
    pub addr: SocketAddr,
    service: Arc<PredictionService>,
    stop: Arc<AtomicBool>,
    queue: Arc<JobQueue>,
    waker: Waker,
    transport: Arc<TransportStats>,
    reactor_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    durability_thread: Option<JoinHandle<()>>,
    /// Follower mode (DESIGN.md §11): the replication tailer keeping this
    /// hub converged with its leader. Stopped (and joined) first during
    /// shutdown, so no apply races the final drain flush.
    tailer: Option<crate::replication::Tailer>,
    /// Set once `stop_and_join` completed, so an explicit `shutdown`
    /// followed by `Drop` does not drain (or snapshot) twice.
    drained: bool,
}

impl HubServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral test port) and serve
    /// the v1 protocol from `service` with default transport tuning — and
    /// the default fit engine: like [`HubServer::start_with`], this
    /// installs the config's (here: default) `fit_engine()` on the
    /// service, replacing anything set via `with_engine`/`set_engine`.
    /// To serve a non-default engine, pass a `ServerConfig` carrying it.
    pub fn start(addr: &str, service: Arc<PredictionService>) -> crate::Result<HubServer> {
        HubServer::start_with(addr, service, ServerConfig::default())
    }

    /// [`HubServer::start`] with explicit transport and worker tuning.
    pub fn start_with(
        addr: &str,
        service: Arc<PredictionService>,
        config: ServerConfig,
    ) -> crate::Result<HubServer> {
        anyhow::ensure!(config.workers >= 1, "server needs at least one worker");
        // The server config is authoritative for cold-fit execution and
        // coalescing: install both so they take effect however the
        // service was constructed.
        service.set_engine(config.fit_engine());
        service.set_coalesce_window(config.coalesce_window);
        let transport = Arc::new(TransportStats::default());
        service.set_transport_stats(transport.clone());

        let listener = TcpListener::bind(addr).context("binding hub listener")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true).context("marking hub listener non-blocking")?;
        let mut poller = Poller::new().context("creating readiness poller")?;
        let (waker, wake_rx) = wake_channel().context("creating reactor waker")?;
        poller
            .register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)
            .context("registering hub listener")?;
        poller
            .register(wake_rx.fd(), TOKEN_WAKER, Interest::READ)
            .context("registering reactor waker")?;

        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(JobQueue {
            jobs: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            in_flight: AtomicU64::new(0),
        });
        let outbox = Arc::new(Outbox { replies: Mutex::new(Vec::new()) });

        // Telemetry gauge: pool size of the most recently started hub
        // (the registry is process-wide; see `obs` module docs).
        obs::metrics().workers_total.store(config.workers as u64, Ordering::Relaxed);

        let mut workers = Vec::with_capacity(config.workers);
        for _ in 0..config.workers {
            let svc = service.clone();
            let stp = stop.clone();
            let q = queue.clone();
            let ob = outbox.clone();
            let wk = waker.clone();
            workers.push(std::thread::spawn(move || worker_loop(&q, &ob, &svc, &stp, &wk)));
        }

        // Durability thread: periodic WAL fsync (Interval policy) and
        // automatic snapshots once the append threshold is reached. The
        // *final* flush is not here — `stop_and_join` runs it after the
        // workers drained, so it covers every committed submission.
        let durability_thread = service.state().storage().map(|store| {
            let state = service.state().clone();
            let stp = stop.clone();
            let interval = config.flush_interval;
            std::thread::spawn(move || durability_loop(&state, &store, &stp, interval))
        });

        let reactor = Reactor {
            poller,
            listener,
            wake_rx,
            queue: queue.clone(),
            outbox,
            stop: stop.clone(),
            stats: transport.clone(),
            max_conns: config.max_conns.max(1),
            max_pipeline: config.max_pipeline.max(1),
            idle_timeout: config.idle_timeout,
            slow_ms: config.slow_ms,
            conns: Vec::new(),
            free: Vec::new(),
            open: 0,
            next_gen: 0,
            read_buf: vec![0u8; READ_CHUNK],
            events: Vec::new(),
            jobs_scratch: Vec::new(),
            replies_scratch: Vec::new(),
            touched_scratch: Vec::new(),
        };
        let reactor_thread = std::thread::spawn(move || reactor.run());

        Ok(HubServer {
            addr: local,
            service,
            stop,
            queue,
            waker,
            transport,
            reactor_thread: Some(reactor_thread),
            workers,
            durability_thread,
            tailer: None,
            drained: false,
        })
    }

    /// Attach the replication tailer that keeps this (follower) hub
    /// converged with its leader; the server owns it from here and stops
    /// it first during shutdown.
    pub fn attach_tailer(&mut self, tailer: crate::replication::Tailer) {
        self.tailer = Some(tailer);
    }

    pub fn service(&self) -> &Arc<PredictionService> {
        &self.service
    }

    pub fn state(&self) -> &Arc<HubState> {
        self.service.state()
    }

    /// Live transport counters (also exposed via the `stats` op).
    pub fn transport(&self) -> &Arc<TransportStats> {
        &self.transport
    }

    /// Graceful drain: stop accepting, let dispatched requests finish and
    /// their replies flush (undispatched frames are dropped; the peer
    /// sees EOF, exactly as queued-but-unserved connections did under the
    /// blocking transport), then join the reactor, workers and the
    /// durability thread. With a durable store attached, the drain ends
    /// with a WAL fsync plus a final compacted snapshot, so a clean
    /// shutdown leaves nothing to replay.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.drained {
            return;
        }
        // Stop tailing before draining: the final flush below must cover
        // the last applied record, with no apply landing after it.
        drop(self.tailer.take());
        self.stop.store(true, Ordering::SeqCst);
        // Interrupt the reactor's parked wait so it starts draining now.
        self.waker.wake();
        if let Some(h) = self.reactor_thread.take() {
            let _ = h.join();
        }
        self.queue.ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.durability_thread.take() {
            let _ = h.join();
        }
        // Graceful-drain flush, after every worker quiesced: all committed
        // submissions are fsynced, and — only if any append is not yet
        // snapshot-covered — captured in one final compacted snapshot. A
        // read-only session must not pay a full-corpus rewrite at every
        // shutdown.
        if let Some(store) = self.service.state().storage() {
            if let Err(e) = store.sync() {
                log::error(
                    "hub.server",
                    "shutdown WAL flush failed",
                    &[("error", format!("{e:#}"))],
                );
            }
            if store.stats().pending > 0 {
                if let Err(e) = self.service.state().snapshot_to(&store) {
                    log::error(
                        "hub.server",
                        "shutdown snapshot failed",
                        &[("error", format!("{e:#}"))],
                    );
                }
            }
        }
        self.drained = true;
    }
}

impl Drop for HubServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

// ---------------------------------------------------------------------------
// Reactor
// ---------------------------------------------------------------------------

/// Per-connection reactor state: the non-blocking socket, its incremental
/// frame decoder, the bounded outgoing reply buffer (`out[out_pos..]` is
/// unwritten), and pipeline accounting.
struct Conn {
    stream: TcpStream,
    gen: u64,
    decoder: FrameDecoder,
    out: Vec<u8>,
    out_pos: usize,
    in_flight: usize,
    last_activity: Instant,
    read_closed: bool,
    interest: Interest,
    /// Cumulative bytes ever flushed to the socket. Trace completion is
    /// keyed off this stream offset, so compacting `out` (which shifts
    /// buffer indices) never corrupts span accounting.
    written_total: u64,
    /// Replies buffered but not yet fully flushed, oldest first:
    /// `(absolute stream offset of the reply's last byte, write-buffer
    /// entry timestamp, span)`. A span completes once `written_total`
    /// reaches its end offset.
    pending_spans: VecDeque<(u64, u64, Span)>,
}

impl Conn {
    fn drained(&self) -> bool {
        self.in_flight == 0 && self.out_pos >= self.out.len()
    }
}

struct Reactor {
    poller: Poller,
    listener: TcpListener,
    wake_rx: WakeReceiver,
    queue: Arc<JobQueue>,
    outbox: Arc<Outbox>,
    stop: Arc<AtomicBool>,
    stats: Arc<TransportStats>,
    max_conns: usize,
    max_pipeline: usize,
    idle_timeout: Duration,
    /// Slow-request log threshold in milliseconds (0 = disabled).
    slow_ms: u64,
    /// Slab of connections; the poller token is `slot + TOKEN_BASE`.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    open: usize,
    next_gen: u64,
    read_buf: Vec<u8>,
    events: Vec<Event>,
    /// Tick-loop scratch buffers (L9 alloc_hot): taken at the top of
    /// their hot fn, drained, and put back so capacity is reused across
    /// ticks instead of reallocated per call.
    jobs_scratch: Vec<Job>,
    replies_scratch: Vec<Reply>,
    touched_scratch: Vec<usize>,
}

impl Reactor {
    fn run(mut self) {
        while !self.stop.load(Ordering::SeqCst) {
            self.tick();
        }
        self.drain();
    }

    /// One reactor iteration: wait for readiness, accept, read/decode/
    /// dispatch, deliver finished replies, flush, reap idle connections.
    fn tick(&mut self) {
        let mut events = std::mem::take(&mut self.events);
        events.clear();
        if let Err(e) = self.poller.wait(&mut events, Some(POLL_INTERVAL)) {
            log::error("hub.server", "readiness wait failed", &[("error", e.to_string())]);
            std::thread::sleep(Duration::from_millis(10));
        }
        for ev in &events {
            match ev.token {
                TOKEN_LISTENER => self.accept_ready(),
                TOKEN_WAKER => self.wake_rx.drain(),
                token => self.conn_event(token, *ev),
            }
        }
        self.events = events;
        self.drain_outbox();
        self.sweep();
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.open >= self.max_conns {
                        refuse(stream, &self.stats);
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let slot = match self.free.pop() {
                        Some(s) => s,
                        None => {
                            self.conns.push(None);
                            self.conns.len() - 1
                        }
                    };
                    let fd = stream.as_raw_fd();
                    if self.poller.register(fd, TOKEN_BASE + slot as u64, Interest::READ).is_err()
                    {
                        self.free.push(slot);
                        continue;
                    }
                    self.next_gen += 1;
                    // lint: allow(panics, reason = "slot was just popped from the free list or pushed onto conns above — in bounds by construction")
                    self.conns[slot] = Some(Conn {
                        stream,
                        gen: self.next_gen,
                        decoder: FrameDecoder::default(),
                        // lint: allow(alloc_hot, reason = "per-connection setup, not per-frame: Vec::new is capacity-free until the first reply buffers")
                        out: Vec::new(),
                        out_pos: 0,
                        in_flight: 0,
                        last_activity: Instant::now(),
                        read_closed: false,
                        interest: Interest::READ,
                        written_total: 0,
                        pending_spans: VecDeque::new(),
                    });
                    self.open += 1;
                    self.stats.open_connections.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                // Accept errors are transient (ECONNABORTED from a peer
                // that reset while queued, EMFILE under fd pressure).
                // Back off briefly instead of spinning on a level-
                // triggered listener that stays "ready".
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
    }

    fn conn_event(&mut self, token: u64, ev: Event) {
        let slot = (token - TOKEN_BASE) as usize;
        if self.conns.get(slot).map(|c| c.is_none()).unwrap_or(true) {
            return; // closed earlier this tick; stale event
        }
        if ev.readable || ev.hangup {
            self.handle_readable(slot);
        }
        if ev.hangup {
            // Peer is gone (or half-closed): no more frames will arrive.
            // Pending replies still flush; the sweep closes once drained.
            if let Some(c) = self.conns.get_mut(slot).and_then(Option::as_mut) {
                c.read_closed = true;
            }
        }
        if ev.writable {
            self.flush_and_update(slot);
        }
    }

    /// Read until the socket would block (or the pipeline cap pauses
    /// reads), feeding the frame decoder and dispatching complete frames.
    fn handle_readable(&mut self, slot: usize) {
        loop {
            self.pump_frames(slot);
            let conn = match self.conns.get_mut(slot).and_then(Option::as_mut) {
                Some(c) => c,
                None => return,
            };
            if conn.read_closed || conn.in_flight >= self.max_pipeline {
                break;
            }
            match conn.stream.read(&mut self.read_buf[..]) {
                Ok(0) => {
                    conn.read_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.last_activity = Instant::now();
                    // lint: allow(panics, reason = "read(2) returns at most the buffer length, so n <= read_buf.len() and the slice is in range")
                    if let Err(e) = conn.decoder.feed(&self.read_buf[..n]) {
                        // Absurd frame length: answer on the connection-
                        // scoped id-0 channel, stop reading, close once
                        // the error (and any pending replies) flushed.
                        let frame = Response::err(0, e).to_line();
                        conn.out.extend_from_slice(frame.as_bytes());
                        conn.out.push(b'\n');
                        conn.read_closed = true;
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(slot);
                    return;
                }
            }
        }
        self.pump_frames(slot);
        self.flush_and_update(slot);
    }

    /// Dispatch buffered complete frames to the worker pool, up to the
    /// per-connection pipeline cap. No-op once the stop flag is set:
    /// workers are exiting, and a frame dispatched now would hang the
    /// drain's in-flight accounting.
    fn pump_frames(&mut self, slot: usize) {
        if self.stop.load(Ordering::SeqCst) {
            return;
        }
        let mut new_jobs: Vec<Job> = std::mem::take(&mut self.jobs_scratch);
        {
            let conn = match self.conns.get_mut(slot).and_then(Option::as_mut) {
                Some(c) => c,
                None => {
                    self.jobs_scratch = new_jobs;
                    return;
                }
            };
            while conn.in_flight < self.max_pipeline {
                let recv_us = obs::now_us();
                match conn.decoder.next_frame() {
                    Some(line) => {
                        conn.in_flight += 1;
                        new_jobs.push(Job {
                            token: TOKEN_BASE + slot as u64,
                            gen: conn.gen,
                            line,
                            recv_us,
                            decode_us: obs::now_us().saturating_sub(recv_us),
                            enqueued_us: 0,
                        });
                    }
                    None => break,
                }
            }
            if !new_jobs.is_empty() {
                self.stats
                    .peak_pipeline_depth
                    .fetch_max(conn.in_flight as u64, Ordering::Relaxed);
            }
        }
        if new_jobs.is_empty() {
            self.jobs_scratch = new_jobs;
            return;
        }
        let n = new_jobs.len();
        let enqueued_us = obs::now_us();
        for job in &mut new_jobs {
            job.enqueued_us = enqueued_us;
        }
        self.queue.in_flight.fetch_add(n as u64, Ordering::SeqCst);
        // lint: allow(panics, reason = "mutex poisoning is fatal by design: a thread that panicked holding the job queue already broke the dispatch invariants")
        self.queue.jobs.lock().unwrap().extend(new_jobs.drain(..));
        self.jobs_scratch = new_jobs;
        if n == 1 {
            self.queue.ready.notify_one();
        } else {
            self.queue.ready.notify_all();
        }
    }

    /// Move finished replies from the outbox into their connections'
    /// write buffers, then resume those connections (paused reads may
    /// unblock, buffered frames may dispatch, replies flush).
    fn drain_outbox(&mut self) {
        // Swap (not take) so the vector handed to the workers keeps its
        // capacity from previous ticks — no realloc ramp-up per drain.
        let mut replies = std::mem::take(&mut self.replies_scratch);
        {
            // lint: allow(panics, reason = "mutex poisoning is fatal by design: a worker that panicked mid-push left the outbox in an unknown state")
            std::mem::swap(&mut replies, &mut *self.outbox.replies.lock().unwrap());
        }
        if replies.is_empty() {
            self.replies_scratch = replies;
            return;
        }
        let mut touched = std::mem::take(&mut self.touched_scratch);
        for r in replies.drain(..) {
            let slot = (r.token - TOKEN_BASE) as usize;
            if let Some(c) = self.conns.get_mut(slot).and_then(Option::as_mut) {
                // `gen` mismatch ⇒ the request's connection died and the
                // slot was reused: drop the reply, never cross-deliver.
                if c.gen == r.gen {
                    c.in_flight -= 1;
                    c.last_activity = Instant::now();
                    c.out.extend_from_slice(&r.bytes);
                    let now = obs::now_us();
                    let mut span = r.span;
                    span.dispatch_us = now.saturating_sub(r.pushed_us);
                    let abs_end = c.written_total + (c.out.len() - c.out_pos) as u64;
                    c.pending_spans.push_back((abs_end, now, span));
                    touched.push(slot);
                }
            }
        }
        let stopping = self.stop.load(Ordering::SeqCst);
        touched.sort_unstable();
        touched.dedup();
        self.replies_scratch = replies;
        for &slot in &touched {
            if stopping {
                self.flush_and_update(slot);
            } else {
                self.handle_readable(slot);
            }
        }
        touched.clear();
        self.touched_scratch = touched;
    }

    /// Write as much buffered reply data as the socket accepts, enforce
    /// the slow-reader cap, and update poller interest.
    fn flush_and_update(&mut self, slot: usize) {
        let mut dead = false;
        let mut overflow = false;
        {
            let conn = match self.conns.get_mut(slot).and_then(Option::as_mut) {
                Some(c) => c,
                None => return,
            };
            while conn.out_pos < conn.out.len() {
                // lint: allow(panics, reason = "the loop condition guarantees out_pos < out.len(), so the range start is in bounds")
                match conn.stream.write(&conn.out[conn.out_pos..]) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => {
                        conn.out_pos += n;
                        conn.written_total += n as u64;
                        conn.last_activity = Instant::now();
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
            if !dead {
                if conn.out_pos == conn.out.len() {
                    conn.out.clear();
                    conn.out_pos = 0;
                } else if conn.out_pos > 64 * 1024 {
                    conn.out.drain(..conn.out_pos);
                    conn.out_pos = 0;
                }
                overflow = conn.out.len() - conn.out_pos > MAX_WRITE_BUFFER;
                // Complete every span whose reply bytes are now fully on
                // the wire (compaction above is safe: completion is keyed
                // on the cumulative stream offset, not buffer indices).
                let now = obs::now_us();
                while conn
                    .pending_spans
                    .front()
                    .is_some_and(|(end, _, _)| *end <= conn.written_total)
                {
                    if let Some((_, entered_us, mut span)) = conn.pending_spans.pop_front() {
                        span.reply_us = now.saturating_sub(entered_us);
                        span.total_us = now.saturating_sub(span.recv_us);
                        complete_span(span, self.slow_ms);
                    }
                }
            }
        }
        if dead {
            self.close_conn(slot);
            return;
        }
        if overflow {
            let n = self.stats.slow_reader_disconnects.fetch_add(1, Ordering::Relaxed) + 1;
            log::warn(
                "hub.transport",
                "disconnecting slow reader",
                &[
                    ("buffered_over", MAX_WRITE_BUFFER.to_string()),
                    ("total_disconnects", n.to_string()),
                ],
            );
            self.close_conn(slot);
            return;
        }
        self.update_interest(slot);
    }

    fn update_interest(&mut self, slot: usize) {
        let (fd, want, current) = match self.conns.get(slot).and_then(Option::as_ref) {
            Some(c) => (
                c.stream.as_raw_fd(),
                Interest {
                    readable: !c.read_closed && c.in_flight < self.max_pipeline,
                    writable: c.out_pos < c.out.len(),
                },
                c.interest,
            ),
            None => return,
        };
        if want != current
            && self.poller.modify(fd, TOKEN_BASE + slot as u64, want).is_ok()
        {
            if let Some(c) = self.conns.get_mut(slot).and_then(Option::as_mut) {
                c.interest = want;
            }
        }
    }

    /// Close connections that are finished (peer EOF / decoder poisoned,
    /// everything in flight answered and flushed) or idle past
    /// `idle_timeout` — the latter unconditionally: under the reactor an
    /// idle socket no longer occupies a worker, but reaping keeps fd
    /// accounting predictable and frees abandoned peers promptly.
    fn sweep(&mut self) {
        let now = Instant::now();
        let to_close: Vec<(usize, bool)> = self
            .conns
            .iter()
            .enumerate()
            .filter_map(|(slot, c)| {
                let c = c.as_ref()?;
                if !c.drained() {
                    return None;
                }
                if c.read_closed {
                    return Some((slot, false));
                }
                let idle = now.duration_since(c.last_activity) >= self.idle_timeout;
                idle.then_some((slot, true))
            })
            .collect();
        for (slot, idle_reap) in to_close {
            if idle_reap {
                self.stats.idle_reaped_connections.fetch_add(1, Ordering::Relaxed);
            }
            self.close_conn(slot);
        }
    }

    fn close_conn(&mut self, slot: usize) {
        if let Some(conn) = self.conns.get_mut(slot).and_then(Option::take) {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.free.push(slot);
            self.open -= 1;
            self.stats.open_connections.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Shutdown drain. Phase 1 (no deadline — the blocking transport
    /// likewise joined workers mid-request): discard undispatched frames,
    /// then wait for every dispatched request to finish and its reply to
    /// flush. Phase 2: peers that stop reading get [`WRITE_GRACE`] for
    /// the remaining bytes, then everything closes.
    fn drain(&mut self) {
        let _ = self.poller.deregister(self.listener.as_raw_fd());
        // lint: allow(panics, reason = "mutex poisoning is fatal by design: shutdown cannot reason about a queue a panicked holder left behind")
        let discarded: Vec<Job> = self.queue.jobs.lock().unwrap().drain(..).collect();
        if !discarded.is_empty() {
            self.queue.in_flight.fetch_sub(discarded.len() as u64, Ordering::SeqCst);
            for job in &discarded {
                let slot = (job.token - TOKEN_BASE) as usize;
                if let Some(c) = self.conns.get_mut(slot).and_then(Option::as_mut) {
                    if c.gen == job.gen {
                        c.in_flight -= 1;
                    }
                }
            }
        }
        let mut grace: Option<Instant> = None;
        loop {
            // Read the dispatch counter *before* draining the outbox:
            // workers push the reply before decrementing, so a zero read
            // here guarantees the drain below saw every reply.
            let pending = self.queue.in_flight.load(Ordering::SeqCst);
            self.drain_outbox();
            let open: Vec<usize> = self
                .conns
                .iter()
                .enumerate()
                .filter_map(|(s, c)| c.is_some().then_some(s))
                .collect();
            for slot in open {
                self.flush_and_update(slot);
            }
            let unflushed =
                self.conns.iter().flatten().any(|c| c.out_pos < c.out.len());
            if pending == 0 && !unflushed {
                break;
            }
            if pending == 0 {
                let deadline = *grace.get_or_insert_with(|| Instant::now() + WRITE_GRACE);
                if Instant::now() >= deadline {
                    break;
                }
            }
            let mut events = std::mem::take(&mut self.events);
            events.clear();
            let _ = self.poller.wait(&mut events, Some(Duration::from_millis(20)));
            for ev in &events {
                if ev.token == TOKEN_WAKER {
                    self.wake_rx.drain();
                }
            }
            self.events = events;
        }
        for slot in 0..self.conns.len() {
            self.close_conn(slot);
        }
    }
}

/// Best-effort structured refusal: flood control answers with a normal v1
/// error frame, so well-behaved clients see `unavailable` instead of a
/// silent hangup. The accepted socket is still in blocking mode, so a
/// short write timeout bounds how long a never-reading peer can hold the
/// reactor; failures are counted and logged instead of silently ignored.
fn refuse(stream: TcpStream, stats: &TransportStats) {
    stats.refused_connections.fetch_add(1, Ordering::Relaxed);
    let mut stream = stream;
    let _ = stream.set_write_timeout(Some(POLL_INTERVAL));
    let reply = Response::err(
        0,
        WireError::new(ErrorCode::Unavailable, "hub at connection capacity, retry later"),
    );
    let frame = format!("{}\n", reply.to_line());
    if let Err(e) = stream.write_all(frame.as_bytes()) {
        let n = stats.refusal_write_failures.fetch_add(1, Ordering::Relaxed) + 1;
        log::warn(
            "hub.transport",
            "refusal frame write failed",
            &[("total_failures", n.to_string()), ("error", e.to_string())],
        );
    }
}

/// Record a completed request trace: every reactor-measured stage goes
/// into its histogram, and the span lands in the trace ring (promoting
/// to the slow-request log past `slow_ms`). Stages recorded here are
/// disjoint sub-intervals of the request lifetime, so the per-stage
/// histograms stay internally consistent with `request_total` —
/// identical counts, and stage sums never exceeding the total.
/// `Total` is recorded *first* so a concurrent metrics snapshot can
/// observe a total without its sub-stages but never the reverse — the
/// stage-sum ≤ total-sum invariant holds even mid-completion.
fn complete_span(span: Span, slow_ms: u64) {
    let m = obs::metrics();
    m.record(Stage::Total, span.total_us);
    m.record(Stage::Decode, span.decode_us);
    m.record(Stage::QueueWait, span.queue_us);
    m.record(Stage::Service, span.service_us);
    m.record(Stage::Dispatch, span.dispatch_us);
    m.record(Stage::ReplyWrite, span.reply_us);
    m.traces.complete(span, slow_ms);
}

/// Background durability pass (DESIGN.md §9): under
/// [`FsyncPolicy::Interval`] fsync dirty WALs every `interval`, and write
/// a compacted snapshot whenever the store's append threshold is reached.
/// Errors are reported and retried next tick — durability degrades to the
/// last good flush instead of killing the serving path.
fn durability_loop(
    state: &HubState,
    store: &DurableStore,
    stop: &AtomicBool,
    interval: Duration,
) {
    let mut last_flush = Instant::now();
    while !stop.load(Ordering::SeqCst) {
        // Short sleeps so shutdown is observed within POLL_INTERVAL even
        // under long flush intervals.
        std::thread::sleep(POLL_INTERVAL.min(interval));
        if store.config().fsync == FsyncPolicy::Interval && last_flush.elapsed() >= interval {
            last_flush = Instant::now();
            if let Err(e) = store.sync() {
                log::error("hub.durability", "WAL fsync failed", &[("error", format!("{e:#}"))]);
            }
        }
        if store.should_snapshot() {
            if let Err(e) = state.snapshot_to(store) {
                log::error(
                    "hub.durability",
                    "automatic snapshot failed",
                    &[("error", format!("{e:#}"))],
                );
            }
        }
    }
}

/// Worker: pop one decoded frame at a time, execute it against the
/// service, and hand the reply frame back to the reactor. Exits as soon
/// as the stop flag is set; the reactor discards whatever is still
/// queued.
fn worker_loop(
    queue: &JobQueue,
    outbox: &Outbox,
    service: &PredictionService,
    stop: &AtomicBool,
    waker: &Waker,
) {
    loop {
        let job = {
            // lint: allow(panics, reason = "mutex poisoning is fatal by design: a peer worker that panicked holding the queue already corrupted the in_flight accounting")
            let mut jobs = queue.jobs.lock().unwrap();
            loop {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(j) = jobs.pop_front() {
                    break j;
                }
                // Timed wait so a lost wakeup can never stall shutdown.
                // lint: allow(panics, reason = "wait_timeout errs only on poisoning, which is fatal by design (see the lock above)")
                jobs = queue.ready.wait_timeout(jobs, POLL_INTERVAL).unwrap().0;
            }
        };
        let guard = InFlightGuard(&queue.in_flight);
        let metrics = obs::metrics();
        let picked_us = obs::now_us();
        metrics.busy_workers.fetch_add(1, Ordering::Relaxed);
        let (reply, op) = service.handle_line_traced(&job.line, stop);
        metrics.busy_workers.fetch_sub(1, Ordering::Relaxed);
        let span = Span {
            id: reply.id,
            op: op.to_string(),
            recv_us: job.recv_us,
            decode_us: job.decode_us,
            queue_us: picked_us.saturating_sub(job.enqueued_us),
            service_us: obs::now_us().saturating_sub(picked_us),
            ok: reply.result.is_ok(),
            ..Span::default()
        };
        let mut bytes = reply.to_line().into_bytes();
        bytes.push(b'\n');
        let pushed_us = obs::now_us();
        // Push before the guard decrements (see JobQueue::in_flight).
        // lint: allow(panics, reason = "mutex poisoning is fatal by design: losing a reply silently would hang the client; crashing the worker is the honest failure")
        outbox.replies.lock().unwrap().push(Reply {
            token: job.token,
            gen: job.gen,
            bytes,
            span,
            pushed_us,
        });
        drop(guard);
        waker.wake();
    }
}
