//! Cluster configurator (paper §IV): choose a machine type, then the
//! smallest scale-out that meets the user's deadline with the requested
//! confidence, avoiding expected hardware bottlenecks.
//!
//! Scale-out rule (§IV-B), with (μ, σ) from the chosen model's CV
//! residuals and c the confidence:
//!
//! ```text
//! ŝ = min { s ∈ S | t_s + μ + Φ⁻¹(c)·σ ≤ t_max }
//! ```
//!
//! where `Φ⁻¹(c) = erf⁻¹(2c−1)·√2` (≈ 1.64485 at c = 0.95).

pub mod machine;
pub mod scaleout;
pub mod search;

pub use machine::select_machine_type;
pub use scaleout::{select_scale_out, ConfigChoice, ScaleOutOption, UserGoals};
pub use search::{
    configure_search, search_catalog, CatalogSearch, FitGridSource, FrontierEntry, GridPrediction,
    GridSource, MIN_RUNS_PER_TYPE, NoTypesEvaluated, TypeOutcome, TypeReport,
};

use std::sync::Arc;

use anyhow::Context as _;

use crate::cloud::Catalog;
use crate::cv::parallel::FitEngine;
use crate::data::{Dataset, FeatureMatrix};
use crate::models::{C3oPredictor, SelectionReport};
use crate::runtime::FitBackend;
use crate::sim::JobInput;

/// [`fit_prepared`] with an explicit fit-path execution engine — the
/// hub's `PredictionService` passes its configured engine here so cold
/// fits fan CV work across cores (and obey the selection budget), while
/// any engine produces bit-identical scores and the same chosen model.
pub fn fit_prepared_with(
    view: &FeatureMatrix,
    machine: &str,
    backend: Arc<dyn FitBackend>,
    engine: &FitEngine,
) -> crate::Result<(C3oPredictor, SelectionReport)> {
    let data = view
        .train_data(machine)
        .filter(|d| d.len() >= 4)
        .with_context(|| format!("not enough runtime data for machine type {machine}"))?;
    let mut predictor = C3oPredictor::new(backend);
    predictor.set_engine(engine.clone());
    let report = predictor.fit(data)?;
    Ok((predictor, report))
}

/// Fit a C3O predictor from a prebuilt columnar view — the §IV training
/// step. The hub's `PredictionService` calls this with the view its
/// repository snapshot built once for the current dataset revision, so
/// concurrent fits (and refits after a cache invalidation) never
/// re-materialize feature rows. Uses the serial reference engine.
pub fn fit_prepared(
    view: &FeatureMatrix,
    machine: &str,
    backend: Arc<dyn FitBackend>,
) -> crate::Result<(C3oPredictor, SelectionReport)> {
    fit_prepared_with(view, machine, backend, &FitEngine::serial())
}

/// Fit a C3O predictor on one machine type's slice of `shared` — local
/// mode, which has no cached view to reuse.
pub fn fit_predictor(
    shared: &Dataset,
    machine: &str,
    backend: Arc<dyn FitBackend>,
) -> crate::Result<(C3oPredictor, SelectionReport)> {
    fit_prepared(&shared.feature_view(), machine, backend)
}

/// [`configure`] with an explicit fit-path execution engine (the CLI's
/// `--fit-threads` / `--fit-budget` land here).
pub fn configure_with(
    catalog: &Catalog,
    shared: &Dataset,
    maintainer_type: Option<&str>,
    input: &JobInput,
    goals: &UserGoals,
    backend: Arc<dyn FitBackend>,
    engine: &FitEngine,
) -> crate::Result<ConfigChoice> {
    // One columnar view serves both the machine choice and the fit.
    let view = shared.feature_view();
    let machine = select_machine_type(catalog, &view, maintainer_type)?;
    let (predictor, report) = fit_prepared_with(&view, &machine, backend, engine)?;
    let (mu, sigma) = (report.chosen_score.resid_mean, report.chosen_score.resid_std);

    select_scale_out(catalog, &machine, &predictor, input, goals, mu, sigma)
}

/// End-to-end configuration: machine type (§IV-A) then scale-out (§IV-B).
///
/// `shared` is the job's shared runtime dataset (possibly spanning several
/// machine types); `maintainer_type` is the repo maintainer's designated
/// machine type, if any.
pub fn configure(
    catalog: &Catalog,
    shared: &Dataset,
    maintainer_type: Option<&str>,
    input: &JobInput,
    goals: &UserGoals,
    backend: Arc<dyn FitBackend>,
) -> crate::Result<ConfigChoice> {
    configure_with(
        catalog,
        shared,
        maintainer_type,
        input,
        goals,
        backend,
        &FitEngine::serial(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use crate::sim::{generate_job, GeneratorConfig};
    use crate::data::JobKind;

    #[test]
    fn end_to_end_configure_returns_valid_choice() {
        let catalog = Catalog::aws_like();
        let ds = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        let input = JobInput::new(JobKind::Sort, 15.0, vec![]);
        let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
        let choice = configure(
            &catalog,
            &ds,
            Some("m5.xlarge"),
            &input,
            &goals,
            Arc::new(NativeBackend::new()),
        )
        .unwrap();
        assert_eq!(choice.machine_type, "m5.xlarge");
        assert!(catalog.scale_outs.contains(&choice.scale_out));
        assert!(choice.predicted_runtime_s > 0.0);
    }

    #[test]
    fn parallel_engine_configures_identically_to_serial() {
        let catalog = Catalog::aws_like();
        let ds = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        let input = JobInput::new(JobKind::Sort, 15.0, vec![]);
        let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
        let serial = configure(
            &catalog,
            &ds,
            Some("m5.xlarge"),
            &input,
            &goals,
            Arc::new(NativeBackend::new()),
        )
        .unwrap();
        let parallel = configure_with(
            &catalog,
            &ds,
            Some("m5.xlarge"),
            &input,
            &goals,
            Arc::new(NativeBackend::new()),
            &FitEngine::with_threads(4),
        )
        .unwrap();
        assert_eq!(serial.machine_type, parallel.machine_type);
        assert_eq!(serial.scale_out, parallel.scale_out);
        assert_eq!(
            serial.predicted_runtime_s.to_bits(),
            parallel.predicted_runtime_s.to_bits()
        );
    }
}
