//! Machine-type selection (paper §IV-A).
//!
//! The maintainer of a C3O repository designates a suitable machine type
//! from test runs; users adopt it and only tune the scale-out. When no
//! designation exists, the fallback "preferably chooses a general-purpose
//! machine for which there is runtime data available".
//!
//! Selection consumes a [`FeatureMatrix`] view, whose per-machine counts
//! are already materialized — on the hub this is the repository
//! snapshot's revision-cached view, so the per-request path does no
//! record scan at all; local mode builds the view once per `configure`
//! and reuses it for the fit.

use crate::cloud::Catalog;
use crate::data::FeatureMatrix;

/// Pick the machine type per §IV-A.
pub fn select_machine_type(
    catalog: &Catalog,
    view: &FeatureMatrix,
    maintainer_type: Option<&str>,
) -> crate::Result<String> {
    anyhow::ensure!(view.machines().next().is_some(), "no runtime data at all");

    if let Some(mt) = maintainer_type {
        catalog.get(mt)?; // must exist in the catalog
        anyhow::ensure!(
            view.rows(mt) > 0,
            "maintainer designated {mt} but the shared dataset has no runs on it"
        );
        return Ok(mt.to_string());
    }

    // Fallback: general-purpose types with data, most data first.
    let mut best: Option<(usize, String)> = None;
    for t in catalog.general_purpose() {
        let n = view.rows(&t.name);
        if n > 0 && best.as_ref().map_or(true, |(bn, _)| n > *bn) {
            best = Some((n, t.name.clone()));
        }
    }
    if let Some((_, name)) = best {
        return Ok(name);
    }
    // Last resort: any type with the most data (ties go to the
    // lexicographically last type: `machines()` iterates sorted and
    // `max_by_key` keeps the last maximum).
    let name = view
        .machines()
        .max_by_key(|m| view.rows(m))
        .expect("non-empty")
        .to_string();
    Ok(name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, JobKind, RunRecord};

    fn view_with(machines: &[(&str, usize)]) -> FeatureMatrix {
        let mut ds = Dataset::new(JobKind::Sort);
        for (mt, count) in machines {
            for i in 0..*count {
                ds.push(RunRecord {
                    machine_type: mt.to_string(),
                    scale_out: 2 + (i as u32 % 6),
                    data_size_gb: 10.0 + i as f64,
                    context: vec![],
                    runtime_s: 100.0 + i as f64,
                })
                .unwrap();
            }
        }
        ds.feature_view()
    }

    #[test]
    fn maintainer_designation_wins() {
        let catalog = Catalog::aws_like();
        let view = view_with(&[("m5.xlarge", 5), ("c5.xlarge", 50)]);
        let mt = select_machine_type(&catalog, &view, Some("m5.xlarge")).unwrap();
        assert_eq!(mt, "m5.xlarge");
    }

    #[test]
    fn maintainer_designation_requires_data() {
        let catalog = Catalog::aws_like();
        let view = view_with(&[("c5.xlarge", 5)]);
        assert!(select_machine_type(&catalog, &view, Some("m5.xlarge")).is_err());
    }

    #[test]
    fn maintainer_designation_must_be_in_catalog() {
        let catalog = Catalog::aws_like();
        let view = view_with(&[("weird.type", 5)]);
        assert!(select_machine_type(&catalog, &view, Some("weird.type")).is_err());
    }

    #[test]
    fn fallback_prefers_general_purpose_with_data() {
        let catalog = Catalog::aws_like();
        // c5 has more data, but m5 (general) has data too => m5 wins.
        let view = view_with(&[("m5.xlarge", 5), ("c5.xlarge", 50)]);
        let mt = select_machine_type(&catalog, &view, None).unwrap();
        assert_eq!(mt, "m5.xlarge");
    }

    #[test]
    fn fallback_uses_any_type_when_no_general_data() {
        let catalog = Catalog::aws_like();
        let view = view_with(&[("c5.xlarge", 3), ("r5.xlarge", 9)]);
        let mt = select_machine_type(&catalog, &view, None).unwrap();
        assert_eq!(mt, "r5.xlarge");
    }

    #[test]
    fn empty_dataset_rejected() {
        let catalog = Catalog::aws_like();
        let view = Dataset::new(JobKind::Sort).feature_view();
        assert!(select_machine_type(&catalog, &view, None).is_err());
    }
}
