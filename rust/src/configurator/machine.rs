//! Machine-type selection (paper §IV-A).
//!
//! The maintainer of a C3O repository designates a suitable machine type
//! from test runs; users adopt it and only tune the scale-out. When no
//! designation exists, the fallback "preferably chooses a general-purpose
//! machine for which there is runtime data available" — most runs wins,
//! ties go to the lexicographically smallest machine-type name (the one
//! deterministic rule, applied by both fallback passes).
//!
//! Selection consumes a [`FeatureMatrix`] view, whose per-machine counts
//! are already materialized — on the hub this is the repository
//! snapshot's revision-cached view, so the per-request path does no
//! record scan at all; local mode builds the view once per `configure`
//! and reuses it for the fit.

use crate::cloud::Catalog;
use crate::data::FeatureMatrix;

/// Pick the machine type per §IV-A.
pub fn select_machine_type(
    catalog: &Catalog,
    view: &FeatureMatrix,
    maintainer_type: Option<&str>,
) -> crate::Result<String> {
    anyhow::ensure!(view.machines().next().is_some(), "no runtime data at all");

    if let Some(mt) = maintainer_type {
        catalog.get(mt)?; // must exist in the catalog
        anyhow::ensure!(
            view.rows(mt) > 0,
            "maintainer designated {mt} but the shared dataset has no runs on it"
        );
        return Ok(mt.to_string());
    }

    // Fallback: general-purpose types with data, most data first. Ties —
    // here and in the last resort below — go to the lexicographically
    // *smallest* machine-type name, so the pick is deterministic and
    // independent of catalog or view iteration order (the two paths used
    // to disagree: first-in-catalog-order vs last-in-sorted-order).
    let mut best: Option<(usize, &str)> = None;
    for t in catalog.general_purpose() {
        let n = view.rows(&t.name);
        if n == 0 {
            continue;
        }
        let better = match best {
            None => true,
            Some((bn, bname)) => n > bn || (n == bn && t.name.as_str() < bname),
        };
        if better {
            best = Some((n, t.name.as_str()));
        }
    }
    if let Some((_, name)) = best {
        return Ok(name.to_string());
    }
    // Last resort: any type with the most data. `machines()` iterates
    // sorted ascending and only a strictly larger count replaces the
    // incumbent, so ties keep the lexicographically smallest name — the
    // same rule as the general-purpose pass.
    let mut best: Option<(usize, &str)> = None;
    for m in view.machines() {
        let n = view.rows(m);
        let better = match best {
            None => true,
            Some((bn, _)) => n > bn,
        };
        if better {
            best = Some((n, m));
        }
    }
    let (_, name) = best.expect("non-empty");
    Ok(name.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, JobKind, RunRecord};

    fn view_with(machines: &[(&str, usize)]) -> FeatureMatrix {
        let mut ds = Dataset::new(JobKind::Sort);
        for (mt, count) in machines {
            for i in 0..*count {
                ds.push(RunRecord {
                    machine_type: mt.to_string(),
                    scale_out: 2 + (i as u32 % 6),
                    data_size_gb: 10.0 + i as f64,
                    context: vec![],
                    runtime_s: 100.0 + i as f64,
                })
                .unwrap();
            }
        }
        ds.feature_view()
    }

    #[test]
    fn maintainer_designation_wins() {
        let catalog = Catalog::aws_like();
        let view = view_with(&[("m5.xlarge", 5), ("c5.xlarge", 50)]);
        let mt = select_machine_type(&catalog, &view, Some("m5.xlarge")).unwrap();
        assert_eq!(mt, "m5.xlarge");
    }

    #[test]
    fn maintainer_designation_requires_data() {
        let catalog = Catalog::aws_like();
        let view = view_with(&[("c5.xlarge", 5)]);
        assert!(select_machine_type(&catalog, &view, Some("m5.xlarge")).is_err());
    }

    #[test]
    fn maintainer_designation_must_be_in_catalog() {
        let catalog = Catalog::aws_like();
        let view = view_with(&[("weird.type", 5)]);
        assert!(select_machine_type(&catalog, &view, Some("weird.type")).is_err());
    }

    #[test]
    fn fallback_prefers_general_purpose_with_data() {
        let catalog = Catalog::aws_like();
        // c5 has more data, but m5 (general) has data too => m5 wins.
        let view = view_with(&[("m5.xlarge", 5), ("c5.xlarge", 50)]);
        let mt = select_machine_type(&catalog, &view, None).unwrap();
        assert_eq!(mt, "m5.xlarge");
    }

    #[test]
    fn fallback_uses_any_type_when_no_general_data() {
        let catalog = Catalog::aws_like();
        let view = view_with(&[("c5.xlarge", 3), ("r5.xlarge", 9)]);
        let mt = select_machine_type(&catalog, &view, None).unwrap();
        assert_eq!(mt, "r5.xlarge");
    }

    #[test]
    fn general_purpose_tie_is_lexicographically_first() {
        let catalog = Catalog::aws_like();
        // m5.2xlarge and m5.xlarge tied on count; "m5.2xlarge" sorts first.
        let view = view_with(&[("m5.xlarge", 7), ("m5.2xlarge", 7), ("c5.xlarge", 50)]);
        let mt = select_machine_type(&catalog, &view, None).unwrap();
        assert_eq!(mt, "m5.2xlarge");
    }

    #[test]
    fn last_resort_tie_is_lexicographically_first() {
        let catalog = Catalog::aws_like();
        // No general-purpose data; c5 and r5 tied => lexicographic pick.
        let view = view_with(&[("r5.xlarge", 9), ("c5.xlarge", 9)]);
        let mt = select_machine_type(&catalog, &view, None).unwrap();
        assert_eq!(mt, "c5.xlarge");
    }

    #[test]
    fn empty_dataset_rejected() {
        let catalog = Catalog::aws_like();
        let view = Dataset::new(JobKind::Sort).feature_view();
        assert!(select_machine_type(&catalog, &view, None).is_err());
    }
}
