//! Scale-out selection (paper §IV-B): the erf-confidence admission rule,
//! bottleneck exclusion, and the runtime/cost pair view.

use crate::cloud::Catalog;
use crate::models::C3oPredictor;
use crate::sim::JobInput;
use crate::util::erf::confidence_multiplier;

/// What the user wants (Fig. 4 step 3).
#[derive(Debug, Clone)]
pub struct UserGoals {
    /// Maximum allowed runtime t_max, if the job has a deadline.
    pub deadline_s: Option<f64>,
    /// Confidence c that the deadline is met (paper default 0.95).
    pub confidence: f64,
}

impl Default for UserGoals {
    fn default() -> Self {
        UserGoals { deadline_s: None, confidence: 0.95 }
    }
}

/// One candidate scale-out with its predictions — the §IV-B "pairs of
/// estimated runtimes and resulting prices" shown when runtime and cost
/// are of equal concern.
#[derive(Debug, Clone)]
pub struct ScaleOutOption {
    pub scale_out: u32,
    pub predicted_runtime_s: f64,
    /// Upper confidence bound: t_s + μ + Φ⁻¹(c)·σ.
    pub runtime_ucb_s: f64,
    pub cost_usd: f64,
    /// Expected memory bottleneck at this scale-out.
    pub bottleneck: bool,
    /// Meets the deadline at the requested confidence (None: no deadline).
    pub admissible: Option<bool>,
}

/// The configurator's decision.
#[derive(Debug, Clone)]
pub struct ConfigChoice {
    pub machine_type: String,
    pub scale_out: u32,
    pub predicted_runtime_s: f64,
    pub runtime_ucb_s: f64,
    pub est_cost_usd: f64,
    /// All evaluated options (for the §IV-B runtime/cost plot).
    pub options: Vec<ScaleOutOption>,
}

/// Memory-bottleneck heuristic (§IV-B): for iterative jobs, flag
/// scale-outs whose total usable memory cannot hold the working set.
/// Mirrors the simulator's spill model conservatively (the configurator
/// only sees dataset size, not the exact expansion factor).
fn expect_bottleneck(
    catalog: &Catalog,
    machine_type: &str,
    scale_out: u32,
    input: &JobInput,
) -> bool {
    if !input.job.is_iterative() {
        return false;
    }
    let mt = match catalog.get(machine_type) {
        Ok(mt) => mt,
        Err(_) => return false,
    };
    // Conservative working-set estimate: 1.25x the dataset (PageRank's
    // graph expansion is handled through its context feature by the
    // *predictor*; the exclusion rule is a guard rail, not the model).
    let working = 1.25 * input.data_size_gb;
    let usable = 0.55 * mt.memory_gb * scale_out as f64;
    working > usable
}

/// Choose the §IV-B scale-out.
///
/// With a deadline: the smallest admissible scale-out, skipping expected
/// bottlenecks "unless there is no valid other option". Without a
/// deadline: the cheapest non-bottlenecked option.
pub fn select_scale_out(
    catalog: &Catalog,
    machine_type: &str,
    predictor: &C3oPredictor,
    input: &JobInput,
    goals: &UserGoals,
    resid_mu: f64,
    resid_sigma: f64,
) -> crate::Result<ConfigChoice> {
    anyhow::ensure!(
        goals.confidence > 0.0 && goals.confidence < 1.0,
        "confidence must be in (0,1)"
    );
    let mt = catalog.get(machine_type)?;
    let mult = confidence_multiplier(goals.confidence);

    let mut options = Vec::with_capacity(catalog.scale_outs.len());
    for &s in &catalog.scale_outs {
        let mut features = vec![s as f64, input.data_size_gb];
        features.extend_from_slice(&input.context);
        let t = predictor.predict_one(&features)?.max(0.0);
        let ucb = t + resid_mu + mult * resid_sigma;
        let bottleneck = expect_bottleneck(catalog, machine_type, s, input);
        options.push(ScaleOutOption {
            scale_out: s,
            predicted_runtime_s: t,
            runtime_ucb_s: ucb,
            cost_usd: catalog.job_cost(mt, s, t),
            bottleneck,
            admissible: goals.deadline_s.map(|d| ucb <= d),
        });
    }

    let pick = |opts: &[ScaleOutOption]| -> Option<u32> {
        match goals.deadline_s {
            Some(_) => opts
                .iter()
                .filter(|o| o.admissible == Some(true))
                .map(|o| o.scale_out)
                .min(),
            None => opts
                .iter()
                .min_by(|a, b| a.cost_usd.partial_cmp(&b.cost_usd).unwrap())
                .map(|o| o.scale_out),
        }
    };

    // First pass excludes bottlenecked scale-outs; §IV-B allows them only
    // when nothing else is valid.
    let clean: Vec<ScaleOutOption> =
        options.iter().filter(|o| !o.bottleneck).cloned().collect();
    let chosen = pick(&clean)
        .or_else(|| pick(&options))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no scale-out in {:?} meets the deadline {:?} at confidence {}",
                catalog.scale_outs,
                goals.deadline_s,
                goals.confidence
            )
        })?;

    let opt = options.iter().find(|o| o.scale_out == chosen).unwrap().clone();
    Ok(ConfigChoice {
        machine_type: machine_type.to_string(),
        scale_out: opt.scale_out,
        predicted_runtime_s: opt.predicted_runtime_s,
        runtime_ucb_s: opt.runtime_ucb_s,
        est_cost_usd: opt.cost_usd,
        options,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::JobKind;
    use crate::linalg::Matrix;
    use crate::models::TrainData;
    use crate::runtime::NativeBackend;
    use crate::util::prng::Pcg;
    use std::sync::Arc;

    /// Predictor trained on a clean 1/s world.
    fn trained_predictor() -> C3oPredictor {
        let mut rng = Pcg::seed(11);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..80 {
            let s = rng.range(2, 13) as f64;
            let d = rng.range_f64(10.0, 30.0);
            rows.push(vec![s, d]);
            y.push(40.0 + 60.0 * d / s + 2.0 * s);
        }
        let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();
        let mut p = C3oPredictor::new(Arc::new(NativeBackend::new()));
        p.fit(&data).unwrap();
        p
    }

    fn sort_input(d: f64) -> JobInput {
        JobInput::new(JobKind::Sort, d, vec![])
    }

    #[test]
    fn picks_minimum_admissible_scaleout() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals { deadline_s: Some(320.0), confidence: 0.95 };
        let c = select_scale_out(&catalog, "m5.xlarge", &p, &sort_input(20.0), &goals, 0.0, 5.0)
            .unwrap();
        // Every admissible option must be >= the chosen one.
        for o in &c.options {
            if o.admissible == Some(true) {
                assert!(o.scale_out >= c.scale_out);
            }
        }
        assert!(c.runtime_ucb_s <= 320.0);
    }

    #[test]
    fn higher_confidence_never_lowers_scaleout() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let mut prev = 0u32;
        for &c in &[0.5, 0.8, 0.9, 0.95, 0.99] {
            let goals = UserGoals { deadline_s: Some(330.0), confidence: c };
            let choice = select_scale_out(
                &catalog, "m5.xlarge", &p, &sort_input(20.0), &goals, 0.0, 30.0,
            )
            .unwrap();
            assert!(choice.scale_out >= prev, "c={c}: {} < {prev}", choice.scale_out);
            prev = choice.scale_out;
        }
    }

    #[test]
    fn impossible_deadline_errors() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals { deadline_s: Some(1.0), confidence: 0.95 };
        assert!(select_scale_out(
            &catalog, "m5.xlarge", &p, &sort_input(20.0), &goals, 0.0, 5.0
        )
        .is_err());
    }

    #[test]
    fn no_deadline_picks_cheapest() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        let c = select_scale_out(&catalog, "m5.xlarge", &p, &sort_input(20.0), &goals, 0.0, 5.0)
            .unwrap();
        let min_cost = c
            .options
            .iter()
            .filter(|o| !o.bottleneck)
            .map(|o| o.cost_usd)
            .fold(f64::INFINITY, f64::min);
        assert!((c.est_cost_usd - min_cost).abs() < 1e-12);
    }

    #[test]
    fn bottlenecked_scaleouts_skipped_for_iterative_jobs() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        // K-Means 30 GB on c5.xlarge (8 GB): working 37.5 GB needs
        // 37.5/(0.55*8) ≈ 8.5 ⇒ s <= 8 is bottlenecked.
        let input = JobInput::new(JobKind::KMeans, 30.0, vec![5.0, 0.001]);
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        let c = select_scale_out(&catalog, "c5.xlarge", &p, &input, &goals, 0.0, 5.0).unwrap();
        assert!(c.scale_out >= 9, "chose bottlenecked {}", c.scale_out);
        let opt9 = c.options.iter().find(|o| o.scale_out == 9).unwrap();
        assert!(!opt9.bottleneck);
        let opt8 = c.options.iter().find(|o| o.scale_out == 8).unwrap();
        assert!(opt8.bottleneck);
    }

    #[test]
    fn bottleneck_allowed_when_no_alternative() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        // 60 GB on c5.xlarge: bottlenecked at every catalog scale-out.
        let input = JobInput::new(JobKind::KMeans, 60.0, vec![5.0, 0.001]);
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        let c = select_scale_out(&catalog, "c5.xlarge", &p, &input, &goals, 0.0, 5.0).unwrap();
        assert!(c.options.iter().all(|o| o.bottleneck));
        // Still returns the cheapest rather than erroring.
        assert!(catalog.scale_outs.contains(&c.scale_out));
    }

    #[test]
    fn ucb_uses_paper_multiplier() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals { deadline_s: Some(1e9), confidence: 0.95 };
        let c = select_scale_out(&catalog, "m5.xlarge", &p, &sort_input(15.0), &goals, 2.0, 10.0)
            .unwrap();
        for o in &c.options {
            let expect = o.predicted_runtime_s + 2.0 + 1.6448536269514722 * 10.0;
            assert!((o.runtime_ucb_s - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn invalid_confidence_rejected() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        for bad in [0.0, 1.0, -0.5, 1.5] {
            let goals = UserGoals { deadline_s: None, confidence: bad };
            assert!(select_scale_out(
                &catalog, "m5.xlarge", &p, &sort_input(15.0), &goals, 0.0, 5.0
            )
            .is_err());
        }
    }
}
