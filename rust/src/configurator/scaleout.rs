//! Scale-out selection (paper §IV-B): the erf-confidence admission rule,
//! bottleneck exclusion, and the runtime/cost pair view.
//!
//! The grid evaluation ([`build_options`]) and the pick rule
//! ([`pick_option`]) are shared with the catalog-wide search in
//! [`crate::configurator::search`], so a full-grid search is bit-identical
//! to an exhaustive per-type [`select_scale_out`] loop.

use crate::cloud::{Catalog, MachineType};
use crate::models::C3oPredictor;
use crate::sim::JobInput;
use crate::util::erf::confidence_multiplier;

/// What the user wants (Fig. 4 step 3).
#[derive(Debug, Clone)]
pub struct UserGoals {
    /// Maximum allowed runtime t_max, if the job has a deadline.
    pub deadline_s: Option<f64>,
    /// Confidence c that the deadline is met (paper default 0.95).
    pub confidence: f64,
}

impl Default for UserGoals {
    fn default() -> Self {
        UserGoals { deadline_s: None, confidence: 0.95 }
    }
}

/// One candidate scale-out with its predictions — the §IV-B "pairs of
/// estimated runtimes and resulting prices" shown when runtime and cost
/// are of equal concern.
#[derive(Debug, Clone)]
pub struct ScaleOutOption {
    pub scale_out: u32,
    pub predicted_runtime_s: f64,
    /// Upper confidence bound: t_s + μ + Φ⁻¹(c)·σ.
    pub runtime_ucb_s: f64,
    pub cost_usd: f64,
    /// Expected memory bottleneck at this scale-out.
    pub bottleneck: bool,
    /// Meets the deadline at the requested confidence (None: no deadline).
    pub admissible: Option<bool>,
}

/// The configurator's decision.
#[derive(Debug, Clone)]
pub struct ConfigChoice {
    pub machine_type: String,
    pub scale_out: u32,
    pub predicted_runtime_s: f64,
    pub runtime_ucb_s: f64,
    pub est_cost_usd: f64,
    /// All evaluated options (for the §IV-B runtime/cost plot).
    pub options: Vec<ScaleOutOption>,
}

/// Memory-bottleneck heuristic (§IV-B): for iterative jobs, flag
/// scale-outs whose total usable memory cannot hold the working set.
/// Mirrors the simulator's spill model conservatively (the configurator
/// only sees dataset size, not the exact expansion factor).
///
/// Takes the *resolved* [`MachineType`]: callers look the type up in the
/// catalog and propagate the lookup error before any grid evaluation, so
/// a catalog/view mismatch fails loudly — the old string-keyed variant
/// swallowed the error as "no bottleneck", which under grid search would
/// silently admit bottlenecked configurations.
fn expect_bottleneck(mt: &MachineType, scale_out: u32, input: &JobInput) -> bool {
    if !input.job.is_iterative() {
        return false;
    }
    // Conservative working-set estimate: 1.25x the dataset (PageRank's
    // graph expansion is handled through its context feature by the
    // *predictor*; the exclusion rule is a guard rail, not the model).
    let working = 1.25 * input.data_size_gb;
    let usable = 0.55 * mt.memory_gb * scale_out as f64;
    working > usable
}

/// Feature rows `[scale_out, data_size, context...]` for the whole
/// scale-out grid, in catalog order — the batch one fitted model answers
/// per machine type (locally row by row, on the hub as one
/// `predict_batch`).
pub(crate) fn grid_rows(catalog: &Catalog, input: &JobInput) -> Vec<Vec<f64>> {
    catalog
        .scale_outs
        .iter()
        .map(|&s| {
            let mut f = Vec::with_capacity(2 + input.context.len());
            f.push(s as f64);
            f.push(input.data_size_gb);
            f.extend_from_slice(&input.context);
            f
        })
        .collect()
}

/// Evaluate one machine type's scale-out grid from its model's raw
/// runtime predictions (one per `catalog.scale_outs` entry, in order).
/// The caller has already validated `goals.confidence` and resolved `mt`
/// from the catalog.
pub(crate) fn build_options(
    catalog: &Catalog,
    mt: &MachineType,
    runtimes: &[f64],
    input: &JobInput,
    goals: &UserGoals,
    resid_mu: f64,
    resid_sigma: f64,
) -> Vec<ScaleOutOption> {
    let mult = confidence_multiplier(goals.confidence);
    catalog
        .scale_outs
        .iter()
        .zip(runtimes)
        .map(|(&s, &raw)| {
            let t = raw.max(0.0);
            let ucb = t + resid_mu + mult * resid_sigma;
            ScaleOutOption {
                scale_out: s,
                predicted_runtime_s: t,
                runtime_ucb_s: ucb,
                cost_usd: catalog.job_cost(mt, s, t),
                bottleneck: expect_bottleneck(mt, s, input),
                admissible: goals.deadline_s.map(|d| ucb <= d),
            }
        })
        .collect()
}

/// A configuration a user could actually buy: finite positive predicted
/// runtime, finite confidence bound, finite cost. A degenerate model
/// predicting NaN / ∞ / ≤ 0 s yields a $0 or NaN cost that would
/// otherwise win every cost comparison (or panic a `partial_cmp` pick).
pub(crate) fn viable(o: &ScaleOutOption) -> bool {
    o.predicted_runtime_s.is_finite()
        && o.predicted_runtime_s > 0.0
        && o.runtime_ucb_s.is_finite()
        && o.cost_usd.is_finite()
}

/// The §IV-B pick over one machine type's evaluated grid. With a
/// deadline: the smallest admissible scale-out. Without: the cheapest
/// option (`total_cmp`, so NaN costs can never panic; ties go to the
/// smaller scale-out). Non-viable options are disqualified outright;
/// bottlenecked ones are admitted only when no clean option survives.
/// `None` means nothing survived — callers turn that into a structured
/// error, never an unwind (a hub worker must answer an error frame).
pub(crate) fn pick_option<'a>(
    options: &'a [ScaleOutOption],
    goals: &UserGoals,
) -> Option<&'a ScaleOutOption> {
    fn pick_among<'a, I: Iterator<Item = &'a ScaleOutOption>>(
        opts: I,
        goals: &UserGoals,
    ) -> Option<&'a ScaleOutOption> {
        match goals.deadline_s {
            Some(_) => opts.filter(|o| o.admissible == Some(true)).min_by_key(|o| o.scale_out),
            None => opts.min_by(|a, b| {
                a.cost_usd.total_cmp(&b.cost_usd).then(a.scale_out.cmp(&b.scale_out))
            }),
        }
    }
    pick_among(options.iter().filter(|o| viable(o) && !o.bottleneck), goals)
        .or_else(|| pick_among(options.iter().filter(|o| viable(o)), goals))
}

/// Why a pick came up empty — a structured error the hub can answer as an
/// error frame.
pub(crate) fn no_pick_error(
    options: &[ScaleOutOption],
    machine_type: &str,
    catalog: &Catalog,
    goals: &UserGoals,
) -> anyhow::Error {
    if !options.iter().any(viable) {
        anyhow::anyhow!(
            "no scale-out of {machine_type} has a finite positive predicted runtime and \
             cost (degenerate model or catalog entry)"
        )
    } else {
        anyhow::anyhow!(
            "no scale-out in {:?} meets the deadline {:?} at confidence {}",
            catalog.scale_outs,
            goals.deadline_s,
            goals.confidence
        )
    }
}

/// Choose the §IV-B scale-out.
///
/// With a deadline: the smallest admissible scale-out, skipping expected
/// bottlenecks "unless there is no valid other option". Without a
/// deadline: the cheapest non-bottlenecked option.
pub fn select_scale_out(
    catalog: &Catalog,
    machine_type: &str,
    predictor: &C3oPredictor,
    input: &JobInput,
    goals: &UserGoals,
    resid_mu: f64,
    resid_sigma: f64,
) -> crate::Result<ConfigChoice> {
    anyhow::ensure!(
        goals.confidence > 0.0 && goals.confidence < 1.0,
        "confidence must be in (0,1)"
    );
    let mt = catalog.get(machine_type)?;
    let runtimes = grid_rows(catalog, input)
        .iter()
        .map(|row| predictor.predict_one(row))
        .collect::<crate::Result<Vec<f64>>>()?;
    let options = build_options(catalog, mt, &runtimes, input, goals, resid_mu, resid_sigma);
    let chosen = pick_option(&options, goals)
        .ok_or_else(|| no_pick_error(&options, machine_type, catalog, goals))?
        .clone();
    Ok(ConfigChoice {
        machine_type: machine_type.to_string(),
        scale_out: chosen.scale_out,
        predicted_runtime_s: chosen.predicted_runtime_s,
        runtime_ucb_s: chosen.runtime_ucb_s,
        est_cost_usd: chosen.cost_usd,
        options,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::JobKind;
    use crate::linalg::Matrix;
    use crate::models::TrainData;
    use crate::runtime::NativeBackend;
    use crate::util::prng::Pcg;
    use std::sync::Arc;

    /// Predictor trained on a clean 1/s world.
    fn trained_predictor() -> C3oPredictor {
        let mut rng = Pcg::seed(11);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..80 {
            let s = rng.range(2, 13) as f64;
            let d = rng.range_f64(10.0, 30.0);
            rows.push(vec![s, d]);
            y.push(40.0 + 60.0 * d / s + 2.0 * s);
        }
        let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();
        let mut p = C3oPredictor::new(Arc::new(NativeBackend::new()));
        p.fit(&data).unwrap();
        p
    }

    fn sort_input(d: f64) -> JobInput {
        JobInput::new(JobKind::Sort, d, vec![])
    }

    #[test]
    fn picks_minimum_admissible_scaleout() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals { deadline_s: Some(320.0), confidence: 0.95 };
        let c = select_scale_out(&catalog, "m5.xlarge", &p, &sort_input(20.0), &goals, 0.0, 5.0)
            .unwrap();
        // Every admissible option must be >= the chosen one.
        for o in &c.options {
            if o.admissible == Some(true) {
                assert!(o.scale_out >= c.scale_out);
            }
        }
        assert!(c.runtime_ucb_s <= 320.0);
    }

    #[test]
    fn higher_confidence_never_lowers_scaleout() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let mut prev = 0u32;
        for &c in &[0.5, 0.8, 0.9, 0.95, 0.99] {
            let goals = UserGoals { deadline_s: Some(330.0), confidence: c };
            let choice = select_scale_out(
                &catalog, "m5.xlarge", &p, &sort_input(20.0), &goals, 0.0, 30.0,
            )
            .unwrap();
            assert!(choice.scale_out >= prev, "c={c}: {} < {prev}", choice.scale_out);
            prev = choice.scale_out;
        }
    }

    #[test]
    fn impossible_deadline_errors() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals { deadline_s: Some(1.0), confidence: 0.95 };
        assert!(select_scale_out(
            &catalog, "m5.xlarge", &p, &sort_input(20.0), &goals, 0.0, 5.0
        )
        .is_err());
    }

    #[test]
    fn no_deadline_picks_cheapest() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        let c = select_scale_out(&catalog, "m5.xlarge", &p, &sort_input(20.0), &goals, 0.0, 5.0)
            .unwrap();
        let min_cost = c
            .options
            .iter()
            .filter(|o| !o.bottleneck)
            .map(|o| o.cost_usd)
            .fold(f64::INFINITY, f64::min);
        assert!((c.est_cost_usd - min_cost).abs() < 1e-12);
    }

    #[test]
    fn bottlenecked_scaleouts_skipped_for_iterative_jobs() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        // K-Means 30 GB on c5.xlarge (8 GB): working 37.5 GB needs
        // 37.5/(0.55*8) ≈ 8.5 ⇒ s <= 8 is bottlenecked.
        let input = JobInput::new(JobKind::KMeans, 30.0, vec![5.0, 0.001]);
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        let c = select_scale_out(&catalog, "c5.xlarge", &p, &input, &goals, 0.0, 5.0).unwrap();
        assert!(c.scale_out >= 9, "chose bottlenecked {}", c.scale_out);
        let opt9 = c.options.iter().find(|o| o.scale_out == 9).unwrap();
        assert!(!opt9.bottleneck);
        let opt8 = c.options.iter().find(|o| o.scale_out == 8).unwrap();
        assert!(opt8.bottleneck);
    }

    #[test]
    fn bottleneck_allowed_when_no_alternative() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        // 60 GB on c5.xlarge: bottlenecked at every catalog scale-out.
        let input = JobInput::new(JobKind::KMeans, 60.0, vec![5.0, 0.001]);
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        let c = select_scale_out(&catalog, "c5.xlarge", &p, &input, &goals, 0.0, 5.0).unwrap();
        assert!(c.options.iter().all(|o| o.bottleneck));
        // Still returns the cheapest rather than erroring.
        assert!(catalog.scale_outs.contains(&c.scale_out));
    }

    #[test]
    fn ucb_uses_paper_multiplier() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals { deadline_s: Some(1e9), confidence: 0.95 };
        let c = select_scale_out(&catalog, "m5.xlarge", &p, &sort_input(15.0), &goals, 2.0, 10.0)
            .unwrap();
        for o in &c.options {
            let expect = o.predicted_runtime_s + 2.0 + 1.6448536269514722 * 10.0;
            assert!((o.runtime_ucb_s - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn nan_and_zero_cost_options_never_win_or_panic() {
        // Regression: the no-deadline pick used `partial_cmp().unwrap()`
        // (panics on NaN cost) and a degenerate $0 option won every cost
        // comparison.
        let opt = |s: u32, t: f64, ucb: f64, cost: f64| ScaleOutOption {
            scale_out: s,
            predicted_runtime_s: t,
            runtime_ucb_s: ucb,
            cost_usd: cost,
            bottleneck: false,
            admissible: None,
        };
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        let options = vec![
            opt(2, 0.0, 5.0, 0.0),
            opt(3, f64::NAN, f64::NAN, f64::NAN),
            opt(4, 100.0, 110.0, 0.5),
        ];
        assert_eq!(pick_option(&options, &goals).unwrap().scale_out, 4);
        // Nothing viable at all -> None; select_scale_out turns this into
        // a structured error instead of unwinding a hub worker.
        let degenerate = vec![
            opt(2, 0.0, 5.0, 0.0),
            opt(3, f64::INFINITY, f64::INFINITY, f64::INFINITY),
        ];
        assert!(pick_option(&degenerate, &goals).is_none());
    }

    #[test]
    fn degenerate_predictor_errors_instead_of_free_cluster() {
        // A model trained on negative runtimes predicts <= 0 everywhere;
        // the clamped $0 options must be disqualified and the pick must
        // return an error, not a zero-cost configuration.
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for s in 2..12 {
            rows.push(vec![s as f64, 15.0]);
            y.push(-5.0);
        }
        let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();
        let mut p = C3oPredictor::new(Arc::new(NativeBackend::new()));
        p.fit(&data).unwrap();
        let catalog = Catalog::aws_like();
        let goals = UserGoals { deadline_s: None, confidence: 0.95 };
        let err = select_scale_out(&catalog, "m5.xlarge", &p, &sort_input(15.0), &goals, 0.0, 5.0)
            .unwrap_err();
        assert!(err.to_string().contains("finite positive"), "{err:#}");
    }

    #[test]
    fn unknown_machine_type_fails_loudly() {
        // A catalog/view mismatch must surface the catalog error — never
        // degrade to "no bottleneck" and admit the configuration.
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        let goals = UserGoals::default();
        let err = select_scale_out(&catalog, "z9.mega", &p, &sort_input(15.0), &goals, 0.0, 5.0)
            .unwrap_err();
        assert!(err.to_string().contains("unknown machine type"), "{err:#}");
    }

    #[test]
    fn invalid_confidence_rejected() {
        let catalog = Catalog::aws_like();
        let p = trained_predictor();
        for bad in [0.0, 1.0, -0.5, 1.5] {
            let goals = UserGoals { deadline_s: None, confidence: bad };
            assert!(select_scale_out(
                &catalog, "m5.xlarge", &p, &sort_input(15.0), &goals, 0.0, 5.0
            )
            .is_err());
        }
    }
}
