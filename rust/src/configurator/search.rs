//! Catalog-wide configuration search: the full (machine type ×
//! scale-out) grid.
//!
//! `configure` (paper §IV) pins one machine type — the maintainer
//! designation or the §IV-A fallback — and only searches scale-outs for
//! it. The paper's end goal, though, is a *choice*: the cheapest cluster
//! configuration that meets the user's runtime target at the requested
//! confidence (and Flora, arXiv 2502.21046, shows most of the win comes
//! from searching resource *types*, not just scale-outs). This module
//! evaluates every catalog machine type's scale-out grid and returns the
//! cost-optimal admissible configuration plus the ranked runtime/cost
//! frontier.
//!
//! The grid is answered through a [`GridSource`]: one model resolution +
//! one batch prediction per machine type. Local mode fits each type's
//! slice of the shared dataset ([`FitGridSource`], on the PR-3
//! `FitEngine`); the hub's `PredictionService` resolves types through its
//! revision-keyed fitted-model cache, so a warm hub answers the whole
//! grid with **zero refits**. Both sources feed the same
//! `build_options` / `pick_option` internals as
//! [`super::select_scale_out`], so the search is bit-identical to an
//! exhaustive per-type `select_scale_out` loop (asserted by the parity
//! tests below and in `tests/api_v1.rs`).
//!
//! Machine types with fewer than [`MIN_RUNS_PER_TYPE`] runs are reported
//! as [`TypeOutcome::InsufficientData`] — never silently skipped — and a
//! type whose fit fails is reported as [`TypeOutcome::Failed`] without
//! aborting the rest of the grid.

use std::sync::Arc;

use crate::cloud::Catalog;
use crate::cv::parallel::FitEngine;
use crate::data::{Dataset, FeatureMatrix};
use crate::runtime::FitBackend;
use crate::sim::JobInput;

use super::fit_prepared_with;
use super::scaleout::{
    build_options, grid_rows, no_pick_error, pick_option, viable, ConfigChoice, ScaleOutOption,
    UserGoals,
};

/// Minimum runs a machine type needs before the search will evaluate it —
/// the `fit_prepared` training floor. Below it the type is reported as
/// [`TypeOutcome::InsufficientData`].
pub const MIN_RUNS_PER_TYPE: usize = 4;

/// One machine type's fitted model, as the grid search consumes it: the
/// selected model's name, its CV residual distribution (§IV-B), and the
/// raw predicted runtimes for the whole scale-out grid.
#[derive(Debug, Clone)]
pub struct GridPrediction {
    /// Winner of dynamic model selection (GBM | BOM | OGB | ...).
    pub model: String,
    /// CV residual mean μ.
    pub resid_mu: f64,
    /// CV residual std σ.
    pub resid_sigma: f64,
    /// Raw model outputs, one per `catalog.scale_outs` entry, in order.
    pub runtimes: Vec<f64>,
}

/// Source of per-machine-type grid predictions: one model resolution and
/// one batch prediction per type. `runs` feeds the data-sufficiency gate;
/// `predict_grid` is only called for types at or above the floor.
pub trait GridSource {
    /// Runs available in the shared dataset for `machine_type`.
    fn runs(&self, machine_type: &str) -> usize;
    /// Resolve (fit or fetch) the type's model and predict `rows`.
    fn predict_grid(
        &mut self,
        machine_type: &str,
        rows: &[Vec<f64>],
    ) -> crate::Result<GridPrediction>;
}

/// Per-machine-type outcome of the grid search, in catalog order.
#[derive(Debug, Clone)]
pub struct TypeReport {
    pub machine_type: String,
    /// Runs available in the shared dataset for this type.
    pub runs: usize,
    pub outcome: TypeOutcome,
}

/// What happened to one machine type during the search.
#[derive(Debug, Clone)]
pub enum TypeOutcome {
    /// Model fitted (or fetched warm) and the grid evaluated. `pick` is
    /// this type's §IV-B choice — `None` when no option survives
    /// viability/admission.
    Evaluated {
        /// Winner of dynamic model selection for this type.
        model: String,
        /// The evaluated scale-out grid (the §IV-B runtime/cost pairs).
        options: Vec<ScaleOutOption>,
        /// The scale-out this type's §IV-B pick chose, if any survived.
        pick: Option<u32>,
    },
    /// Fewer than the required number of runs; the type was not fitted.
    InsufficientData { required: usize },
    /// The fit or prediction for this type failed; the rest of the grid
    /// is unaffected.
    Failed { error: String },
}

/// Marker error: the search could evaluate *zero* machine types — every
/// type sat below the data floor or failed its fit. A hub-side data /
/// fitting condition, not a bad request: the service maps it to
/// `unavailable` (where an impossible deadline on a fitted grid stays
/// `invalid_data`). Carried as the source of the returned error chain;
/// detect it with `err.downcast_ref::<NoTypesEvaluated>()`.
#[derive(Debug, Clone, Copy)]
pub struct NoTypesEvaluated;

impl std::fmt::Display for NoTypesEvaluated {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("no machine type could be evaluated")
    }
}

impl std::error::Error for NoTypesEvaluated {}

/// One viable grid point in the ranked §IV-B runtime/cost view.
#[derive(Debug, Clone)]
pub struct FrontierEntry {
    pub machine_type: String,
    pub scale_out: u32,
    pub predicted_runtime_s: f64,
    pub runtime_ucb_s: f64,
    pub cost_usd: f64,
    pub bottleneck: bool,
}

/// Result of a catalog-wide search.
#[derive(Debug, Clone)]
pub struct CatalogSearch {
    /// The winning configuration: cheapest across the per-type §IV-B
    /// picks. Under a deadline each type contributes its *smallest*
    /// admissible scale-out (the paper's guard against over-trusting
    /// predicted speedups), so a larger-but-predicted-cheaper admissible
    /// scale-out of the same type is deliberately not chosen — it is
    /// still visible as `frontier[0]`, which is always the globally
    /// cheapest admissible grid point. `options` are the winning machine
    /// type's evaluated grid — the same data a single-type `configure`
    /// returns.
    pub choice: ConfigChoice,
    /// Every viable grid point (admissible when a deadline is set) across
    /// all evaluated types, ranked by cost — the §IV-B runtime/cost view
    /// over the whole catalog. Bottlenecked points are flagged, not
    /// hidden.
    pub frontier: Vec<FrontierEntry>,
    /// Per-machine-type outcome, in catalog order: evaluated,
    /// `insufficient_data`, or failed.
    pub types: Vec<TypeReport>,
}

/// Evaluate the full (machine type × scale-out) grid and pick the
/// cheapest admissible per-type configuration.
///
/// Per type, the pick is exactly [`super::select_scale_out`]'s (smallest
/// admissible scale-out under a deadline; cheapest non-bottlenecked
/// otherwise). Across types the winner is the documented reduction an
/// exhaustive per-type loop would apply: prefer non-bottlenecked picks,
/// then minimum cost (`total_cmp`), ties to the lexicographically
/// smaller machine-type name. See [`CatalogSearch::choice`] for why,
/// under a deadline, this can differ from the globally cheapest
/// admissible grid point (exposed as `frontier[0]`).
pub fn search_catalog<S: GridSource>(
    catalog: &Catalog,
    source: &mut S,
    input: &JobInput,
    goals: &UserGoals,
) -> crate::Result<CatalogSearch> {
    anyhow::ensure!(
        goals.confidence > 0.0 && goals.confidence < 1.0,
        "confidence must be in (0,1)"
    );
    anyhow::ensure!(!catalog.types().is_empty(), "catalog has no machine types to search");
    anyhow::ensure!(!catalog.scale_outs.is_empty(), "catalog offers no scale-outs");

    let rows = grid_rows(catalog, input);
    let mut types = Vec::with_capacity(catalog.types().len());
    for mt in catalog.types() {
        let runs = source.runs(&mt.name);
        let outcome = if runs < MIN_RUNS_PER_TYPE {
            TypeOutcome::InsufficientData { required: MIN_RUNS_PER_TYPE }
        } else {
            match source.predict_grid(&mt.name, &rows) {
                Err(e) => TypeOutcome::Failed { error: format!("{e:#}") },
                Ok(gp) if gp.runtimes.len() != rows.len() => TypeOutcome::Failed {
                    error: format!(
                        "grid prediction arity mismatch: {} runtimes for {} scale-outs",
                        gp.runtimes.len(),
                        rows.len()
                    ),
                },
                Ok(gp) => {
                    let options = build_options(
                        catalog,
                        mt,
                        &gp.runtimes,
                        input,
                        goals,
                        gp.resid_mu,
                        gp.resid_sigma,
                    );
                    let pick = pick_option(&options, goals).map(|o| o.scale_out);
                    TypeOutcome::Evaluated { model: gp.model, options, pick }
                }
            }
        };
        types.push(TypeReport { machine_type: mt.name.clone(), runs, outcome });
    }

    let (winner_type, winner_opt) = reduce(&types)
        .ok_or_else(|| no_search_winner_error(catalog, &types, input, goals))?;
    let options = match &winner_type.outcome {
        TypeOutcome::Evaluated { options, .. } => options.clone(),
        _ => unreachable!("winner comes from an evaluated type"),
    };
    let choice = ConfigChoice {
        machine_type: winner_type.machine_type.clone(),
        scale_out: winner_opt.scale_out,
        predicted_runtime_s: winner_opt.predicted_runtime_s,
        runtime_ucb_s: winner_opt.runtime_ucb_s,
        est_cost_usd: winner_opt.cost_usd,
        options,
    };
    let frontier = frontier(&types, goals);
    Ok(CatalogSearch { choice, frontier, types })
}

/// The cross-type reduction: among per-type picks, prefer
/// non-bottlenecked, then minimum cost, ties to the lexicographically
/// smaller name. Shared semantics with the parity tests' exhaustive loop.
fn reduce(types: &[TypeReport]) -> Option<(&TypeReport, &ScaleOutOption)> {
    let mut winner: Option<(&TypeReport, &ScaleOutOption)> = None;
    for tr in types {
        let TypeOutcome::Evaluated { options, pick: Some(s), .. } = &tr.outcome else {
            continue;
        };
        let Some(o) = options.iter().find(|o| o.scale_out == *s) else {
            continue;
        };
        let better = match winner {
            None => true,
            Some((wt, wo)) => match (o.bottleneck, wo.bottleneck) {
                (false, true) => true,
                (true, false) => false,
                _ => match o.cost_usd.total_cmp(&wo.cost_usd) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => tr.machine_type < wt.machine_type,
                },
            },
        };
        if better {
            winner = Some((tr, o));
        }
    }
    winner
}

/// The cost-ranked §IV-B view across every evaluated type: viable grid
/// points, admissible ones only when a deadline is set.
fn frontier(types: &[TypeReport], goals: &UserGoals) -> Vec<FrontierEntry> {
    let mut out = Vec::new();
    for tr in types {
        let TypeOutcome::Evaluated { options, .. } = &tr.outcome else {
            continue;
        };
        for o in options {
            if !viable(o) || (goals.deadline_s.is_some() && o.admissible != Some(true)) {
                continue;
            }
            out.push(FrontierEntry {
                machine_type: tr.machine_type.clone(),
                scale_out: o.scale_out,
                predicted_runtime_s: o.predicted_runtime_s,
                runtime_ucb_s: o.runtime_ucb_s,
                cost_usd: o.cost_usd,
                bottleneck: o.bottleneck,
            });
        }
    }
    out.sort_by(|a, b| {
        a.cost_usd
            .total_cmp(&b.cost_usd)
            .then_with(|| a.machine_type.cmp(&b.machine_type))
            .then_with(|| a.scale_out.cmp(&b.scale_out))
    });
    out
}

/// Structured whole-search failure: says *why* per machine type, so a
/// deadline-impossible grid and a data-starved repository read
/// differently on the wire.
fn no_search_winner_error(
    catalog: &Catalog,
    types: &[TypeReport],
    input: &JobInput,
    goals: &UserGoals,
) -> anyhow::Error {
    let mut evaluated = 0usize;
    let mut insufficient = 0usize;
    let mut failed = 0usize;
    for tr in types {
        match tr.outcome {
            TypeOutcome::Evaluated { .. } => evaluated += 1,
            TypeOutcome::InsufficientData { .. } => insufficient += 1,
            TypeOutcome::Failed { .. } => failed += 1,
        }
    }
    if evaluated == 0 {
        return anyhow::Error::new(NoTypesEvaluated).context(format!(
            "no machine type could be evaluated for {}: {insufficient} below the \
             {MIN_RUNS_PER_TYPE}-run data floor, {failed} failed to fit",
            input.job
        ));
    }
    // Some types were evaluated, so the first no-pick reason explains the
    // grid-wide failure (degenerate predictions or an impossible deadline).
    for tr in types {
        if let TypeOutcome::Evaluated { options, pick: None, .. } = &tr.outcome {
            return no_pick_error(options, &tr.machine_type, catalog, goals)
                .context(format!("{} evaluated type(s), none admissible", evaluated));
        }
    }
    anyhow::anyhow!("no admissible configuration across {} evaluated type(s)", evaluated)
}

/// Local-mode [`GridSource`]: fits one predictor per machine type from a
/// shared columnar view, each fit on the given engine (`--fit-threads` /
/// `--fit-budget` apply per fit).
pub struct FitGridSource<'a> {
    view: &'a FeatureMatrix,
    backend: Arc<dyn FitBackend>,
    engine: FitEngine,
}

impl<'a> FitGridSource<'a> {
    pub fn new(view: &'a FeatureMatrix, backend: Arc<dyn FitBackend>, engine: FitEngine) -> Self {
        FitGridSource { view, backend, engine }
    }
}

impl GridSource for FitGridSource<'_> {
    fn runs(&self, machine_type: &str) -> usize {
        self.view.rows(machine_type)
    }

    fn predict_grid(
        &mut self,
        machine_type: &str,
        rows: &[Vec<f64>],
    ) -> crate::Result<GridPrediction> {
        let (predictor, report) =
            fit_prepared_with(self.view, machine_type, self.backend.clone(), &self.engine)?;
        let runtimes = rows
            .iter()
            .map(|row| predictor.predict_one(row))
            .collect::<crate::Result<Vec<f64>>>()?;
        Ok(GridPrediction {
            model: report.chosen,
            resid_mu: report.chosen_score.resid_mean,
            resid_sigma: report.chosen_score.resid_std,
            runtimes,
        })
    }
}

/// End-to-end local catalog search: build the columnar view once, fit
/// each sufficiently-covered machine type, pick the cost-optimal
/// admissible configuration (`c3o configure --search-catalog`).
pub fn configure_search(
    catalog: &Catalog,
    shared: &Dataset,
    input: &JobInput,
    goals: &UserGoals,
    backend: Arc<dyn FitBackend>,
    engine: &FitEngine,
) -> crate::Result<CatalogSearch> {
    let view = shared.feature_view();
    let mut source = FitGridSource::new(&view, backend, engine.clone());
    search_catalog(catalog, &mut source, input, goals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configurator::select_scale_out;
    use crate::data::JobKind;
    use crate::runtime::NativeBackend;
    use crate::sim::{generate_job, GeneratorConfig};

    fn backend() -> Arc<dyn FitBackend> {
        Arc::new(NativeBackend::new())
    }

    fn try_search(
        catalog: &Catalog,
        shared: &Dataset,
        input: &JobInput,
        goals: &UserGoals,
    ) -> crate::Result<CatalogSearch> {
        configure_search(catalog, shared, input, goals, backend(), &FitEngine::serial())
    }

    /// The reduction the parity tests apply over an exhaustive
    /// per-type `select_scale_out` loop — written independently of
    /// `reduce` on purpose.
    fn exhaustive_loop(
        catalog: &Catalog,
        shared: &Dataset,
        input: &JobInput,
        goals: &UserGoals,
    ) -> Option<ConfigChoice> {
        let view = shared.feature_view();
        let mut best: Option<ConfigChoice> = None;
        for mt in catalog.types() {
            if view.rows(&mt.name) < MIN_RUNS_PER_TYPE {
                continue;
            }
            let (predictor, report) =
                fit_prepared_with(&view, &mt.name, backend(), &FitEngine::serial()).unwrap();
            let Ok(choice) = select_scale_out(
                catalog,
                &mt.name,
                &predictor,
                input,
                goals,
                report.chosen_score.resid_mean,
                report.chosen_score.resid_std,
            ) else {
                continue;
            };
            let chosen_bottleneck = choice
                .options
                .iter()
                .find(|o| o.scale_out == choice.scale_out)
                .unwrap()
                .bottleneck;
            let better = match &best {
                None => true,
                Some(b) => {
                    let b_bottleneck = b
                        .options
                        .iter()
                        .find(|o| o.scale_out == b.scale_out)
                        .unwrap()
                        .bottleneck;
                    match (chosen_bottleneck, b_bottleneck) {
                        (false, true) => true,
                        (true, false) => false,
                        _ => match choice.est_cost_usd.total_cmp(&b.est_cost_usd) {
                            std::cmp::Ordering::Less => true,
                            std::cmp::Ordering::Greater => false,
                            std::cmp::Ordering::Equal => choice.machine_type < b.machine_type,
                        },
                    }
                }
            };
            if better {
                best = Some(choice);
            }
        }
        best
    }

    #[test]
    fn grid_search_matches_exhaustive_per_type_loop_bit_identically() {
        let catalog = Catalog::aws_like();
        let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        let input = JobInput::new(JobKind::Sort, 15.0, vec![]);
        for goals in [
            UserGoals { deadline_s: Some(900.0), confidence: 0.95 },
            UserGoals { deadline_s: None, confidence: 0.95 },
        ] {
            let search = try_search(&catalog, &shared, &input, &goals).unwrap();
            let exhaustive = exhaustive_loop(&catalog, &shared, &input, &goals).unwrap();
            assert_eq!(search.choice.machine_type, exhaustive.machine_type);
            assert_eq!(search.choice.scale_out, exhaustive.scale_out);
            assert_eq!(
                search.choice.predicted_runtime_s.to_bits(),
                exhaustive.predicted_runtime_s.to_bits()
            );
            assert_eq!(search.choice.runtime_ucb_s.to_bits(), exhaustive.runtime_ucb_s.to_bits());
            assert_eq!(search.choice.est_cost_usd.to_bits(), exhaustive.est_cost_usd.to_bits());
            for (a, b) in search.choice.options.iter().zip(&exhaustive.options) {
                assert_eq!(a.scale_out, b.scale_out);
                assert_eq!(a.predicted_runtime_s.to_bits(), b.predicted_runtime_s.to_bits());
                assert_eq!(a.cost_usd.to_bits(), b.cost_usd.to_bits());
                assert_eq!(a.bottleneck, b.bottleneck);
                assert_eq!(a.admissible, b.admissible);
            }
        }
    }

    #[test]
    fn insufficient_types_reported_not_skipped() {
        let catalog = Catalog::aws_like();
        // The default corpus only covers m5.xlarge and c5.xlarge.
        let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        let input = JobInput::new(JobKind::Sort, 15.0, vec![]);
        let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
        let search = try_search(&catalog, &shared, &input, &goals).unwrap();
        assert_eq!(search.types.len(), catalog.types().len(), "every type is reported");
        let mut evaluated = 0;
        let mut insufficient = 0;
        for tr in &search.types {
            match &tr.outcome {
                TypeOutcome::Evaluated { options, .. } => {
                    evaluated += 1;
                    assert_eq!(options.len(), catalog.scale_outs.len());
                }
                TypeOutcome::InsufficientData { required } => {
                    insufficient += 1;
                    assert_eq!(*required, MIN_RUNS_PER_TYPE);
                    assert!(tr.runs < MIN_RUNS_PER_TYPE);
                }
                TypeOutcome::Failed { error } => panic!("{}: {error}", tr.machine_type),
            }
        }
        assert_eq!(evaluated, 2);
        assert_eq!(insufficient, catalog.types().len() - 2);
    }

    #[test]
    fn frontier_is_cost_ranked_and_admissible() {
        let catalog = Catalog::aws_like();
        let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        let input = JobInput::new(JobKind::Sort, 15.0, vec![]);
        let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
        let search = try_search(&catalog, &shared, &input, &goals).unwrap();
        assert!(!search.frontier.is_empty());
        for w in search.frontier.windows(2) {
            assert!(w[0].cost_usd <= w[1].cost_usd, "frontier must be cost-ranked");
        }
        for f in &search.frontier {
            assert!(f.predicted_runtime_s > 0.0 && f.runtime_ucb_s <= 900.0);
        }
        // The winner is itself a frontier point, so it can never beat the
        // frontier's cheapest entry.
        assert!(search.choice.est_cost_usd >= search.frontier[0].cost_usd - 1e-12);
    }

    #[test]
    fn empty_catalog_and_empty_data_are_structured_errors() {
        let catalog = Catalog::aws_like();
        let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        let input = JobInput::new(JobKind::Sort, 15.0, vec![]);
        let goals = UserGoals::default();

        let empty = Catalog::custom(vec![], 0.0, vec![]);
        let err = try_search(&empty, &shared, &input, &goals).unwrap_err();
        assert!(err.to_string().contains("no machine types"), "{err:#}");

        let no_data = Dataset::new(JobKind::Sort);
        let err = try_search(&catalog, &no_data, &input, &goals).unwrap_err();
        assert!(err.to_string().contains("data floor"), "{err:#}");
        assert!(
            err.downcast_ref::<NoTypesEvaluated>().is_some(),
            "zero-types-evaluated must be detectable for error-code mapping"
        );
    }

    #[test]
    fn impossible_deadline_is_structured_error() {
        let catalog = Catalog::aws_like();
        let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        let input = JobInput::new(JobKind::Sort, 15.0, vec![]);
        let goals = UserGoals { deadline_s: Some(1.0), confidence: 0.95 };
        let err = try_search(&catalog, &shared, &input, &goals).unwrap_err();
        assert!(err.to_string().contains("none admissible"), "{err:#}");
    }

    #[test]
    fn failed_type_does_not_abort_the_grid() {
        struct HalfBroken<'a> {
            inner: FitGridSource<'a>,
        }
        impl GridSource for HalfBroken<'_> {
            fn runs(&self, machine_type: &str) -> usize {
                self.inner.runs(machine_type)
            }
            fn predict_grid(
                &mut self,
                machine_type: &str,
                rows: &[Vec<f64>],
            ) -> crate::Result<GridPrediction> {
                anyhow::ensure!(machine_type != "c5.xlarge", "injected c5 failure");
                self.inner.predict_grid(machine_type, rows)
            }
        }
        let catalog = Catalog::aws_like();
        let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
        let view = shared.feature_view();
        let mut source =
            HalfBroken { inner: FitGridSource::new(&view, backend(), FitEngine::serial()) };
        let input = JobInput::new(JobKind::Sort, 15.0, vec![]);
        let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
        let search = search_catalog(&catalog, &mut source, &input, &goals).unwrap();
        assert_eq!(search.choice.machine_type, "m5.xlarge");
        let c5 = search.types.iter().find(|t| t.machine_type == "c5.xlarge").unwrap();
        match &c5.outcome {
            TypeOutcome::Failed { error } => assert!(error.contains("injected"), "{error}"),
            other => panic!("expected Failed, got {other:?}"),
        }
    }
}
