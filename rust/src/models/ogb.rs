//! Optimistic Gradient Boosting (paper §V-B): the optimistic SSM × IBM
//! decomposition with gradient boosting for *both* submodels.
//!
//! The SSM-GBM learns runtime-vs-scale-out on the largest shared-context
//! group; projections and recombination are identical to the BOM, but both
//! stages are non-parametric, which keeps the local-data accuracy of the
//! optimistic approach while tolerating mild non-linearity in the inputs
//! behaviour.

use std::collections::HashMap;

use crate::linalg::Matrix;

use super::bom::largest_scaleout_group;
use super::features::ibm_features;
use super::gbm::{Gbm, GbmParams};
use super::{RuntimeModel, TrainData};

const SPEEDUP_FLOOR: f64 = 0.02;

/// Optimistic Gradient Boosting model.
pub struct Ogb {
    params: GbmParams,
    ssm: Option<Gbm>,
    ibm: Option<Gbm>,
    /// SSM prediction at scale-out 1 (normalization constant).
    ssm_base: f64,
}

impl Ogb {
    pub fn new(params: GbmParams) -> Self {
        Ogb { params, ssm: None, ibm: None, ssm_base: 1.0 }
    }

    pub fn with_defaults() -> Self {
        // Fewer, shallower stages than the plain GBM: each submodel sees a
        // low-dimensional problem.
        Ogb::new(GbmParams { n_estimators: 80, max_depth: 2, ..Default::default() })
    }

    fn speedup(&self, s: f64) -> f64 {
        let ssm = self.ssm.as_ref().expect("fitted");
        let v = ssm.predict_one(&[s]).expect("ssm fitted");
        if self.ssm_base.abs() < 1e-9 {
            return SPEEDUP_FLOOR;
        }
        (v / self.ssm_base).max(SPEEDUP_FLOOR)
    }
}

impl RuntimeModel for Ogb {
    fn name(&self) -> &'static str {
        "OGB"
    }

    fn fit(&mut self, data: &TrainData) -> crate::Result<()> {
        anyhow::ensure!(data.len() >= 2, "OGB needs >= 2 training points");

        // --- SSM-GBM on the pooled normalized shared-context groups.
        let pts = super::bom::pooled_ssm_points(data);
        let ssm_rows: Vec<Vec<f64>> = pts.iter().map(|&(s, _)| vec![s]).collect();
        let ssm_y: Vec<f64> = pts.iter().map(|&(_, t)| t).collect();
        let mut ssm = Gbm::new(self.params);
        ssm.fit(&TrainData::new(Matrix::from_rows(&ssm_rows)?, ssm_y)?)?;
        self.ssm_base = ssm.predict_one(&[1.0])?;
        self.ssm = Some(ssm);

        // --- Project to scale-out 1, fit IBM-GBM on non-scale-out features.
        let ibm_rows: Vec<Vec<f64>> = (0..data.len())
            .map(|i| ibm_features(data.x.row(i))[1..].to_vec()) // drop the 1-intercept
            .collect();
        let t1: Vec<f64> = (0..data.len())
            .map(|i| data.y[i] / self.speedup(data.x.row(i)[0]))
            .collect();
        let mut ibm = Gbm::new(self.params);
        ibm.fit(&TrainData::new(Matrix::from_rows(&ibm_rows)?, t1)?)?;
        self.ibm = Some(ibm);
        Ok(())
    }

    fn predict_one(&self, features: &[f64]) -> crate::Result<f64> {
        // Fitted-state audit (cf. the Gbm `fitted` flag): like the BOM,
        // the Option-typed `ibm` is set last in `fit` and is an explicit
        // flag — no value-based fitted-ness inference here.
        let ibm = self.ibm.as_ref().ok_or_else(|| anyhow::anyhow!("OGB not fitted"))?;
        let base = ibm.predict_one(&ibm_features(features)[1..])?;
        Ok(base * self.speedup(features[0]))
    }

    /// Uses the default per-row LOO loop — the fit-path engine may fan
    /// the rows out as independent tasks.
    fn loo_splits_independent(&self) -> bool {
        true
    }

    fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
        Box::new(Ogb::new(self.params))
    }
}

/// Group count diagnostic (used by tests and the eval harness to
/// characterize training sets).
pub fn context_group_count(data: &TrainData) -> usize {
    let mut set: HashMap<Vec<u64>, ()> = HashMap::new();
    for i in 0..data.len() {
        let key: Vec<u64> = data.x.row(i)[1..].iter().map(|f| f.to_bits()).collect();
        set.insert(key, ());
    }
    set.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;
    use crate::util::stats::mape;

    fn separable_world(n: usize, seed: u64) -> TrainData {
        let mut rng = Pcg::seed(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let s = rng.range(2, 13) as f64;
            let (d, k) = if i % 3 == 0 {
                (20.0, 5.0)
            } else {
                (rng.range_f64(10.0, 30.0), rng.range(3, 10) as f64)
            };
            rows.push(vec![s, d, k]);
            let g = 1.0 / s + 0.02 * s;
            // Mildly non-linear inputs behaviour (GBM-friendly).
            let h = 10.0 + 4.0 * d + 9.0 * k + 0.15 * d * k;
            y.push(g * h);
        }
        TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    #[test]
    fn fits_separable_nonlinear_world() {
        let data = separable_world(150, 1);
        let mut m = Ogb::with_defaults();
        m.fit(&data).unwrap();
        let err = mape(&m.predict(&data.x).unwrap(), &data.y);
        assert!(err < 8.0, "in-sample MAPE {err}%");
    }

    #[test]
    fn interpolates_new_scaleout_within_range() {
        let data = separable_world(150, 2);
        let mut m = Ogb::with_defaults();
        m.fit(&data).unwrap();
        // Known context at an interior scale-out.
        let truth = (1.0 / 7.0 + 0.02 * 7.0) * (10.0 + 4.0 * 20.0 + 9.0 * 5.0 + 0.15 * 20.0 * 5.0);
        let p = m.predict_one(&[7.0, 20.0, 5.0]).unwrap();
        assert!((p / truth - 1.0).abs() < 0.25, "p={p} truth={truth}");
    }

    #[test]
    fn deterministic() {
        let data = separable_world(100, 3);
        let mut a = Ogb::with_defaults();
        let mut b = Ogb::with_defaults();
        a.fit(&data).unwrap();
        b.fit(&data).unwrap();
        let q = [5.0, 18.0, 6.0];
        assert_eq!(a.predict_one(&q).unwrap(), b.predict_one(&q).unwrap());
    }

    #[test]
    fn context_group_count_counts() {
        let data = separable_world(90, 4);
        assert!(context_group_count(&data) > 10);
    }

    #[test]
    fn unfitted_errors() {
        assert!(Ogb::with_defaults().predict_one(&[2.0, 10.0, 3.0]).is_err());
    }
}
