//! Gradient-boosted regression trees (paper §V-A): the general model that
//! "can succeed almost regardless of feature-dimensionality and
//! interdependence of features" and shines on global/collaborative data.
//!
//! Squared loss, shrinkage, optional row subsampling — functionally the
//! scikit-learn `GradientBoostingRegressor` the paper's prototype used.

use crate::util::prng::Pcg;

use super::tree::{RegressionTree, TreeParams};
use super::{RuntimeModel, TrainData};

/// GBM hyper-parameters (defaults mirror sklearn's).
#[derive(Debug, Clone, Copy)]
pub struct GbmParams {
    pub n_estimators: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Row subsample fraction per stage (1.0 = none).
    pub subsample: f64,
    pub seed: u64,
}

impl Default for GbmParams {
    fn default() -> Self {
        GbmParams {
            n_estimators: 100,
            learning_rate: 0.1,
            max_depth: 3,
            min_samples_leaf: 1,
            subsample: 1.0,
            seed: 0x6B,
        }
    }
}

/// Gradient boosting machine.
pub struct Gbm {
    params: GbmParams,
    base: f64,
    stages: Vec<RegressionTree>,
    /// Explicit fitted flag. Inferring fitted-ness from the learned state
    /// (`!stages.is_empty() || base != 0.0`) misreported a model trained
    /// on zero-mean targets with `n_estimators: 0` as unfitted.
    fitted: bool,
}

impl Gbm {
    pub fn new(params: GbmParams) -> Self {
        Gbm { params, base: 0.0, stages: Vec::new(), fitted: false }
    }

    pub fn with_defaults() -> Self {
        Gbm::new(GbmParams::default())
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Raw prediction for a feature row.
    fn raw_predict(&self, row: &[f64]) -> f64 {
        let mut v = self.base;
        for t in &self.stages {
            v += self.params.learning_rate * t.predict_one(row);
        }
        v
    }
}

impl RuntimeModel for Gbm {
    fn name(&self) -> &'static str {
        "GBM"
    }

    fn fit(&mut self, data: &TrainData) -> crate::Result<()> {
        anyhow::ensure!(!data.is_empty(), "GBM needs training data");
        let n = data.len();
        self.base = data.y.iter().sum::<f64>() / n as f64;
        self.stages.clear();

        let tree_params = TreeParams {
            max_depth: self.params.max_depth,
            min_samples_leaf: self.params.min_samples_leaf,
        };
        let mut rng = Pcg::seed(self.params.seed);
        // Feature orders depend only on x: sort once, reuse for all 100
        // stages (§Perf: this removes the dominant n·log n term from the
        // boosting loop; see EXPERIMENTS.md).
        let full_idx: Vec<usize> = (0..n).collect();
        let master_sorted = RegressionTree::sort_features(&data.x, &full_idx);
        // Current predictions on the training set (incremental — avoids
        // O(stages^2) re-evaluation).
        let mut current = vec![self.base; n];
        let mut residuals = vec![0.0; n];
        let mut in_sample = vec![true; n];
        for _ in 0..self.params.n_estimators {
            for i in 0..n {
                residuals[i] = data.y[i] - current[i];
            }
            let tree = if self.params.subsample < 1.0 {
                let k = ((n as f64 * self.params.subsample).round() as usize).max(1);
                let idx = rng.sample_indices(n, k);
                in_sample.fill(false);
                for &i in &idx {
                    in_sample[i] = true;
                }
                // Stable-filter the master orders: keeps them sorted.
                let sorted: Vec<Vec<usize>> = master_sorted
                    .iter()
                    .map(|o| o.iter().copied().filter(|&i| in_sample[i]).collect())
                    .collect();
                RegressionTree::fit_presorted(&data.x, &residuals, sorted, tree_params)
            } else {
                RegressionTree::fit_presorted(
                    &data.x,
                    &residuals,
                    master_sorted.clone(),
                    tree_params,
                )
            };
            for i in 0..n {
                current[i] += self.params.learning_rate * tree.predict_one(data.x.row(i));
            }
            self.stages.push(tree);
        }
        self.fitted = true;
        Ok(())
    }

    fn predict_one(&self, features: &[f64]) -> crate::Result<f64> {
        anyhow::ensure!(self.fitted, "GBM not fitted");
        Ok(self.raw_predict(features))
    }

    /// Uses the default per-row LOO loop — the fit-path engine may fan
    /// the rows out as independent tasks.
    fn loo_splits_independent(&self) -> bool {
        true
    }

    fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
        Box::new(Gbm::new(self.params))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::stats::mape;

    fn nonlinear_world(n: usize, seed: u64) -> TrainData {
        let mut rng = Pcg::seed(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let s = rng.range(2, 13) as f64;
            let d = rng.range_f64(10.0, 30.0);
            let k = rng.range(3, 10) as f64;
            rows.push(vec![s, d, k]);
            // Non-linear with an interaction — linear models fail here.
            y.push(30.0 + 8.0 * d * k / s + 3.0 * s.ln());
        }
        TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    #[test]
    fn fits_nonlinear_interaction() {
        let data = nonlinear_world(200, 1);
        let mut m = Gbm::with_defaults();
        m.fit(&data).unwrap();
        let preds = m.predict(&data.x).unwrap();
        let err = mape(&preds, &data.y);
        assert!(err < 3.0, "in-sample MAPE {err}%");
    }

    #[test]
    fn generalizes_within_range() {
        let train = nonlinear_world(300, 2);
        let test = nonlinear_world(50, 3);
        let mut m = Gbm::with_defaults();
        m.fit(&train).unwrap();
        let preds = m.predict(&test.x).unwrap();
        let err = mape(&preds, &test.y);
        assert!(err < 12.0, "held-out MAPE {err}%");
    }

    #[test]
    fn poor_extrapolation_is_expected() {
        // §VI-D: "decreased effectiveness in large extrapolations, which is
        // typical for tree-based models" — the GBM must plateau outside
        // the training range rather than follow the trend.
        let train = nonlinear_world(300, 4);
        let mut m = Gbm::with_defaults();
        m.fit(&train).unwrap();
        let p_known = m.predict_one(&[6.0, 20.0, 5.0]).unwrap();
        let p_far = m.predict_one(&[6.0, 200.0, 5.0]).unwrap(); // 10x size
        let truth_far = 30.0 + 8.0 * 200.0 * 5.0 / 6.0 + 3.0 * 6.0f64.ln();
        assert!(p_far < 0.6 * truth_far, "tree extrapolated: {p_far} vs {truth_far}");
        assert!(p_far >= 0.5 * p_known);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = nonlinear_world(100, 5);
        let mut a = Gbm::with_defaults();
        let mut b = Gbm::with_defaults();
        a.fit(&data).unwrap();
        b.fit(&data).unwrap();
        let q = [5.0, 17.0, 4.0];
        assert_eq!(a.predict_one(&q).unwrap(), b.predict_one(&q).unwrap());
    }

    #[test]
    fn subsample_still_converges() {
        let data = nonlinear_world(200, 6);
        let mut m = Gbm::new(GbmParams { subsample: 0.7, ..Default::default() });
        m.fit(&data).unwrap();
        let err = mape(&m.predict(&data.x).unwrap(), &data.y);
        assert!(err < 6.0, "subsampled in-sample MAPE {err}%");
    }

    #[test]
    fn single_point_predicts_its_value() {
        let data = TrainData::new(
            Matrix::from_rows(&[vec![4.0, 10.0]]).unwrap(),
            vec![123.0],
        )
        .unwrap();
        let mut m = Gbm::with_defaults();
        m.fit(&data).unwrap();
        assert!((m.predict_one(&[8.0, 20.0]).unwrap() - 123.0).abs() < 1e-9);
    }

    #[test]
    fn more_stages_reduce_training_error() {
        let data = nonlinear_world(150, 7);
        let mut small = Gbm::new(GbmParams { n_estimators: 5, ..Default::default() });
        let mut large = Gbm::new(GbmParams { n_estimators: 200, ..Default::default() });
        small.fit(&data).unwrap();
        large.fit(&data).unwrap();
        let e_small = mape(&small.predict(&data.x).unwrap(), &data.y);
        let e_large = mape(&large.predict(&data.x).unwrap(), &data.y);
        assert!(e_large < e_small);
    }

    #[test]
    fn unfitted_errors() {
        assert!(Gbm::with_defaults().predict_one(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn zero_mean_targets_with_zero_stages_count_as_fitted() {
        // Regression: the old `!stages.is_empty() || base != 0.0` check
        // called this legitimately fitted model "not fitted".
        let data = TrainData::new(
            Matrix::from_rows(&[vec![2.0, 1.0], vec![4.0, 1.0]]).unwrap(),
            vec![-5.0, 5.0],
        )
        .unwrap();
        let mut m = Gbm::new(GbmParams { n_estimators: 0, ..Default::default() });
        m.fit(&data).unwrap();
        assert_eq!(m.predict_one(&[3.0, 1.0]).unwrap(), 0.0);
        assert_eq!(m.n_stages(), 0);
    }
}
