//! The C3O predictor (paper §V-C): dynamic model selection.
//!
//! Candidates are the system's constituent models — GBM, BOM, OGB (plus
//! any maintainer-supplied custom models registered through
//! [`C3oPredictor::add_candidate`]). On every (re)fit the predictor
//! cross-validates all candidates on the current training data, picks the
//! one with the lowest held-out MAPE, refits it on everything, and records
//! the residual distribution (μ, σ) the configurator's confidence rule
//! needs.
//!
//! LOO is used up to [`C3oPredictor::loo_cap`] training points, k-fold
//! beyond — the §VI-C "cap the model selection phase" provision.

use std::sync::Arc;

use crate::cv::{self, CvScore};
use crate::runtime::FitBackend;

use super::bom::Bom;
use super::gbm::{Gbm, GbmParams};
use super::ogb::Ogb;
use super::{RuntimeModel, TrainData};

/// Outcome of one model-selection pass.
#[derive(Debug, Clone)]
pub struct SelectionReport {
    /// Candidate name → CV score, in candidate order.
    pub scores: Vec<(String, CvScore)>,
    /// Winner name.
    pub chosen: String,
    /// Winner's CV score (μ, σ feed the configurator).
    pub chosen_score: CvScore,
}

/// The C3O runtime predictor.
pub struct C3oPredictor {
    candidates: Vec<Box<dyn RuntimeModel>>,
    fitted: Option<Box<dyn RuntimeModel>>,
    report: Option<SelectionReport>,
    /// Above this size, selection switches from LOO to k-fold.
    pub loo_cap: usize,
    pub kfold_k: usize,
    seed: u64,
}

impl C3oPredictor {
    /// Default candidate set (paper §V): GBM, BOM, OGB.
    pub fn new(backend: Arc<dyn FitBackend>) -> Self {
        let candidates: Vec<Box<dyn RuntimeModel>> = vec![
            Box::new(Gbm::new(GbmParams::default())),
            Box::new(Bom::new(backend.clone())),
            Box::new(Ogb::with_defaults()),
        ];
        C3oPredictor {
            candidates,
            fitted: None,
            report: None,
            loo_cap: 120,
            kfold_k: 10,
            seed: 0xC30,
        }
    }

    /// Register a maintainer-supplied custom model (§III-C-c: custom models
    /// share the common model API — [`RuntimeModel`]).
    pub fn add_candidate(&mut self, model: Box<dyn RuntimeModel>) {
        self.candidates.push(model);
    }

    pub fn candidate_names(&self) -> Vec<&'static str> {
        self.candidates.iter().map(|c| c.name()).collect()
    }

    /// Cross-validate one candidate under the size-capped policy.
    fn cv_one(&self, m: &dyn RuntimeModel, data: &TrainData) -> crate::Result<CvScore> {
        if data.len() <= self.loo_cap {
            cv::loo_score(m, data)
        } else {
            cv::kfold_score(m, data, self.kfold_k, self.seed)
        }
    }

    /// Fit = select (CV all candidates) + refit the winner on all data.
    pub fn fit(&mut self, data: &TrainData) -> crate::Result<SelectionReport> {
        anyhow::ensure!(data.len() >= 3, "C3O needs >= 3 training points");
        let mut scores = Vec::with_capacity(self.candidates.len());
        for c in &self.candidates {
            let mut scratch = c.clone_unfitted();
            // Candidates must be fitted once before LOO default paths that
            // clone; fit errors for a candidate disqualify it rather than
            // abort selection (a custom model may need more data).
            let score = match scratch.fit(data) {
                Ok(()) => self.cv_one(scratch.as_ref(), data),
                Err(e) => Err(e),
            };
            match score {
                Ok(s) => scores.push((c.name().to_string(), s)),
                Err(_) => scores.push((
                    c.name().to_string(),
                    CvScore {
                        mape: f64::INFINITY,
                        resid_mean: 0.0,
                        resid_std: f64::INFINITY,
                        n: 0,
                    },
                )),
            }
        }
        let (best_idx, _) = scores
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.1.mape.partial_cmp(&b.1.mape).unwrap())
            .expect("non-empty candidates");
        anyhow::ensure!(
            scores[best_idx].1.mape.is_finite(),
            "no candidate model could be cross-validated"
        );

        let mut winner = self.candidates[best_idx].clone_unfitted();
        winner.fit(data)?;
        let report = SelectionReport {
            chosen: scores[best_idx].0.clone(),
            chosen_score: scores[best_idx].1.clone(),
            scores,
        };
        self.fitted = Some(winner);
        self.report = Some(report.clone());
        Ok(report)
    }

    /// Predict a runtime for `[scale_out, data_size, ctx...]`.
    pub fn predict_one(&self, features: &[f64]) -> crate::Result<f64> {
        self.fitted
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("C3O predictor not fitted"))?
            .predict_one(features)
    }

    /// The last selection report (None before the first fit).
    pub fn report(&self) -> Option<&SelectionReport> {
        self.report.as_ref()
    }

    /// Residual distribution of the chosen model: (μ, σ) for §IV-B.
    pub fn error_distribution(&self) -> Option<(f64, f64)> {
        self.report.as_ref().map(|r| (r.chosen_score.resid_mean, r.chosen_score.resid_std))
    }
}

impl RuntimeModel for C3oPredictor {
    fn name(&self) -> &'static str {
        "C3O"
    }

    fn fit(&mut self, data: &TrainData) -> crate::Result<()> {
        C3oPredictor::fit(self, data).map(|_| ())
    }

    fn predict_one(&self, features: &[f64]) -> crate::Result<f64> {
        C3oPredictor::predict_one(self, features)
    }

    fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
        Box::new(C3oPredictor {
            candidates: self.candidates.iter().map(|c| c.clone_unfitted()).collect(),
            fitted: None,
            report: None,
            loo_cap: self.loo_cap,
            kfold_k: self.kfold_k,
            seed: self.seed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::runtime::NativeBackend;
    use crate::util::prng::Pcg;

    fn predictor() -> C3oPredictor {
        C3oPredictor::new(Arc::new(NativeBackend::new()))
    }

    fn separable_world(n: usize, seed: u64) -> TrainData {
        let mut rng = Pcg::seed(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let s = rng.range(2, 13) as f64;
            let (d, k) = if i % 3 == 0 {
                (20.0, 5.0)
            } else {
                (rng.range_f64(10.0, 30.0), rng.range(3, 10) as f64)
            };
            rows.push(vec![s, d, k]);
            y.push((1.0 / s + 0.02 * s) * (10.0 + 4.0 * d + 9.0 * k)
                * (1.0 + 0.02 * rng.normal()));
        }
        TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    #[test]
    fn selects_and_predicts() {
        let data = separable_world(60, 1);
        let mut p = predictor();
        let report = p.fit(&data).unwrap();
        assert_eq!(report.scores.len(), 3);
        assert!(["GBM", "BOM", "OGB"].contains(&report.chosen.as_str()));
        let pred = p.predict_one(&[6.0, 20.0, 5.0]).unwrap();
        assert!(pred > 0.0);
    }

    #[test]
    fn chosen_has_lowest_cv_mape() {
        let data = separable_world(50, 2);
        let mut p = predictor();
        let report = p.fit(&data).unwrap();
        let min = report
            .scores
            .iter()
            .map(|(_, s)| s.mape)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(report.chosen_score.mape, min);
    }

    #[test]
    fn error_distribution_available_after_fit() {
        let data = separable_world(40, 3);
        let mut p = predictor();
        assert!(p.error_distribution().is_none());
        p.fit(&data).unwrap();
        let (_, sigma) = p.error_distribution().unwrap();
        assert!(sigma >= 0.0);
    }

    #[test]
    fn custom_candidate_can_win() {
        // An oracle model that knows the world exactly must be selected.
        struct Oracle;
        impl RuntimeModel for Oracle {
            fn name(&self) -> &'static str {
                "Oracle"
            }
            fn fit(&mut self, _d: &TrainData) -> crate::Result<()> {
                Ok(())
            }
            fn predict_one(&self, f: &[f64]) -> crate::Result<f64> {
                Ok((1.0 / f[0] + 0.02 * f[0]) * (10.0 + 4.0 * f[1] + 9.0 * f[2]))
            }
            fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
                Box::new(Oracle)
            }
        }
        let data = separable_world(40, 4);
        let mut p = predictor();
        p.add_candidate(Box::new(Oracle));
        let report = p.fit(&data).unwrap();
        assert_eq!(report.chosen, "Oracle");
    }

    #[test]
    fn failing_candidate_disqualified_not_fatal() {
        struct Broken;
        impl RuntimeModel for Broken {
            fn name(&self) -> &'static str {
                "Broken"
            }
            fn fit(&mut self, _d: &TrainData) -> crate::Result<()> {
                anyhow::bail!("nope")
            }
            fn predict_one(&self, _f: &[f64]) -> crate::Result<f64> {
                anyhow::bail!("nope")
            }
            fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
                Box::new(Broken)
            }
        }
        let data = separable_world(40, 5);
        let mut p = predictor();
        p.add_candidate(Box::new(Broken));
        let report = p.fit(&data).unwrap();
        assert_ne!(report.chosen, "Broken");
    }

    #[test]
    fn too_little_data_rejected() {
        let data = separable_world(2, 6);
        assert!(predictor().fit(&data).is_err());
    }

    #[test]
    fn works_at_fig5_minimum_of_three_points() {
        // Fig. 5's smallest training size is 3; selection must not crash.
        let data = separable_world(3, 8);
        let mut p = predictor();
        p.fit(&data).unwrap();
        assert!(p.predict_one(&[6.0, 20.0, 5.0]).unwrap().is_finite());
    }

    #[test]
    fn kfold_used_above_cap() {
        let data = separable_world(140, 7);
        let mut p = predictor();
        p.loo_cap = 100;
        let report = p.fit(&data).unwrap();
        assert!(report.chosen_score.n == 140);
    }
}
