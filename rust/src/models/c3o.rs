//! The C3O predictor (paper §V-C): dynamic model selection.
//!
//! Candidates are the system's constituent models — GBM, BOM, OGB (plus
//! any maintainer-supplied custom models registered through
//! [`C3oPredictor::add_candidate`]). On every (re)fit the predictor
//! cross-validates all candidates on the current training data, picks the
//! one with the lowest held-out MAPE, refits it on everything, and records
//! the residual distribution (μ, σ) the configurator's confidence rule
//! needs.
//!
//! LOO is used up to [`C3oPredictor::loo_cap`] training points, k-fold
//! beyond — the §VI-C "cap the model selection phase" provision. The CV
//! work itself runs on a [`FitEngine`]: candidate × split tasks fan out
//! over a worker pool (bit-identical to the serial path), and an optional
//! [`crate::cv::parallel::SelectionBudget`] degrades LOO → k-fold →
//! reduced training set instead of blowing the paper's 10–30 s envelope
//! (DESIGN.md §8).

use std::sync::Arc;

use crate::cv::parallel::{FitEngine, SelectionPlan};
use crate::cv::CvScore;
use crate::runtime::FitBackend;

use super::bom::Bom;
use super::gbm::{Gbm, GbmParams};
use super::ogb::Ogb;
use super::{RuntimeModel, TrainData};

/// Outcome of one model-selection pass.
#[derive(Debug, Clone)]
pub struct SelectionReport {
    /// Candidate name → CV score, in candidate order. Disqualified
    /// candidates (fit error or non-finite held-out MAPE) carry ∞ MAPE.
    pub scores: Vec<(String, CvScore)>,
    /// Winner name.
    pub chosen: String,
    /// Winner's CV score (μ, σ feed the configurator).
    pub chosen_score: CvScore,
    /// What the selection pass actually ran (CV method, any budget-driven
    /// training-set reduction, thread count).
    pub plan: SelectionPlan,
}

/// The ∞-MAPE score a disqualified candidate reports.
fn disqualified_score() -> CvScore {
    CvScore {
        mape: f64::INFINITY,
        resid_mean: 0.0,
        resid_std: f64::INFINITY,
        n: 0,
    }
}

/// The C3O runtime predictor.
pub struct C3oPredictor {
    candidates: Vec<Box<dyn RuntimeModel>>,
    fitted: Option<Box<dyn RuntimeModel>>,
    report: Option<SelectionReport>,
    /// Above this size, selection switches from LOO to k-fold.
    pub loo_cap: usize,
    pub kfold_k: usize,
    /// Fit-path execution engine: CV worker threads + selection budget.
    /// Defaults to the serial reference engine; the hub's service and the
    /// CLI install parallel engines (`--fit-threads`, `--fit-budget`).
    engine: FitEngine,
    seed: u64,
}

impl C3oPredictor {
    /// Default candidate set (paper §V): GBM, BOM, OGB.
    pub fn new(backend: Arc<dyn FitBackend>) -> Self {
        let candidates: Vec<Box<dyn RuntimeModel>> = vec![
            Box::new(Gbm::new(GbmParams::default())),
            Box::new(Bom::new(backend.clone())),
            Box::new(Ogb::with_defaults()),
        ];
        C3oPredictor {
            candidates,
            fitted: None,
            report: None,
            loo_cap: 120,
            kfold_k: 10,
            engine: FitEngine::serial(),
            seed: 0xC30,
        }
    }

    /// Replace the fit-path execution engine (threads + selection budget).
    /// Any thread count selects the same model with bit-identical scores;
    /// the budget, when set, may degrade the CV plan.
    pub fn set_engine(&mut self, engine: FitEngine) {
        self.engine = engine;
    }

    pub fn engine(&self) -> &FitEngine {
        &self.engine
    }

    /// Register a maintainer-supplied custom model (§III-C-c: custom models
    /// share the common model API — [`RuntimeModel`]).
    pub fn add_candidate(&mut self, model: Box<dyn RuntimeModel>) {
        self.candidates.push(model);
    }

    pub fn candidate_names(&self) -> Vec<&'static str> {
        self.candidates.iter().map(|c| c.name()).collect()
    }

    /// Fit = select (CV all candidates on the engine) + refit the winner
    /// on all data.
    ///
    /// CV runs unfitted clones, so no candidate is pre-fitted here; a
    /// candidate that errors anywhere (or whose held-out MAPE goes
    /// non-finite — NaN predictions must not poison the ranking, let
    /// alone panic it) is disqualified rather than aborting selection.
    pub fn fit(&mut self, data: &TrainData) -> crate::Result<SelectionReport> {
        anyhow::ensure!(data.len() >= 3, "C3O needs >= 3 training points");
        let (plan, results) = self.engine.score_candidates(
            &self.candidates,
            data,
            self.loo_cap,
            self.kfold_k,
            self.seed,
        )?;
        let mut scores: Vec<(String, CvScore)> = Vec::with_capacity(self.candidates.len());
        for (c, r) in self.candidates.iter().zip(results) {
            let s = match r {
                Ok(s) if s.mape.is_finite() => s,
                _ => disqualified_score(),
            };
            scores.push((c.name().to_string(), s));
        }

        // Total order (stable: earlier candidates win exact ties) — unlike
        // `partial_cmp(..).unwrap()`, `total_cmp` cannot panic on NaN.
        let mut ranked: Vec<usize> = (0..scores.len()).collect();
        ranked.sort_by(|&a, &b| scores[a].1.mape.total_cmp(&scores[b].1.mape));

        // Refit the best CV candidate on the full training set (selection
        // may have run on a budget-reduced subset). A candidate that
        // cross-validates but cannot refit on all data is disqualified and
        // the next-ranked one takes over.
        let mut winner: Option<(usize, Box<dyn RuntimeModel>)> = None;
        for &i in &ranked {
            if !scores[i].1.mape.is_finite() {
                break;
            }
            let mut m = self.candidates[i].clone_unfitted();
            match m.fit(data) {
                Ok(()) => {
                    winner = Some((i, m));
                    break;
                }
                Err(_) => scores[i].1 = disqualified_score(),
            }
        }
        let (best_idx, fitted) = winner
            .ok_or_else(|| anyhow::anyhow!("no candidate model could be cross-validated"))?;

        let report = SelectionReport {
            chosen: scores[best_idx].0.clone(),
            chosen_score: scores[best_idx].1.clone(),
            scores,
            plan,
        };
        self.fitted = Some(fitted);
        self.report = Some(report.clone());
        Ok(report)
    }

    /// Predict a runtime for `[scale_out, data_size, ctx...]`.
    pub fn predict_one(&self, features: &[f64]) -> crate::Result<f64> {
        self.fitted
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("C3O predictor not fitted"))?
            .predict_one(features)
    }

    /// The last selection report (None before the first fit).
    pub fn report(&self) -> Option<&SelectionReport> {
        self.report.as_ref()
    }

    /// Residual distribution of the chosen model: (μ, σ) for §IV-B.
    pub fn error_distribution(&self) -> Option<(f64, f64)> {
        self.report.as_ref().map(|r| (r.chosen_score.resid_mean, r.chosen_score.resid_std))
    }
}

impl RuntimeModel for C3oPredictor {
    fn name(&self) -> &'static str {
        "C3O"
    }

    fn fit(&mut self, data: &TrainData) -> crate::Result<()> {
        C3oPredictor::fit(self, data).map(|_| ())
    }

    fn predict_one(&self, features: &[f64]) -> crate::Result<f64> {
        C3oPredictor::predict_one(self, features)
    }

    fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
        Box::new(C3oPredictor {
            candidates: self.candidates.iter().map(|c| c.clone_unfitted()).collect(),
            fitted: None,
            report: None,
            loo_cap: self.loo_cap,
            kfold_k: self.kfold_k,
            engine: self.engine.clone(),
            seed: self.seed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::parallel::{CvMethod, SelectionBudget};
    use crate::linalg::Matrix;
    use crate::runtime::NativeBackend;
    use crate::util::prng::Pcg;

    fn predictor() -> C3oPredictor {
        C3oPredictor::new(Arc::new(NativeBackend::new()))
    }

    fn separable_world(n: usize, seed: u64) -> TrainData {
        let mut rng = Pcg::seed(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let s = rng.range(2, 13) as f64;
            let (d, k) = if i % 3 == 0 {
                (20.0, 5.0)
            } else {
                (rng.range_f64(10.0, 30.0), rng.range(3, 10) as f64)
            };
            rows.push(vec![s, d, k]);
            y.push((1.0 / s + 0.02 * s) * (10.0 + 4.0 * d + 9.0 * k)
                * (1.0 + 0.02 * rng.normal()));
        }
        TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    #[test]
    fn selects_and_predicts() {
        let data = separable_world(60, 1);
        let mut p = predictor();
        let report = p.fit(&data).unwrap();
        assert_eq!(report.scores.len(), 3);
        assert!(["GBM", "BOM", "OGB"].contains(&report.chosen.as_str()));
        let pred = p.predict_one(&[6.0, 20.0, 5.0]).unwrap();
        assert!(pred > 0.0);
    }

    #[test]
    fn chosen_has_lowest_cv_mape() {
        let data = separable_world(50, 2);
        let mut p = predictor();
        let report = p.fit(&data).unwrap();
        let min = report
            .scores
            .iter()
            .map(|(_, s)| s.mape)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(report.chosen_score.mape, min);
    }

    #[test]
    fn error_distribution_available_after_fit() {
        let data = separable_world(40, 3);
        let mut p = predictor();
        assert!(p.error_distribution().is_none());
        p.fit(&data).unwrap();
        let (_, sigma) = p.error_distribution().unwrap();
        assert!(sigma >= 0.0);
    }

    #[test]
    fn custom_candidate_can_win() {
        // An oracle model that knows the world exactly must be selected.
        struct Oracle;
        impl RuntimeModel for Oracle {
            fn name(&self) -> &'static str {
                "Oracle"
            }
            fn fit(&mut self, _d: &TrainData) -> crate::Result<()> {
                Ok(())
            }
            fn predict_one(&self, f: &[f64]) -> crate::Result<f64> {
                Ok((1.0 / f[0] + 0.02 * f[0]) * (10.0 + 4.0 * f[1] + 9.0 * f[2]))
            }
            fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
                Box::new(Oracle)
            }
        }
        let data = separable_world(40, 4);
        let mut p = predictor();
        p.add_candidate(Box::new(Oracle));
        let report = p.fit(&data).unwrap();
        assert_eq!(report.chosen, "Oracle");
    }

    #[test]
    fn failing_candidate_disqualified_not_fatal() {
        struct Broken;
        impl RuntimeModel for Broken {
            fn name(&self) -> &'static str {
                "Broken"
            }
            fn fit(&mut self, _d: &TrainData) -> crate::Result<()> {
                anyhow::bail!("nope")
            }
            fn predict_one(&self, _f: &[f64]) -> crate::Result<f64> {
                anyhow::bail!("nope")
            }
            fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
                Box::new(Broken)
            }
        }
        let data = separable_world(40, 5);
        let mut p = predictor();
        p.add_candidate(Box::new(Broken));
        let report = p.fit(&data).unwrap();
        assert_ne!(report.chosen, "Broken");
    }

    #[test]
    fn too_little_data_rejected() {
        let data = separable_world(2, 6);
        assert!(predictor().fit(&data).is_err());
    }

    #[test]
    fn works_at_fig5_minimum_of_three_points() {
        // Fig. 5's smallest training size is 3; selection must not crash.
        let data = separable_world(3, 8);
        let mut p = predictor();
        p.fit(&data).unwrap();
        assert!(p.predict_one(&[6.0, 20.0, 5.0]).unwrap().is_finite());
    }

    #[test]
    fn kfold_used_above_cap() {
        let data = separable_world(140, 7);
        let mut p = predictor();
        p.loo_cap = 100;
        let report = p.fit(&data).unwrap();
        assert!(report.chosen_score.n == 140);
        assert_eq!(report.plan.method, CvMethod::KFold(10));
    }

    #[test]
    fn nan_mape_candidate_disqualified_not_panic() {
        // Regression: `partial_cmp(..).unwrap()` panicked when a candidate's
        // held-out predictions went NaN. Now it is disqualified like a fit
        // error.
        struct NanModel;
        impl RuntimeModel for NanModel {
            fn name(&self) -> &'static str {
                "NaNModel"
            }
            fn fit(&mut self, _d: &TrainData) -> crate::Result<()> {
                Ok(())
            }
            fn predict_one(&self, _f: &[f64]) -> crate::Result<f64> {
                Ok(f64::NAN)
            }
            fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
                Box::new(NanModel)
            }
        }
        let data = separable_world(30, 12);
        let mut p = predictor();
        p.add_candidate(Box::new(NanModel));
        let report = p.fit(&data).unwrap();
        assert_ne!(report.chosen, "NaNModel");
        let (_, s) = report.scores.iter().find(|(n, _)| n == "NaNModel").unwrap();
        assert!(s.mape.is_infinite(), "NaN MAPE must rank as disqualified");
        assert!(report.chosen_score.mape.is_finite());
    }

    #[test]
    fn parallel_engine_selects_same_model_with_identical_scores() {
        // The acceptance property: any thread count reproduces the serial
        // path bit-for-bit, in both the LOO and the k-fold regime.
        for &(n, seed) in &[(40usize, 9u64), (140, 10)] {
            let data = separable_world(n, seed);
            let mut serial = predictor();
            serial.loo_cap = 100;
            serial.set_engine(FitEngine::serial());
            let mut parallel = predictor();
            parallel.loo_cap = 100;
            parallel.set_engine(FitEngine::with_threads(4));

            let rs = serial.fit(&data).unwrap();
            let rp = parallel.fit(&data).unwrap();
            assert_eq!(rs.chosen, rp.chosen, "n={n}");
            assert_eq!(rs.plan.method, rp.plan.method);
            for ((na, sa), (nb, sb)) in rs.scores.iter().zip(&rp.scores) {
                assert_eq!(na, nb);
                assert_eq!(sa.mape.to_bits(), sb.mape.to_bits(), "{na} mape");
                assert_eq!(sa.resid_mean.to_bits(), sb.resid_mean.to_bits(), "{na} mu");
                assert_eq!(sa.resid_std.to_bits(), sb.resid_std.to_bits(), "{na} sigma");
                assert_eq!(sa.n, sb.n);
            }
            let q = [6.0, 20.0, 5.0];
            assert_eq!(
                serial.predict_one(&q).unwrap().to_bits(),
                parallel.predict_one(&q).unwrap().to_bits()
            );
        }
    }

    #[test]
    fn point_budget_recorded_in_report_and_deterministic() {
        let data = separable_world(200, 13);
        let engine = FitEngine {
            threads: 2,
            budget: SelectionBudget { max_points: Some(60), ..SelectionBudget::default() },
        };
        let mut a = predictor();
        a.set_engine(engine.clone());
        let mut b = predictor();
        b.set_engine(engine);
        let ra = a.fit(&data).unwrap();
        let rb = b.fit(&data).unwrap();
        assert_eq!(ra.plan.n_total, 200);
        assert_eq!(ra.plan.n_used, 60);
        assert!(ra.plan.reduced());
        // 60 reduced points fit under the default LOO cap again.
        assert_eq!(ra.plan.method, CvMethod::Loo);
        assert_eq!(ra.chosen, rb.chosen);
        for ((_, sa), (_, sb)) in ra.scores.iter().zip(&rb.scores) {
            assert_eq!(sa.mape.to_bits(), sb.mape.to_bits());
        }
    }

    #[test]
    fn winner_that_cannot_refit_on_full_data_falls_back() {
        // CVs perfectly on LOO subsets (n-1 points) but refuses the full
        // set — the next-ranked candidate must win instead of `fit`
        // erroring out.
        struct SubsetOnlyOracle {
            full: usize,
        }
        impl RuntimeModel for SubsetOnlyOracle {
            fn name(&self) -> &'static str {
                "SubsetOnly"
            }
            fn fit(&mut self, d: &TrainData) -> crate::Result<()> {
                anyhow::ensure!(d.len() < self.full, "refuses the full set");
                Ok(())
            }
            fn predict_one(&self, f: &[f64]) -> crate::Result<f64> {
                Ok((1.0 / f[0] + 0.02 * f[0]) * (10.0 + 4.0 * f[1] + 9.0 * f[2]))
            }
            fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
                Box::new(SubsetOnlyOracle { full: self.full })
            }
        }
        let data = separable_world(30, 14);
        let mut p = predictor();
        p.add_candidate(Box::new(SubsetOnlyOracle { full: 30 }));
        let report = p.fit(&data).unwrap();
        assert_ne!(report.chosen, "SubsetOnly");
        assert!(report.chosen_score.mape.is_finite());
        let (_, s) = report.scores.iter().find(|(n, _)| n == "SubsetOnly").unwrap();
        assert!(s.mape.is_infinite(), "refit failure must disqualify");
        assert!(p.predict_one(&[6.0, 20.0, 5.0]).unwrap().is_finite());
    }
}
