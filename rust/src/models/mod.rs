//! Runtime-prediction models (paper §V).
//!
//! * [`ernest`] — the Ernest baseline (NNLS over `[1, d/s, log s, s]`).
//! * [`gbm`] — gradient-boosted regression trees (the paper's strong
//!   general model for collaborative/global data).
//! * [`bom`] — Basic Optimistic Model: poly-3 scale-out-to-speedup model
//!   (SSM) recombined with a linear inputs-behavior model (IBM).
//! * [`ogb`] — Optimistic Gradient Boosting: GBM for both SSM and IBM.
//! * [`c3o`] — the C3O predictor: dynamic selection among the constituent
//!   models via cross-validation (§V-C).
//!
//! All models consume a [`TrainData`] whose feature layout is
//! `[scale_out, data_size, context...]` (machine type is fixed per
//! training set, §VI-C) and predict gross runtimes in seconds.

pub mod bom;
pub mod c3o;
pub mod ernest;
pub mod features;
pub mod gbm;
pub mod ogb;
pub mod tree;

pub use bom::Bom;
pub use c3o::{C3oPredictor, SelectionReport};
pub use ernest::Ernest;
pub use gbm::{Gbm, GbmParams};
pub use ogb::Ogb;

use crate::data::Dataset;
use crate::linalg::Matrix;

/// A training view: feature rows `[scale_out, data_size, ctx...]` + target
/// runtimes (seconds).
#[derive(Debug, Clone)]
pub struct TrainData {
    pub x: Matrix,
    pub y: Vec<f64>,
}

impl TrainData {
    pub fn new(x: Matrix, y: Vec<f64>) -> crate::Result<Self> {
        anyhow::ensure!(x.rows() == y.len(), "x rows {} != y len {}", x.rows(), y.len());
        Ok(TrainData { x, y })
    }

    /// Build from a dataset (one machine type's records).
    pub fn from_dataset(ds: &Dataset) -> crate::Result<Self> {
        let rows: Vec<Vec<f64>> = ds.records.iter().map(|r| r.features()).collect();
        let y = ds.records.iter().map(|r| r.runtime_s).collect();
        TrainData::new(Matrix::from_rows(&rows)?, y)
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Select a subset of rows by index.
    pub fn subset(&self, idx: &[usize]) -> Self {
        let rows: Vec<Vec<f64>> = idx.iter().map(|&i| self.x.row(i).to_vec()).collect();
        let y = idx.iter().map(|&i| self.y[i]).collect();
        TrainData { x: Matrix::from_rows(&rows).unwrap(), y }
    }
}

/// A runtime model. Implementations must be deterministic given their
/// construction-time seed. `Send + Sync` so fitted models can be shared
/// across hub connection threads via the PredictionService cache
/// (prediction is `&self`).
pub trait RuntimeModel: Send + Sync {
    /// Short name as it appears in the paper's tables.
    fn name(&self) -> &'static str;

    /// Fit on training data. May be called repeatedly (refits from scratch).
    fn fit(&mut self, data: &TrainData) -> crate::Result<()>;

    /// Predict one feature row `[scale_out, data_size, ctx...]`.
    fn predict_one(&self, features: &[f64]) -> crate::Result<f64>;

    /// Predict a batch (default: row loop; backends may override).
    fn predict(&self, x: &Matrix) -> crate::Result<Vec<f64>> {
        (0..x.rows()).map(|i| self.predict_one(x.row(i))).collect()
    }

    /// Leave-one-out predictions over `data`: element i is the prediction
    /// for row i by a model fitted on all rows except i.
    ///
    /// Default: N refits. Parametric models override this with a batched
    /// single-launch implementation on the PJRT artifacts (the E4 hot
    /// path).
    fn loo_predictions(&self, data: &TrainData) -> crate::Result<Vec<f64>> {
        let n = data.len();
        let mut out = Vec::with_capacity(n);
        let mut scratch = self.clone_unfitted();
        for i in 0..n {
            let idx: Vec<usize> = (0..n).filter(|&j| j != i).collect();
            let sub = data.subset(&idx);
            scratch.fit(&sub)?;
            out.push(scratch.predict_one(data.x.row(i))?);
        }
        Ok(out)
    }

    /// Fresh unfitted clone (same hyper-parameters/backend).
    fn clone_unfitted(&self) -> Box<dyn RuntimeModel>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{JobKind, RunRecord};

    #[test]
    fn train_data_from_dataset() {
        let mut ds = Dataset::new(JobKind::Grep);
        ds.push(RunRecord {
            machine_type: "m5".into(),
            scale_out: 4,
            data_size_gb: 10.0,
            context: vec![0.01],
            runtime_s: 120.0,
        })
        .unwrap();
        let td = TrainData::from_dataset(&ds).unwrap();
        assert_eq!(td.x.row(0), &[4.0, 10.0, 0.01]);
        assert_eq!(td.y, vec![120.0]);
    }

    #[test]
    fn subset_selects_rows() {
        let x = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]).unwrap();
        let td = TrainData::new(x, vec![10.0, 20.0, 30.0]).unwrap();
        let sub = td.subset(&[2, 0]);
        assert_eq!(sub.y, vec![30.0, 10.0]);
        assert_eq!(sub.x.row(0), &[3.0]);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let x = Matrix::zeros(2, 1);
        assert!(TrainData::new(x, vec![1.0]).is_err());
    }
}
