//! Runtime-prediction models (paper §V).
//!
//! * [`ernest`] — the Ernest baseline (NNLS over `[1, d/s, log s, s]`).
//! * [`gbm`] — gradient-boosted regression trees (the paper's strong
//!   general model for collaborative/global data).
//! * [`bom`] — Basic Optimistic Model: poly-3 scale-out-to-speedup model
//!   (SSM) recombined with a linear inputs-behavior model (IBM).
//! * [`ogb`] — Optimistic Gradient Boosting: GBM for both SSM and IBM.
//! * [`c3o`] — the C3O predictor: dynamic selection among the constituent
//!   models via cross-validation (§V-C).
//!
//! All models consume a [`TrainData`] whose feature layout is
//! `[scale_out, data_size, context...]` (machine type is fixed per
//! training set, §VI-C) and predict gross runtimes in seconds.

pub mod bom;
pub mod c3o;
pub mod ernest;
pub mod features;
pub mod gbm;
pub mod ogb;
pub mod tree;

pub use bom::Bom;
pub use c3o::{C3oPredictor, SelectionReport};
pub use ernest::Ernest;
pub use gbm::{Gbm, GbmParams};
pub use ogb::Ogb;

use crate::data::Dataset;
use crate::linalg::Matrix;

/// A training view: feature rows `[scale_out, data_size, ctx...]` + target
/// runtimes (seconds).
#[derive(Debug, Clone)]
pub struct TrainData {
    pub x: Matrix,
    pub y: Vec<f64>,
}

impl TrainData {
    pub fn new(x: Matrix, y: Vec<f64>) -> crate::Result<Self> {
        anyhow::ensure!(x.rows() == y.len(), "x rows {} != y len {}", x.rows(), y.len());
        Ok(TrainData { x, y })
    }

    /// Build from a dataset (one machine type's records).
    pub fn from_dataset(ds: &Dataset) -> crate::Result<Self> {
        let rows: Vec<Vec<f64>> = ds.records.iter().map(|r| r.features()).collect();
        let y = ds.records.iter().map(|r| r.runtime_s).collect();
        TrainData::new(Matrix::from_rows(&rows)?, y)
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Select a subset of rows by index.
    pub fn subset(&self, idx: &[usize]) -> Self {
        let rows: Vec<Vec<f64>> = idx.iter().map(|&i| self.x.row(i).to_vec()).collect();
        let y = idx.iter().map(|&i| self.y[i]).collect();
        TrainData { x: Matrix::from_rows(&rows).unwrap(), y }
    }

    /// All rows except `skip` — the LOO training set for row `skip`.
    /// Row-identical to `subset` over the complementary index list.
    pub fn subset_excluding(&self, skip: usize) -> Self {
        let rows: Vec<Vec<f64>> = (0..self.len())
            .filter(|&i| i != skip)
            .map(|i| self.x.row(i).to_vec())
            .collect();
        let y = (0..self.len()).filter(|&i| i != skip).map(|i| self.y[i]).collect();
        TrainData { x: Matrix::from_rows(&rows).unwrap(), y }
    }
}

/// A runtime model. Implementations must be deterministic given their
/// construction-time seed. `Send + Sync` so fitted models can be shared
/// across hub connection threads via the PredictionService cache
/// (prediction is `&self`).
///
/// **Parallel-fit contract** (since the `cv::parallel` engine): the
/// fit path ships `clone_unfitted` clones into worker threads and fits
/// them concurrently, so a clone must be independent of its source —
/// same hyper-parameters and backend handle, but no shared mutable
/// state — and `fit` must refit from scratch on every call. Determinism
/// plus independent clones is what makes parallel selection bit-identical
/// to the serial path.
pub trait RuntimeModel: Send + Sync {
    /// Short name as it appears in the paper's tables.
    fn name(&self) -> &'static str;

    /// Fit on training data. May be called repeatedly (refits from scratch).
    fn fit(&mut self, data: &TrainData) -> crate::Result<()>;

    /// Predict one feature row `[scale_out, data_size, ctx...]`.
    fn predict_one(&self, features: &[f64]) -> crate::Result<f64>;

    /// Predict a batch (default: row loop; backends may override).
    fn predict(&self, x: &Matrix) -> crate::Result<Vec<f64>> {
        (0..x.rows()).map(|i| self.predict_one(x.row(i))).collect()
    }

    /// Leave-one-out predictions over `data`: element i is the prediction
    /// for row i by a model fitted on all rows except i.
    ///
    /// Default: N refits. Parametric models override this with a batched
    /// single-launch implementation on the PJRT artifacts (the E4 hot
    /// path).
    fn loo_predictions(&self, data: &TrainData) -> crate::Result<Vec<f64>> {
        let n = data.len();
        let mut out = Vec::with_capacity(n);
        let mut scratch = self.clone_unfitted();
        for i in 0..n {
            scratch.fit(&data.subset_excluding(i))?;
            out.push(scratch.predict_one(data.x.row(i))?);
        }
        Ok(out)
    }

    /// True when this model's [`RuntimeModel::loo_predictions`] is the
    /// default per-row refit loop, so the fit-path engine
    /// ([`crate::cv::parallel::FitEngine`]) may fan the rows out as
    /// independent tasks — bit-identical to running the loop, just
    /// parallel. The default is `false`: a model that overrides
    /// `loo_predictions` (batched like Ernest's single `nnls_batch`
    /// launch, or any custom shortcut) is scheduled as **one whole-LOO
    /// task** calling its override, so existing overrides keep their exact
    /// semantics without knowing about this flag. In-tree row-loop models
    /// (GBM, BOM, OGB) opt in.
    fn loo_splits_independent(&self) -> bool {
        false
    }

    /// Fresh unfitted clone (same hyper-parameters/backend). See the
    /// trait-level parallel-fit contract: clones are fitted concurrently
    /// in worker threads.
    fn clone_unfitted(&self) -> Box<dyn RuntimeModel>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{JobKind, RunRecord};

    #[test]
    fn train_data_from_dataset() {
        let mut ds = Dataset::new(JobKind::Grep);
        ds.push(RunRecord {
            machine_type: "m5".into(),
            scale_out: 4,
            data_size_gb: 10.0,
            context: vec![0.01],
            runtime_s: 120.0,
        })
        .unwrap();
        let td = TrainData::from_dataset(&ds).unwrap();
        assert_eq!(td.x.row(0), &[4.0, 10.0, 0.01]);
        assert_eq!(td.y, vec![120.0]);
    }

    #[test]
    fn subset_selects_rows() {
        let x = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]).unwrap();
        let td = TrainData::new(x, vec![10.0, 20.0, 30.0]).unwrap();
        let sub = td.subset(&[2, 0]);
        assert_eq!(sub.y, vec![30.0, 10.0]);
        assert_eq!(sub.x.row(0), &[3.0]);
    }

    #[test]
    fn subset_excluding_matches_subset_complement() {
        let x = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]).unwrap();
        let td = TrainData::new(x, vec![10.0, 20.0, 30.0]).unwrap();
        let a = td.subset_excluding(1);
        let b = td.subset(&[0, 2]);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.row(0), b.x.row(0));
        assert_eq!(a.x.row(1), b.x.row(1));
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let x = Matrix::zeros(2, 1);
        assert!(TrainData::new(x, vec![1.0]).is_err());
    }
}
