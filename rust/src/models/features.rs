//! Feature engineering shared by the parametric models.

use crate::linalg::Matrix;

/// Column normalizers for the Ernest basis: keep every feature O(1) so
/// the f32 Gram on the PJRT artifact path stays well conditioned. NNLS is
/// invariant under positive diagonal feature scaling (theta rescales by
/// the same positive factors), so semantics are unchanged.
const ERNEST_SCALE: [f64; 4] = [1.0, 16.0, 4.0, 16.0];

/// Ernest's feature map (Venkataraman et al., NSDI'16) for a row
/// `[scale_out s, data_size d, ...]`:  `[1, d/s, log2(s), s]`,
/// column-normalized by [`ERNEST_SCALE`].
///
/// Context columns are deliberately dropped — Ernest "was not built to
/// consider any features other than the dataset size and the scale-out"
/// (paper §VI-C-a), which is exactly why it degrades on global data.
pub fn ernest_features(row: &[f64]) -> Vec<f64> {
    let s = row[0].max(1.0);
    let d = row[1];
    vec![
        1.0,
        d / s / ERNEST_SCALE[1],
        s.log2() / ERNEST_SCALE[2],
        s / ERNEST_SCALE[3],
    ]
}

/// Apply [`ernest_features`] to every row.
pub fn ernest_design(x: &Matrix) -> Matrix {
    let rows: Vec<Vec<f64>> =
        (0..x.rows()).map(|i| ernest_features(x.row(i))).collect();
    Matrix::from_rows(&rows).expect("uniform arity")
}

/// Scale-out normalizer for the polynomial basis. Raw `s^3` up to 12^3
/// squares into a Gram condition number beyond f32 on the PJRT artifact
/// path; `t = s / S_NORM` keeps the basis in [0, 1]-ish territory. The
/// SSM's speedup is a *ratio* of basis evaluations, so the normalization
/// cancels semantically.
pub const S_NORM: f64 = 16.0;

/// Third-degree polynomial basis in the (normalized) scale-out for the
/// BOM's SSM: `[1, t, t^2, t^3]` with `t = s / S_NORM`.
pub fn poly3_features(s: f64) -> Vec<f64> {
    let t = s / S_NORM;
    vec![1.0, t, t * t, t * t * t]
}

/// IBM design row for the BOM: intercept + every non-scale-out feature:
/// `[1, d, ctx...]`.
pub fn ibm_features(row: &[f64]) -> Vec<f64> {
    let mut v = Vec::with_capacity(row.len());
    v.push(1.0);
    v.extend_from_slice(&row[1..]);
    v
}

/// Non-scale-out part of a row (used for SSM grouping).
pub fn context_key(row: &[f64]) -> Vec<f64> {
    row[1..].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ernest_map_matches_nsdi_form() {
        // Normalized NSDI basis [1, d/s, log2 s, s] / ERNEST_SCALE.
        let f = ernest_features(&[4.0, 20.0, 0.5]);
        assert_eq!(f, vec![1.0, 5.0 / 16.0, 2.0 / 4.0, 4.0 / 16.0]);
    }

    #[test]
    fn ernest_features_bounded_for_f32_gram() {
        for s in 2..=12 {
            for d in [10.0, 20.0, 30.0] {
                for v in ernest_features(&[s as f64, d]) {
                    assert!(v.abs() <= 1.0 + 1e-12, "s={s} d={d}: {v}");
                }
            }
        }
    }

    #[test]
    fn ernest_ignores_context() {
        let a = ernest_features(&[4.0, 20.0, 0.5]);
        let b = ernest_features(&[4.0, 20.0, 99.0]);
        assert_eq!(a, b);
    }

    #[test]
    fn poly3_basis() {
        let t = 2.0 / S_NORM;
        assert_eq!(poly3_features(2.0), vec![1.0, t, t * t, t * t * t]);
    }

    #[test]
    fn poly3_basis_bounded_for_f32_gram() {
        // All basis entries stay <= 1 for catalog scale-outs (2..=12), so
        // the f32 Gram on the artifact path stays well conditioned.
        for s in 1..=16 {
            for v in poly3_features(s as f64) {
                assert!(v.abs() <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn ibm_keeps_all_but_scaleout() {
        assert_eq!(ibm_features(&[8.0, 15.0, 3.0, 0.1]), vec![1.0, 15.0, 3.0, 0.1]);
    }

    #[test]
    fn context_key_drops_scaleout_only() {
        assert_eq!(context_key(&[8.0, 15.0, 3.0]), vec![15.0, 3.0]);
    }
}
