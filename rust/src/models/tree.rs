//! Regression trees: the weak learner inside [`super::gbm`].
//!
//! Exact greedy splitting, depth- and leaf-size-limited, squared-error
//! criterion — the same algorithm as scikit-learn's
//! `DecisionTreeRegressor` used by the paper's prototype.
//!
//! Perf note (EXPERIMENTS.md §Perf): feature orders are sorted **once per
//! tree** and maintained through splits by stable partition, so finding a
//! node's best split is O(f·n) instead of O(f·n·log n) — this is the L3
//! hot loop (GBM LOO = n refits × 100 trees) behind the paper's
//! model-selection phase.

use crate::linalg::Matrix;

/// Tree growth parameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 3, min_samples_leaf: 1 }
    }
}

#[derive(Debug, Clone)]
enum Node {
    Leaf { value: f64 },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

/// A fitted regression tree (arena-allocated nodes).
#[derive(Debug, Clone)]
pub struct RegressionTree {
    nodes: Vec<Node>,
}

impl RegressionTree {
    /// Fit on rows `idx` of `(x, y)`.
    pub fn fit(x: &Matrix, y: &[f64], idx: &[usize], params: TreeParams) -> Self {
        Self::fit_presorted(x, y, Self::sort_features(x, idx), params)
    }

    /// Per-feature sorted index orders for `idx` — reusable across trees
    /// fitted on the same rows (gradient boosting refits 100 trees on
    /// identical x; hoisting the sort is a §Perf win, see gbm.rs).
    pub fn sort_features(x: &Matrix, idx: &[usize]) -> Vec<Vec<usize>> {
        (0..x.cols())
            .map(|feat| {
                let mut v = idx.to_vec();
                v.sort_by(|&a, &b| {
                    x[(a, feat)].partial_cmp(&x[(b, feat)]).unwrap()
                });
                v
            })
            .collect()
    }

    /// Fit from precomputed [`RegressionTree::sort_features`] orders.
    pub fn fit_presorted(
        x: &Matrix,
        y: &[f64],
        sorted: Vec<Vec<usize>>,
        params: TreeParams,
    ) -> Self {
        assert!(!sorted.is_empty() && !sorted[0].is_empty(), "empty training set");
        let mut tree = RegressionTree { nodes: Vec::new() };
        tree.grow(x, y, sorted, 0, params);
        tree
    }

    fn grow(
        &mut self,
        x: &Matrix,
        y: &[f64],
        sorted: Vec<Vec<usize>>,
        depth: usize,
        params: TreeParams,
    ) -> usize {
        let n = sorted[0].len();
        let mean = sorted[0].iter().map(|&i| y[i]).sum::<f64>() / n as f64;
        if depth >= params.max_depth || n < 2 * params.min_samples_leaf {
            self.nodes.push(Node::Leaf { value: mean });
            return self.nodes.len() - 1;
        }
        match best_split(x, y, &sorted, params.min_samples_leaf) {
            None => {
                self.nodes.push(Node::Leaf { value: mean });
                self.nodes.len() - 1
            }
            Some((feature, threshold)) => {
                // Stable partition of every feature order by the split
                // condition — preserves sortedness on both sides.
                let f = sorted.len();
                let mut left_sorted = Vec::with_capacity(f);
                let mut right_sorted = Vec::with_capacity(f);
                for order in &sorted {
                    let mut l = Vec::with_capacity(n);
                    let mut r = Vec::with_capacity(n);
                    for &i in order {
                        if x[(i, feature)] <= threshold {
                            l.push(i);
                        } else {
                            r.push(i);
                        }
                    }
                    left_sorted.push(l);
                    right_sorted.push(r);
                }
                drop(sorted);
                debug_assert!(
                    !left_sorted[0].is_empty() && !right_sorted[0].is_empty()
                );
                // Reserve our slot before children so the root is node 0.
                self.nodes.push(Node::Leaf { value: mean }); // placeholder
                let slot = self.nodes.len() - 1;
                let left = self.grow(x, y, left_sorted, depth + 1, params);
                let right = self.grow(x, y, right_sorted, depth + 1, params);
                self.nodes[slot] = Node::Split { feature, threshold, left, right };
                slot
            }
        }
    }

    /// Predict one feature row.
    pub fn predict_one(&self, row: &[f64]) -> f64 {
        let mut node = 0usize;
        loop {
            match &self.nodes[node] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    node = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

/// Find the (feature, threshold) minimizing total SSE over the presorted
/// feature orders; None if no valid split exists (constant features or
/// leaf-size limits).
fn best_split(
    x: &Matrix,
    y: &[f64],
    sorted: &[Vec<usize>],
    min_leaf: usize,
) -> Option<(usize, f64)> {
    let n = sorted[0].len();
    let total_sum: f64 = sorted[0].iter().map(|&i| y[i]).sum();
    let mut best: Option<(f64, usize, f64)> = None; // (score, feat, thr)

    for (feat, order) in sorted.iter().enumerate() {
        // Prefix sums over the sorted order.
        let mut left_sum = 0.0;
        for k in 0..n - 1 {
            let i = order[k];
            left_sum += y[i];
            let xv = x[(i, feat)];
            let xn = x[(order[k + 1], feat)];
            if xn <= xv {
                continue; // tie: not a valid cut point
            }
            let nl = k + 1;
            let nr = n - nl;
            if nl < min_leaf || nr < min_leaf {
                continue;
            }
            // Maximizing sum_l^2/n_l + sum_r^2/n_r minimizes SSE.
            let right_sum = total_sum - left_sum;
            let score = left_sum * left_sum / nl as f64
                + right_sum * right_sum / nr as f64;
            if best.map_or(true, |(b, _, _)| score > b + 1e-12) {
                best = Some((score, feat, 0.5 * (xv + xn)));
            }
        }
    }
    best.map(|(_, f, t)| (f, t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn xy(rows: &[Vec<f64>], y: &[f64]) -> (Matrix, Vec<f64>) {
        (Matrix::from_rows(rows).unwrap(), y.to_vec())
    }

    #[test]
    fn single_point_is_leaf() {
        let (x, y) = xy(&[vec![1.0]], &[5.0]);
        let t = RegressionTree::fit(&x, &y, &[0], TreeParams::default());
        assert_eq!(t.predict_one(&[99.0]), 5.0);
        assert_eq!(t.node_count(), 1);
    }

    #[test]
    fn perfect_split_on_step_function() {
        let (x, y) = xy(
            &[vec![1.0], vec![2.0], vec![10.0], vec![11.0]],
            &[0.0, 0.0, 100.0, 100.0],
        );
        let t = RegressionTree::fit(&x, &y, &[0, 1, 2, 3], TreeParams::default());
        assert_eq!(t.predict_one(&[1.5]), 0.0);
        assert_eq!(t.predict_one(&[10.5]), 100.0);
    }

    #[test]
    fn constant_target_stays_leaf() {
        let (x, y) = xy(&[vec![1.0], vec![2.0], vec![3.0]], &[7.0, 7.0, 7.0]);
        let t = RegressionTree::fit(&x, &y, &[0, 1, 2], TreeParams::default());
        assert_eq!(t.predict_one(&[2.0]), 7.0);
    }

    #[test]
    fn constant_feature_cannot_split() {
        let (x, y) = xy(&[vec![5.0], vec![5.0], vec![5.0]], &[1.0, 2.0, 3.0]);
        let t = RegressionTree::fit(&x, &y, &[0, 1, 2], TreeParams::default());
        assert_eq!(t.node_count(), 1);
        assert!((t.predict_one(&[5.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn respects_min_samples_leaf() {
        let (x, y) = xy(
            &[vec![1.0], vec![2.0], vec![3.0], vec![4.0]],
            &[0.0, 0.0, 0.0, 100.0],
        );
        let t = RegressionTree::fit(
            &x,
            &y,
            &[0, 1, 2, 3],
            TreeParams { max_depth: 5, min_samples_leaf: 2 },
        );
        // The only valid split is 2|2: {1,2} vs {3,4}.
        assert!((t.predict_one(&[1.0]) - 0.0).abs() < 1e-12);
        assert!((t.predict_one(&[4.0]) - 50.0).abs() < 1e-12);
    }

    #[test]
    fn picks_most_informative_feature() {
        // Feature 1 is pure noise; feature 0 determines y.
        let mut rng = Pcg::seed(5);
        let rows: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![if i < 25 { 0.0 } else { 1.0 }, rng.f64()])
            .collect();
        let y: Vec<f64> = (0..50).map(|i| if i < 25 { 1.0 } else { 9.0 }).collect();
        let (x, y) = xy(&rows, &y);
        let idx: Vec<usize> = (0..50).collect();
        let t = RegressionTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 1, min_samples_leaf: 1 },
        );
        assert!((t.predict_one(&[0.0, 0.5]) - 1.0).abs() < 1e-9);
        assert!((t.predict_one(&[1.0, 0.5]) - 9.0).abs() < 1e-9);
    }

    #[test]
    fn deep_tree_fits_training_data_exactly() {
        let mut rng = Pcg::seed(6);
        let rows: Vec<Vec<f64>> = (0..30).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let y: Vec<f64> = (0..30).map(|_| rng.f64() * 10.0).collect();
        let (x, y) = xy(&rows, &y);
        let idx: Vec<usize> = (0..30).collect();
        let t = RegressionTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 30, min_samples_leaf: 1 },
        );
        for i in 0..30 {
            assert!((t.predict_one(x.row(i)) - y[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn duplicate_rows_handled() {
        // Ties everywhere: splits must only occur between distinct values.
        let (x, y) = xy(
            &[vec![1.0], vec![1.0], vec![1.0], vec![2.0], vec![2.0]],
            &[3.0, 3.0, 3.0, 9.0, 9.0],
        );
        let t = RegressionTree::fit(
            &x,
            &y,
            &[0, 1, 2, 3, 4],
            TreeParams::default(),
        );
        assert!((t.predict_one(&[1.0]) - 3.0).abs() < 1e-12);
        assert!((t.predict_one(&[2.0]) - 9.0).abs() < 1e-12);
    }
}
