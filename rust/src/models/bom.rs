//! Basic Optimistic Model (paper §V-B).
//!
//! The optimistic approach assumes runtime-influencing factors are pairwise
//! independent and decomposes the predictor into:
//!
//! * **SSM** (scale-out-to-speedup model) — a third-degree polynomial in
//!   the scale-out, fitted on the largest group of training points that
//!   share every feature *except* the scale-out;
//! * **IBM** (inputs-behavior model) — linear regression over the
//!   non-scale-out features, fitted on all points after the SSM projects
//!   them onto scale-out 1.
//!
//! Prediction = IBM(inputs) × SSM-speedup(scale-out).
//!
//! Both stages are ridge-OLS fits executed through the [`FitBackend`]
//! (batched on the PJRT artifacts in production). The §VI-C-b failure mode
//! — no group with ≥ 2 shared-context points makes the polynomial SSM
//! "gravely incorrect" — is reproduced faithfully: we then fit the SSM on
//! the whole mixed-context set, which is exactly the bad behaviour Fig. 5
//! shows below ~10 training points.

use std::collections::HashMap;
use std::sync::Arc;

use crate::linalg::Matrix;
use crate::runtime::FitBackend;

use super::features::{context_key, ibm_features, poly3_features};
use super::{RuntimeModel, TrainData};

const LAM: f64 = 1e-6;
/// Speedup floor: poly-3 extrapolations can cross zero; predictions stay
/// finite (but can be *very* wrong, matching the paper's observation).
const SPEEDUP_FLOOR: f64 = 0.02;

/// Shared SSM machinery for the optimistic models (BOM and OGB).
pub(crate) fn largest_scaleout_group(data: &TrainData) -> Vec<usize> {
    let mut groups: HashMap<Vec<u64>, Vec<usize>> = HashMap::new();
    for i in 0..data.len() {
        // Bit-exact grouping key (grid data ⇒ exact equality is right).
        let key: Vec<u64> =
            context_key(data.x.row(i)).iter().map(|f| f.to_bits()).collect();
        groups.entry(key).or_default().push(i);
    }
    let mut best: Vec<usize> = Vec::new();
    // Deterministic tie-break: lexicographically smallest index list among
    // maximal groups.
    let mut all: Vec<Vec<usize>> = groups.into_values().collect();
    all.sort();
    for g in all {
        if g.len() > best.len() {
            best = g;
        }
    }
    best
}

/// Pooled SSM training points: every group of rows sharing all
/// non-scale-out features contributes its runtimes *normalized by the
/// group mean*, so groups at different runtime scales describe one common
/// scale-out-to-speedup shape.
///
/// This generalizes the paper's "points that share the same values for
/// every feature except the scale-out": with sparse shared-context data a
/// single group starves the SSM (the paper's own BOM failure mode below
/// ~10 points); pooling normalized groups uses all usable evidence while
/// preserving the optimistic-decomposition semantics. Falls back to the
/// unnormalized full dataset when no group has >= 2 points — which
/// reproduces the paper's "gravely incorrect" small-data behaviour.
pub(crate) fn pooled_ssm_points(data: &TrainData) -> Vec<(f64, f64)> {
    let mut groups: HashMap<Vec<u64>, Vec<usize>> = HashMap::new();
    for i in 0..data.len() {
        let key: Vec<u64> =
            context_key(data.x.row(i)).iter().map(|f| f.to_bits()).collect();
        groups.entry(key).or_default().push(i);
    }
    let mut pts = Vec::new();
    let mut all: Vec<Vec<usize>> = groups.into_values().collect();
    all.sort();
    for g in &all {
        if g.len() < 2 {
            continue;
        }
        let mean = g.iter().map(|&i| data.y[i]).sum::<f64>() / g.len() as f64;
        if mean <= 0.0 {
            continue;
        }
        for &i in g {
            pts.push((data.x.row(i)[0], data.y[i] / mean));
        }
    }
    if pts.is_empty() {
        // Degenerate: every context unique. Fit on raw runtimes — wrong
        // in general, exactly as the paper observes for tiny datasets.
        for i in 0..data.len() {
            pts.push((data.x.row(i)[0], data.y[i]));
        }
    }
    pts
}

/// Basic Optimistic Model.
pub struct Bom {
    backend: Arc<dyn FitBackend>,
    /// Poly-3 coefficients of the SSM: runtime-vs-scale-out shape.
    ssm: Option<Vec<f64>>,
    /// IBM linear coefficients over `[1, d, ctx...]`.
    ibm: Option<Vec<f64>>,
}

impl Bom {
    pub fn new(backend: Arc<dyn FitBackend>) -> Self {
        Bom { backend, ssm: None, ibm: None }
    }

    /// SSM-predicted runtime shape at scale-out `s` (unnormalized).
    fn ssm_value(&self, s: f64) -> f64 {
        let c = self.ssm.as_ref().expect("fitted");
        poly3_features(s).iter().zip(c).map(|(a, b)| a * b).sum()
    }

    /// Speedup factor relative to scale-out 1, floored for stability.
    fn speedup(&self, s: f64) -> f64 {
        let base = self.ssm_value(1.0);
        if base.abs() < 1e-9 {
            return SPEEDUP_FLOOR;
        }
        (self.ssm_value(s) / base).max(SPEEDUP_FLOOR)
    }
}

impl RuntimeModel for Bom {
    fn name(&self) -> &'static str {
        "BOM"
    }

    fn fit(&mut self, data: &TrainData) -> crate::Result<()> {
        anyhow::ensure!(data.len() >= 2, "BOM needs >= 2 training points");

        // --- SSM: poly-3 normalized-runtime vs scale-out on the pooled
        // shared-context groups.
        let pts = pooled_ssm_points(data);
        let ssm_rows: Vec<Vec<f64>> =
            pts.iter().map(|&(s, _)| poly3_features(s)).collect();
        let ssm_y: Vec<f64> = pts.iter().map(|&(_, t)| t).collect();
        let ssm_x = Matrix::from_rows(&ssm_rows)?;
        let ones = Matrix::from_vec(1, pts.len(), vec![1.0; pts.len()])?;
        let (theta, _) = self.backend.ols_batch(&ssm_x, &ssm_y, &ones, LAM)?;
        self.ssm = Some(theta.row(0).to_vec());

        // --- Project all points onto scale-out 1 and fit the IBM.
        let ibm_rows: Vec<Vec<f64>> =
            (0..data.len()).map(|i| ibm_features(data.x.row(i))).collect();
        let t1: Vec<f64> = (0..data.len())
            .map(|i| data.y[i] / self.speedup(data.x.row(i)[0]))
            .collect();
        let ibm_x = Matrix::from_rows(&ibm_rows)?;
        let ones = Matrix::from_vec(1, data.len(), vec![1.0; data.len()])?;
        let (theta, _) = self.backend.ols_batch(&ibm_x, &t1, &ones, LAM)?;
        self.ibm = Some(theta.row(0).to_vec());
        Ok(())
    }

    fn predict_one(&self, features: &[f64]) -> crate::Result<f64> {
        // Fitted-state audit (cf. the Gbm `fitted` flag): the Option-typed
        // coefficients are an explicit flag already — `ibm` is set last in
        // `fit`, so a Some here implies a complete fit; no value-based
        // inference involved.
        let ibm = self.ibm.as_ref().ok_or_else(|| anyhow::anyhow!("BOM not fitted"))?;
        let base: f64 =
            ibm_features(features).iter().zip(ibm).map(|(a, b)| a * b).sum();
        Ok(base * self.speedup(features[0]))
    }

    /// Uses the default per-row LOO loop — the fit-path engine may fan
    /// the rows out as independent tasks.
    fn loo_splits_independent(&self) -> bool {
        true
    }

    fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
        Box::new(Bom::new(self.backend.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use crate::util::prng::Pcg;
    use crate::util::stats::mape;

    fn bom() -> Bom {
        Bom::new(Arc::new(NativeBackend::new()))
    }

    /// World obeying the optimistic assumption exactly:
    /// t(s, d, k) = g(s) * h(d, k) with h linear.
    fn separable_world(n: usize, seed: u64) -> TrainData {
        let mut rng = Pcg::seed(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        // Ensure one dense shared-context group for the SSM: fix (d, k) =
        // (20, 5) for a third of the points.
        for i in 0..n {
            let s = rng.range(2, 13) as f64;
            let (d, k) = if i % 3 == 0 {
                (20.0, 5.0)
            } else {
                (rng.range_f64(10.0, 30.0), rng.range(3, 10) as f64)
            };
            rows.push(vec![s, d, k]);
            let g = 1.0 / s + 0.02 * s; // speedup shape with overhead upturn
            let h = 10.0 + 4.0 * d + 9.0 * k;
            y.push(g * h);
        }
        TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    #[test]
    fn fits_separable_world_well() {
        let data = separable_world(90, 1);
        let mut m = bom();
        m.fit(&data).unwrap();
        let preds = m.predict(&data.x).unwrap();
        let err = mape(&preds, &data.y);
        assert!(err < 8.0, "in-sample MAPE {err}%");
    }

    #[test]
    fn speedup_normalized_at_one() {
        let data = separable_world(60, 2);
        let mut m = bom();
        m.fit(&data).unwrap();
        assert!((m.speedup(1.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn largest_group_found() {
        let data = separable_world(90, 3);
        let g = largest_scaleout_group(&data);
        // A third of the points share (20, 5).
        assert!(g.len() >= 90 / 3, "group size {}", g.len());
        for &i in &g {
            assert_eq!(&data.x.row(i)[1..], &[20.0, 5.0]);
        }
    }

    #[test]
    fn degrades_without_shared_context_group() {
        // Every point a unique context: the SSM trains on mixed contexts —
        // the paper's observed BOM failure mode. The model must still
        // produce finite output but with large errors.
        let mut rng = Pcg::seed(4);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for i in 0..12 {
            let s = (2 + i % 6) as f64;
            let d = 10.0 + i as f64 * 1.7;
            let k = 3.0 + (i as f64) * 0.61; // all distinct
            rows.push(vec![s, d, k]);
            y.push((1.0 / s + 0.02 * s) * (10.0 + 4.0 * d + 9.0 * k) * (1.0 + 0.02 * rng.normal()));
        }
        let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();
        let mut m = bom();
        m.fit(&data).unwrap();
        let preds = m.predict(&data.x).unwrap();
        for p in &preds {
            assert!(p.is_finite());
        }
    }

    #[test]
    fn prediction_positive_even_at_extrapolated_scaleout() {
        let data = separable_world(60, 5);
        let mut m = bom();
        m.fit(&data).unwrap();
        // Far outside the 2..12 training range: poly-3 may go negative;
        // the floor keeps predictions positive.
        let p = m.predict_one(&[40.0, 20.0, 5.0]).unwrap();
        assert!(p > 0.0, "p={p}");
    }

    #[test]
    fn unfitted_errors() {
        assert!(bom().predict_one(&[2.0, 10.0, 3.0]).is_err());
    }

    #[test]
    fn captures_context_effect_unlike_ernest() {
        let data = separable_world(90, 6);
        let mut m = bom();
        m.fit(&data).unwrap();
        let lo = m.predict_one(&[6.0, 20.0, 3.0]).unwrap();
        let hi = m.predict_one(&[6.0, 20.0, 9.0]).unwrap();
        assert!(hi > lo * 1.2, "k effect must show: lo={lo} hi={hi}");
    }
}
