//! Ernest baseline (Venkataraman et al., NSDI'16), as used in the paper's
//! Table II: NNLS over the parametric scale-out features
//! `[1, d/s, log2 s, s]`, ignoring every context feature.
//!
//! Leave-one-out CV is a single batched `nnls_batch` launch on the
//! [`FitBackend`] (one mask per held-out row) — the E4 hot path.

use std::sync::Arc;

use crate::linalg::Matrix;
use crate::runtime::FitBackend;

use super::features::{ernest_design, ernest_features};
use super::{RuntimeModel, TrainData};

const LAM: f64 = 1e-6;

/// Ernest runtime model.
pub struct Ernest {
    backend: Arc<dyn FitBackend>,
    theta: Option<Vec<f64>>,
}

impl Ernest {
    pub fn new(backend: Arc<dyn FitBackend>) -> Self {
        Ernest { backend, theta: None }
    }
}

impl RuntimeModel for Ernest {
    fn name(&self) -> &'static str {
        "Ernest"
    }

    fn fit(&mut self, data: &TrainData) -> crate::Result<()> {
        anyhow::ensure!(data.len() >= 2, "Ernest needs >= 2 training points");
        let design = ernest_design(&data.x);
        let w = Matrix::from_vec(1, data.len(), vec![1.0; data.len()])?;
        let (theta, _) = self.backend.nnls_batch(&design, &data.y, &w, LAM)?;
        self.theta = Some(theta.row(0).to_vec());
        Ok(())
    }

    fn predict_one(&self, features: &[f64]) -> crate::Result<f64> {
        let theta = self.theta.as_ref().ok_or_else(|| anyhow::anyhow!("Ernest not fitted"))?;
        let f = ernest_features(features);
        Ok(f.iter().zip(theta).map(|(a, b)| a * b).sum())
    }

    fn loo_predictions(&self, data: &TrainData) -> crate::Result<Vec<f64>> {
        let n = data.len();
        anyhow::ensure!(n >= 3, "LOO needs >= 3 points");
        let design = ernest_design(&data.x);
        // Mask row i leaves point i out.
        let mut w = Matrix::from_vec(n, n, vec![1.0; n * n])?;
        for i in 0..n {
            w[(i, i)] = 0.0;
        }
        let (_, preds) = self.backend.nnls_batch(&design, &data.y, &w, LAM)?;
        Ok((0..n).map(|i| preds[(i, i)]).collect())
    }

    // `loo_splits_independent` stays false: the override above is one
    // batched backend launch for all n splits, and the fit-path engine
    // schedules it as a single whole-LOO task.

    fn clone_unfitted(&self) -> Box<dyn RuntimeModel> {
        Box::new(Ernest::new(self.backend.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use crate::util::prng::Pcg;

    fn ernest() -> Ernest {
        Ernest::new(Arc::new(NativeBackend::new()))
    }

    /// Synthetic job following Ernest's own model form.
    fn ernest_world(n: usize, seed: u64) -> TrainData {
        let mut rng = Pcg::seed(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let s = rng.range(2, 13) as f64;
            let d = rng.range_f64(10.0, 30.0);
            rows.push(vec![s, d]);
            y.push(20.0 + 3.0 * d / s + 5.0 * s.log2() + 0.8 * s);
        }
        TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
    }

    #[test]
    fn recovers_its_own_model_form() {
        let data = ernest_world(40, 1);
        let mut m = ernest();
        m.fit(&data).unwrap();
        for i in 0..data.len() {
            let p = m.predict_one(data.x.row(i)).unwrap();
            assert!((p / data.y[i] - 1.0).abs() < 0.02, "{p} vs {}", data.y[i]);
        }
    }

    #[test]
    fn ignores_context_features() {
        let mut data = ernest_world(30, 2);
        // Append a context column that strongly drives y — Ernest can't see it.
        let rows: Vec<Vec<f64>> = (0..data.len())
            .map(|i| {
                let mut r = data.x.row(i).to_vec();
                r.push(if i % 2 == 0 { 0.0 } else { 100.0 });
                r
            })
            .collect();
        data.x = Matrix::from_rows(&rows).unwrap();
        let mut m = ernest();
        m.fit(&data).unwrap();
        let mut a = data.x.row(0).to_vec();
        let mut b = a.clone();
        a[2] = 0.0;
        b[2] = 1000.0;
        assert_eq!(m.predict_one(&a).unwrap(), m.predict_one(&b).unwrap());
    }

    #[test]
    fn loo_matches_naive_loop() {
        let data = ernest_world(12, 3);
        let m = ernest();
        let fast = m.loo_predictions(&data).unwrap();
        // Naive: refit without row i.
        let mut slow = Vec::new();
        for i in 0..data.len() {
            let idx: Vec<usize> = (0..data.len()).filter(|&j| j != i).collect();
            let mut scratch = ernest();
            scratch.fit(&data.subset(&idx)).unwrap();
            slow.push(scratch.predict_one(data.x.row(i)).unwrap());
        }
        for (f, s) in fast.iter().zip(&slow) {
            assert!((f - s).abs() < 1e-5, "{f} vs {s}");
        }
    }

    #[test]
    fn unfitted_predict_errors() {
        assert!(ernest().predict_one(&[4.0, 10.0]).is_err());
    }

    #[test]
    fn coefficients_nonnegative() {
        // Decreasing runtimes with size would need negative theta; NNLS
        // clamps to zero instead of extrapolating nonsense.
        let rows = vec![vec![2.0, 10.0], vec![4.0, 20.0], vec![8.0, 30.0]];
        let y = vec![100.0, 50.0, 25.0];
        let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();
        let mut m = ernest();
        m.fit(&data).unwrap();
        for i in 2..8 {
            let p = m.predict_one(&[i as f64, 20.0]).unwrap();
            assert!(p >= 0.0);
        }
    }
}
