//! Pure-Rust implementation of [`FitBackend`].
//!
//! Mirrors the L2 JAX graphs exactly (same estimators, same masking
//! semantics); used when `artifacts/` is absent, in unit tests, and as the
//! ground truth for `rust/tests/runtime_parity.rs`.

use crate::linalg::{nnls, ols_ridge, Matrix};

use super::FitBackend;

/// Native (non-PJRT) fit backend.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }

    fn batch(
        x: &Matrix,
        w: &Matrix,
        fit_one: impl Fn(&[f64]) -> crate::Result<Vec<f64>>,
    ) -> crate::Result<(Matrix, Matrix)> {
        let b = w.rows();
        let f = x.cols();
        let n = x.rows();
        let mut theta = Matrix::zeros(b, f);
        let mut preds = Matrix::zeros(b, n);
        for bi in 0..b {
            let th = fit_one(w.row(bi))?;
            theta.row_mut(bi).copy_from_slice(&th);
            let p = x.matvec(&th);
            preds.row_mut(bi).copy_from_slice(&p);
        }
        Ok((theta, preds))
    }
}

impl FitBackend for NativeBackend {
    fn ols_batch(
        &self,
        x: &Matrix,
        y: &[f64],
        w: &Matrix,
        lam: f64,
    ) -> crate::Result<(Matrix, Matrix)> {
        anyhow::ensure!(x.rows() == y.len() && w.cols() == x.rows(), "shape mismatch");
        Self::batch(x, w, |wrow| ols_ridge(x, y, wrow, lam))
    }

    fn nnls_batch(
        &self,
        x: &Matrix,
        y: &[f64],
        w: &Matrix,
        lam: f64,
    ) -> crate::Result<(Matrix, Matrix)> {
        anyhow::ensure!(x.rows() == y.len() && w.cols() == x.rows(), "shape mismatch");
        Self::batch(x, w, |wrow| nnls(x, y, wrow, lam))
    }

    fn predict_grid(&self, theta: &Matrix, xq: &Matrix) -> crate::Result<Matrix> {
        anyhow::ensure!(theta.cols() == xq.cols(), "feature arity mismatch");
        let b = theta.rows();
        let q = xq.rows();
        let mut out = Matrix::zeros(b, q);
        for bi in 0..b {
            let p = xq.matvec(theta.row(bi));
            out.row_mut(bi).copy_from_slice(&p);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn toy() -> (Matrix, Vec<f64>, Matrix) {
        let mut rng = Pcg::seed(2);
        let n = 20;
        let f = 3;
        let rows: Vec<Vec<f64>> =
            (0..n).map(|_| (0..f).map(|_| rng.f64() + 0.1).collect()).collect();
        let beta = [1.0, 2.0, 0.5];
        let y: Vec<f64> = rows
            .iter()
            .map(|r| r.iter().zip(&beta).map(|(a, b)| a * b).sum())
            .collect();
        let x = Matrix::from_rows(&rows).unwrap();
        let mut w = Matrix::zeros(4, n);
        for bi in 0..4 {
            for j in 0..n {
                w[(bi, j)] = if (j + bi) % 5 == 0 { 0.0 } else { 1.0 };
            }
        }
        (x, y, w)
    }

    #[test]
    fn ols_batch_recovers_truth_per_mask() {
        let (x, y, w) = toy();
        let nb = NativeBackend::new();
        let (theta, preds) = nb.ols_batch(&x, &y, &w, 1e-10).unwrap();
        for bi in 0..theta.rows() {
            assert!((theta[(bi, 0)] - 1.0).abs() < 1e-6);
            assert!((theta[(bi, 1)] - 2.0).abs() < 1e-6);
            assert!((theta[(bi, 2)] - 0.5).abs() < 1e-6);
        }
        // preds = X theta.
        for j in 0..x.rows() {
            assert!((preds[(0, j)] - y[j]).abs() < 1e-6);
        }
    }

    #[test]
    fn nnls_batch_nonnegative() {
        let (x, y, w) = toy();
        let nb = NativeBackend::new();
        let (theta, _) = nb.nnls_batch(&x, &y, &w, 1e-8).unwrap();
        for v in theta.data() {
            assert!(*v >= 0.0);
        }
    }

    #[test]
    fn predict_grid_matches_matvec() {
        let nb = NativeBackend::new();
        let theta = Matrix::from_rows(&[vec![1.0, 2.0], vec![0.0, 1.0]]).unwrap();
        let xq = Matrix::from_rows(&[vec![3.0, 4.0], vec![1.0, 0.0]]).unwrap();
        let p = nb.predict_grid(&theta, &xq).unwrap();
        assert_eq!(p.row(0), &[11.0, 1.0]);
        assert_eq!(p.row(1), &[4.0, 0.0]);
    }

    #[test]
    fn shape_mismatches_rejected() {
        let nb = NativeBackend::new();
        let x = Matrix::zeros(3, 2);
        let w = Matrix::zeros(1, 4);
        assert!(nb.ols_batch(&x, &[1.0, 1.0, 1.0], &w, 0.0).is_err());
    }
}
