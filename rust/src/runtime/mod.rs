//! PJRT runtime: load the AOT artifacts and execute them on the hot path.
//!
//! `python/compile/aot.py` lowers the L2 estimator graphs once to HLO text;
//! this module compiles them on the PJRT CPU client at startup and exposes
//! typed, padded entry points. Python never runs here.
//!
//! Start-up flow (`Engine::load`):
//!   1. read + verify `artifacts/MANIFEST.tsv` against [`shapes`],
//!   2. `HloModuleProto::from_text_file` each module (HLO *text* is the
//!      interchange format — serialized jax protos carry 64-bit ids that
//!      xla_extension 0.5.1 rejects),
//!   3. compile to `PjRtLoadedExecutable`s held for the process lifetime.

pub mod engine;
pub mod native;
pub mod shapes;

pub use engine::Engine;
pub use native::NativeBackend;

use crate::linalg::Matrix;

/// A batched fit over masked subsets of one design matrix.
///
/// Implemented both by the PJRT [`Engine`] (AOT artifacts, the production
/// hot path) and by [`NativeBackend`] (pure Rust, used in tests and as a
/// fallback when `artifacts/` is absent). `rust/tests/runtime_parity.rs`
/// asserts the two agree.
pub trait FitBackend: Send + Sync {
    /// Ridge OLS: for every mask row `w[b]`, solve
    /// `(X^T diag(w_b) X + lam I) theta_b = X^T diag(w_b) y` and return
    /// `(theta, preds)` where `preds[b] = X theta_b`.
    fn ols_batch(
        &self,
        x: &Matrix,
        y: &[f64],
        w: &Matrix,
        lam: f64,
    ) -> crate::Result<(Matrix, Matrix)>;

    /// Non-negative least squares, same shapes as [`FitBackend::ols_batch`].
    fn nnls_batch(
        &self,
        x: &Matrix,
        y: &[f64],
        w: &Matrix,
        lam: f64,
    ) -> crate::Result<(Matrix, Matrix)>;

    /// Prediction sweep: `preds[b] = Xq theta_b`.
    fn predict_grid(&self, theta: &Matrix, xq: &Matrix) -> crate::Result<Matrix>;

    /// Human-readable backend name (for logs and bench labels).
    fn name(&self) -> &'static str;
}
