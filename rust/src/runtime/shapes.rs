//! AOT shape contract — MUST match `python/compile/model.py`.
//!
//! The artifacts are lowered with fixed shapes; the engine pads inputs up to
//! these and slices outputs back down. `MANIFEST.tsv` written by `aot.py`
//! carries the same constants; [`crate::runtime::Engine::load`] verifies
//! them before compiling anything.

/// Max training rows per fit (PageRank's per-machine slice is 94, the
/// largest in the Table-I corpus; 128 leaves headroom).
pub const N: usize = 128;
/// Max feature columns (including intercept column if the model uses one).
pub const F: usize = 8;
/// Max simultaneous cross-validation masks per launch.
pub const B: usize = 128;
/// Max query rows in the configurator prediction sweep.
pub const Q: usize = 64;

/// Artifact module names (basenames under `artifacts/`).
pub const MODULES: [&str; 3] = ["ols_batch", "nnls_batch", "predict_grid"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn b_covers_loo_of_n() {
        // Leave-one-out over N rows requires at least N masks.
        assert!(B >= N);
    }

    #[test]
    fn module_names_are_unique() {
        let mut names = MODULES.to_vec();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), MODULES.len());
    }
}
