//! PJRT engine: compile the AOT HLO-text artifacts once, execute them on
//! the request path with shape padding.
//!
//! The artifacts are lowered with fixed shapes (`shapes::{N, F, B, Q}`);
//! the engine pads every call up to those and slices the outputs back
//! down. Padded mask rows are all-zero (their fits collapse to `θ = 0`
//! under the ridge term) and padded feature columns only multiply zeros,
//! so padding is semantically inert — `rust/tests/runtime_parity.rs`
//! checks this against the native backend.
//!
//! Threading: PJRT handles (`PjRtLoadedExecutable`, `PjRtClient`) hold
//! `Rc`s and are neither `Send` nor `Sync`; the engine therefore owns them
//! on a dedicated worker thread and implements [`FitBackend`] by message
//! passing. This also naturally serializes launches on the single CPU
//! device, which is the right execution model (one launch covers a whole
//! CV batch, so the queue is not a bottleneck — E4 measures this).

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;
use std::thread::JoinHandle;

use anyhow::{bail, Context};

use crate::linalg::Matrix;

use super::shapes::{B, F, N, Q};
use super::FitBackend;

/// Ridge floor on the artifact path: guarantees padded (all-zero) systems
/// stay non-singular in f32.
const MIN_LAM: f64 = 1e-4;

enum Request {
    Fit {
        module: FitModule,
        x: Matrix,
        y: Vec<f64>,
        w: Matrix,
        lam: f64,
        reply: mpsc::Sender<crate::Result<(Matrix, Matrix)>>,
    },
    Predict {
        theta: Matrix,
        xq: Matrix,
        reply: mpsc::Sender<crate::Result<Matrix>>,
    },
    Stop,
}

#[derive(Clone, Copy)]
enum FitModule {
    Ols,
    Nnls,
}

/// The production fit backend: executes the AOT artifacts via PJRT CPU.
///
/// Problems exceeding the artifact shapes fall back to the native solver
/// (counted in [`Engine::fallbacks`]) instead of failing — the artifacts
/// cover the whole Table-I corpus, but user-supplied datasets may be
/// arbitrarily large.
pub struct Engine {
    sender: Mutex<mpsc::Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    dir: PathBuf,
    native: super::NativeBackend,
    fallbacks: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Load and compile all artifacts from `dir` (usually `artifacts/`).
    ///
    /// Manifest verification is always available; actually compiling and
    /// executing the HLO requires the `pjrt` cargo feature (the `xla`
    /// crate is not in the offline crate cache — see DESIGN.md §2).
    /// Without it, `load` fails cleanly and callers fall back to
    /// [`super::NativeBackend`].
    pub fn load(dir: &Path) -> crate::Result<Engine> {
        Self::verify_manifest(dir)?;
        Self::spawn_worker(dir)
    }

    #[cfg(feature = "pjrt")]
    fn spawn_worker(dir: &Path) -> crate::Result<Engine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
        let dir_owned = dir.to_path_buf();
        let worker = std::thread::Builder::new()
            .name("c3o-pjrt".into())
            .spawn(move || worker_loop(dir_owned, rx, ready_tx))?;
        ready_rx
            .recv()
            .context("PJRT worker died during startup")??;
        Ok(Engine {
            sender: Mutex::new(tx),
            worker: Some(worker),
            dir: dir.to_path_buf(),
            native: super::NativeBackend::new(),
            fallbacks: std::sync::atomic::AtomicU64::new(0),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn spawn_worker(_dir: &Path) -> crate::Result<Engine> {
        bail!(
            "PJRT engine disabled: built without the `pjrt` cargo feature \
             (the offline crate cache has no `xla` bindings); \
             use the native backend"
        )
    }

    /// How many calls were served by the native fallback because they
    /// exceeded the artifact shapes.
    pub fn fallbacks(&self) -> u64 {
        self.fallbacks.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn fits_artifacts(x: &Matrix, w: &Matrix) -> bool {
        x.rows() <= N && x.cols() <= F && w.rows() <= B
    }

    /// Load from the conventional location, walking up from CWD (so tests,
    /// examples and benches all find `artifacts/` regardless of harness
    /// working directory).
    pub fn load_default() -> crate::Result<Engine> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("MANIFEST.tsv").exists() {
                return Engine::load(&cand);
            }
            if !dir.pop() {
                bail!(
                    "artifacts/MANIFEST.tsv not found above {}; run `make artifacts`",
                    std::env::current_dir()?.display()
                );
            }
        }
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    fn request_fit(
        &self,
        module: FitModule,
        x: &Matrix,
        y: &[f64],
        w: &Matrix,
        lam: f64,
    ) -> crate::Result<(Matrix, Matrix)> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.sender
            .lock()
            .unwrap()
            .send(Request::Fit {
                module,
                x: x.clone(),
                y: y.to_vec(),
                w: w.clone(),
                lam,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("PJRT worker gone"))?;
        reply_rx.recv().context("PJRT worker dropped reply")?
    }

    /// Check the aot.py manifest against the compiled-in shape contract.
    fn verify_manifest(dir: &Path) -> crate::Result<()> {
        let path = dir.join("MANIFEST.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        // First line: "# N=..\tF=..\tB=..\tQ=..".
        let header = text.lines().next().context("empty manifest")?;
        let mut seen = std::collections::BTreeMap::new();
        for part in header.trim_start_matches('#').split_whitespace() {
            if let Some((k, v)) = part.split_once('=') {
                seen.insert(k.to_string(), v.parse::<usize>()?);
            }
        }
        for (key, expect) in [("N", N), ("F", F), ("B", B), ("Q", Q)] {
            match seen.get(key) {
                Some(&v) if v == expect => {}
                Some(&v) => {
                    bail!("manifest {key}={v} != compiled-in {expect}; re-run make artifacts")
                }
                None => bail!("manifest missing {key}"),
            }
        }
        // Body: every listed module file must exist. The manifest body is
        // header-less (name, sha256, shapes per line), so iterate raw
        // lines rather than going through the headered Table parser.
        let mut modules = 0usize;
        for line in text.lines().skip(1) {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let name = line.split('\t').next().unwrap_or("");
            let f = dir.join(format!("{name}.hlo.txt"));
            if !f.exists() {
                bail!("manifest lists {} but file is missing", f.display());
            }
            modules += 1;
        }
        anyhow::ensure!(modules >= 3, "manifest lists only {modules} modules");
        Ok(())
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.sender.lock().unwrap().send(Request::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl FitBackend for Engine {
    fn ols_batch(
        &self,
        x: &Matrix,
        y: &[f64],
        w: &Matrix,
        lam: f64,
    ) -> crate::Result<(Matrix, Matrix)> {
        if !Self::fits_artifacts(x, w) {
            self.fallbacks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            // Match the artifact path's ridge floor so both paths solve
            // the same problem.
            return self.native.ols_batch(x, y, w, lam.max(MIN_LAM));
        }
        self.request_fit(FitModule::Ols, x, y, w, lam)
    }

    fn nnls_batch(
        &self,
        x: &Matrix,
        y: &[f64],
        w: &Matrix,
        lam: f64,
    ) -> crate::Result<(Matrix, Matrix)> {
        if !Self::fits_artifacts(x, w) {
            self.fallbacks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return self.native.nnls_batch(x, y, w, lam.max(MIN_LAM));
        }
        self.request_fit(FitModule::Nnls, x, y, w, lam)
    }

    fn predict_grid(&self, theta: &Matrix, xq: &Matrix) -> crate::Result<Matrix> {
        if theta.rows() > B || theta.cols() > F || xq.rows() > Q {
            self.fallbacks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return self.native.predict_grid(theta, xq);
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.sender
            .lock()
            .unwrap()
            .send(Request::Predict { theta: theta.clone(), xq: xq.clone(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("PJRT worker gone"))?;
        reply_rx.recv().context("PJRT worker dropped reply")?
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// ---------------------------------------------------------------------------
// Worker side: owns the non-Send PJRT handles. Everything below touches the
// `xla` crate and therefore only exists under the `pjrt` feature.

#[cfg(feature = "pjrt")]
struct Modules {
    ols: xla::PjRtLoadedExecutable,
    nnls: xla::PjRtLoadedExecutable,
    predict: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
fn worker_loop(dir: PathBuf, rx: mpsc::Receiver<Request>, ready: mpsc::Sender<crate::Result<()>>) {
    let modules = match compile_modules(&dir) {
        Ok(m) => {
            let _ = ready.send(Ok(()));
            m
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Stop => break,
            Request::Fit { module, x, y, w, lam, reply } => {
                let exe = match module {
                    FitModule::Ols => &modules.ols,
                    FitModule::Nnls => &modules.nnls,
                };
                let _ = reply.send(run_fit(exe, &x, &y, &w, lam));
            }
            Request::Predict { theta, xq, reply } => {
                let _ = reply.send(run_predict(&modules.predict, &theta, &xq));
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn compile_modules(dir: &Path) -> crate::Result<Modules> {
    let client =
        xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
    let compile = |name: &str| -> crate::Result<xla::PjRtLoadedExecutable> {
        let path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))
    };
    Ok(Modules {
        ols: compile("ols_batch")?,
        nnls: compile("nnls_batch")?,
        predict: compile("predict_grid")?,
    })
}

#[cfg(feature = "pjrt")]
fn literal_f32(data: &[f32], dims: &[i64]) -> crate::Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape {dims:?}: {e:?}"))
}

/// Pad `x` (n×f), `y` (n), `w` (b×n) to the artifact shapes.
#[cfg(feature = "pjrt")]
fn pad_inputs(
    x: &Matrix,
    y: &[f64],
    w: &Matrix,
) -> crate::Result<(Vec<f32>, Vec<f32>, Vec<f32>, usize, usize, usize)> {
    let (n, f, b) = (x.rows(), x.cols(), w.rows());
    if n > N || f > F || b > B {
        bail!("problem ({n}x{f}, {b} masks) exceeds artifact shapes ({N}x{F}, {B})");
    }
    anyhow::ensure!(w.cols() == n && y.len() == n, "shape mismatch");
    let mut xp = vec![0f32; N * F];
    for i in 0..n {
        for j in 0..f {
            xp[i * F + j] = x[(i, j)] as f32;
        }
    }
    let mut yp = vec![0f32; N];
    for i in 0..n {
        yp[i] = y[i] as f32;
    }
    let mut wp = vec![0f32; B * N];
    for bi in 0..b {
        for j in 0..n {
            wp[bi * N + j] = w[(bi, j)] as f32;
        }
    }
    Ok((xp, yp, wp, n, f, b))
}

#[cfg(feature = "pjrt")]
fn run_fit(
    exe: &xla::PjRtLoadedExecutable,
    x: &Matrix,
    y: &[f64],
    w: &Matrix,
    lam: f64,
) -> crate::Result<(Matrix, Matrix)> {
    let (xp, yp, wp, n, f, b) = pad_inputs(x, y, w)?;
    let lx = literal_f32(&xp, &[N as i64, F as i64])?;
    let ly = literal_f32(&yp, &[N as i64])?;
    let lw = literal_f32(&wp, &[B as i64, N as i64])?;
    let ll = xla::Literal::scalar(lam.max(MIN_LAM) as f32);
    let result = exe
        .execute::<xla::Literal>(&[lx, ly, lw, ll])
        .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    let (t_lit, p_lit) =
        result.to_tuple2().map_err(|e| anyhow::anyhow!("tuple2: {e:?}"))?;
    let t_raw = t_lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let p_raw = p_lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    anyhow::ensure!(t_raw.len() == B * F && p_raw.len() == B * N, "bad output size");

    let mut theta = Matrix::zeros(b, f);
    for bi in 0..b {
        for j in 0..f {
            theta[(bi, j)] = t_raw[bi * F + j] as f64;
        }
    }
    let mut preds = Matrix::zeros(b, n);
    for bi in 0..b {
        for j in 0..n {
            preds[(bi, j)] = p_raw[bi * N + j] as f64;
        }
    }
    Ok((theta, preds))
}

#[cfg(feature = "pjrt")]
fn run_predict(
    exe: &xla::PjRtLoadedExecutable,
    theta: &Matrix,
    xq: &Matrix,
) -> crate::Result<Matrix> {
    let (b, f, q) = (theta.rows(), theta.cols(), xq.rows());
    if b > B || f > F || q > Q {
        bail!("predict_grid ({b}x{f}, {q} queries) exceeds artifact shapes");
    }
    anyhow::ensure!(xq.cols() == f, "feature arity mismatch");
    let mut tp = vec![0f32; B * F];
    for bi in 0..b {
        for j in 0..f {
            tp[bi * F + j] = theta[(bi, j)] as f32;
        }
    }
    let mut qp = vec![0f32; Q * F];
    for i in 0..q {
        for j in 0..f {
            qp[i * F + j] = xq[(i, j)] as f32;
        }
    }
    let lt = literal_f32(&tp, &[B as i64, F as i64])?;
    let lq = literal_f32(&qp, &[Q as i64, F as i64])?;
    let result = exe
        .execute::<xla::Literal>(&[lt, lq])
        .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    let p_lit = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let raw = p_lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    anyhow::ensure!(raw.len() == B * Q, "bad output size");
    let mut out = Matrix::zeros(b, q);
    for bi in 0..b {
        for j in 0..q {
            out[(bi, j)] = raw[bi * Q + j] as f64;
        }
    }
    Ok(out)
}
