//! Small dense solvers: Gauss-Jordan (partial pivot), Cholesky, ridge OLS
//! and projected-gradient NNLS — the native mirror of the L2 JAX graphs.

use anyhow::bail;

use super::Matrix;

/// Solve `A x = b` by Gauss-Jordan elimination with partial pivoting.
/// Mirrors `python/compile/model.py::gauss_jordan_solve` exactly (same
/// pivoting rule) so native and artifact paths agree to f32 tolerance.
pub fn gauss_jordan_solve(a: &Matrix, b: &[f64]) -> crate::Result<Vec<f64>> {
    let n = a.rows();
    if a.cols() != n || b.len() != n {
        bail!("gauss_jordan_solve: shape mismatch");
    }
    // Augmented system.
    let mut aug = Matrix::zeros(n, n + 1);
    for i in 0..n {
        for j in 0..n {
            aug[(i, j)] = a[(i, j)];
        }
        aug[(i, n)] = b[i];
    }
    for k in 0..n {
        // Partial pivot among rows >= k.
        let mut piv = k;
        let mut best = aug[(k, k)].abs();
        for r in (k + 1)..n {
            let v = aug[(r, k)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-300 {
            bail!("singular system at pivot {k}");
        }
        if piv != k {
            for j in 0..=n {
                let tmp = aug[(k, j)];
                aug[(k, j)] = aug[(piv, j)];
                aug[(piv, j)] = tmp;
            }
        }
        let pv = aug[(k, k)];
        for j in 0..=n {
            aug[(k, j)] /= pv;
        }
        for r in 0..n {
            if r == k {
                continue;
            }
            let f = aug[(r, k)];
            if f == 0.0 {
                continue;
            }
            for j in 0..=n {
                aug[(r, j)] -= f * aug[(k, j)];
            }
        }
    }
    Ok((0..n).map(|i| aug[(i, n)]).collect())
}

/// Cholesky solve for SPD systems (used where we know G ≻ 0; faster and
/// better conditioned than GJ for the Gram systems).
pub fn cholesky_solve(a: &Matrix, b: &[f64]) -> crate::Result<Vec<f64>> {
    let n = a.rows();
    if a.cols() != n || b.len() != n {
        bail!("cholesky_solve: shape mismatch");
    }
    // Lower-triangular factor.
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not positive definite at {i}");
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    // Forward then backward substitution.
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    Ok(x)
}

/// Ridge OLS: `theta = (X^T diag(w) X + lam I)^{-1} X^T diag(w) y`.
///
/// `w` is a per-row sample weight (1/0 for CV masks). Falls back from
/// Cholesky to Gauss-Jordan if the Gram matrix is numerically semidefinite.
pub fn ols_ridge(x: &Matrix, y: &[f64], w: &[f64], lam: f64) -> crate::Result<Vec<f64>> {
    let g = x.weighted_gram(w, lam);
    let c = x.weighted_xty(w, y);
    cholesky_solve(&g, &c).or_else(|_| gauss_jordan_solve(&g, &c))
}

/// Non-negative least squares via the fast active-set method of Bro & de
/// Jong (fNNLS, a normal-equation reformulation of Lawson-Hanson).
///
/// Exact (up to solver tolerance) — the native oracle for the L2 JAX
/// projected-gradient version, which approximates the same minimizer in a
/// fixed iteration budget.
pub fn nnls(x: &Matrix, y: &[f64], w: &[f64], lam: f64) -> crate::Result<Vec<f64>> {
    let g = x.weighted_gram(w, lam);
    let c = x.weighted_xty(w, y);
    let f = g.rows();
    let tol = 1e-10 * (1.0 + c.iter().fold(0.0f64, |a, b| a.max(b.abs())));

    let mut passive = vec![false; f];
    let mut theta = vec![0.0; f];

    // Solve the passive subsystem G[P,P] z = c[P].
    let solve_passive = |passive: &[bool], g: &Matrix, c: &[f64]| -> crate::Result<Vec<f64>> {
        let idx: Vec<usize> =
            (0..f).filter(|&i| passive[i]).collect();
        let k = idx.len();
        let mut gs = Matrix::zeros(k, k);
        let mut cs = vec![0.0; k];
        for (a, &i) in idx.iter().enumerate() {
            cs[a] = c[i];
            for (b, &j) in idx.iter().enumerate() {
                gs[(a, b)] = g[(i, j)];
            }
        }
        let z = cholesky_solve(&gs, &cs).or_else(|_| gauss_jordan_solve(&gs, &cs))?;
        let mut full = vec![0.0; f];
        for (a, &i) in idx.iter().enumerate() {
            full[i] = z[a];
        }
        Ok(full)
    };

    for _outer in 0..(3 * f + 10) {
        // Gradient of the active (zero) coordinates.
        let gt = g.matvec(&theta);
        let grad: Vec<f64> = c.iter().zip(&gt).map(|(ci, gi)| ci - gi).collect();
        let cand = (0..f)
            .filter(|&i| !passive[i] && grad[i] > tol)
            .max_by(|&a, &b| grad[a].partial_cmp(&grad[b]).unwrap());
        let Some(j) = cand else { break };
        passive[j] = true;

        // Inner loop: restore feasibility of the passive set.
        for _inner in 0..(3 * f + 10) {
            let z = solve_passive(&passive, &g, &c)?;
            let neg: Vec<usize> = (0..f)
                .filter(|&i| passive[i] && z[i] <= tol)
                .collect();
            if neg.is_empty() {
                theta = z;
                break;
            }
            // Step as far toward z as feasibility allows, drop hit bounds.
            let alpha = neg
                .iter()
                .map(|&i| theta[i] / (theta[i] - z[i]))
                .fold(f64::INFINITY, f64::min)
                .clamp(0.0, 1.0);
            for i in 0..f {
                if passive[i] {
                    theta[i] += alpha * (z[i] - theta[i]);
                    if theta[i] <= tol {
                        theta[i] = 0.0;
                        passive[i] = false;
                    }
                }
            }
        }
    }
    Ok(theta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;
    use crate::util::proptest::forall_res;

    fn random_spd(rng: &mut Pcg, n: usize) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.normal();
            }
        }
        let mut g = a.t().matmul(&a);
        for i in 0..n {
            g[(i, i)] += 0.1;
        }
        g
    }

    #[test]
    fn gj_known_system() {
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]).unwrap();
        let x = gauss_jordan_solve(&a, &[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn gj_requires_pivoting() {
        let a = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]).unwrap();
        let x = gauss_jordan_solve(&a, &[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn gj_rejects_singular() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]).unwrap();
        assert!(gauss_jordan_solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn cholesky_matches_gj_property() {
        forall_res(
            "cholesky == gauss-jordan on SPD",
            50,
            |rng| {
                let n = rng.range(1, 8);
                let g = random_spd(rng, n);
                let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (g, b)
            },
            |(g, b)| {
                let x1 = cholesky_solve(g, b)?;
                let x2 = gauss_jordan_solve(g, b)?;
                for (a, c) in x1.iter().zip(&x2) {
                    anyhow::ensure!((a - c).abs() < 1e-8, "{a} vs {c}");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]).unwrap();
        assert!(cholesky_solve(&a, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn ols_recovers_exact_linear_model() {
        let mut rng = Pcg::seed(3);
        let n = 40;
        let beta = [2.0, -1.5, 0.25];
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let r: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
            y.push(r.iter().zip(&beta).map(|(a, b)| a * b).sum());
            rows.push(r);
        }
        let x = Matrix::from_rows(&rows).unwrap();
        let w = vec![1.0; n];
        let theta = ols_ridge(&x, &y, &w, 1e-10).unwrap();
        for (t, b) in theta.iter().zip(&beta) {
            assert!((t - b).abs() < 1e-6, "{theta:?}");
        }
    }

    #[test]
    fn ols_respects_mask() {
        // Two populations; masking selects which one is fit.
        let x = Matrix::from_rows(&[vec![1.0], vec![1.0], vec![1.0], vec![1.0]]).unwrap();
        let y = [1.0, 1.0, 5.0, 5.0];
        let t_lo = ols_ridge(&x, &y, &[1.0, 1.0, 0.0, 0.0], 0.0).unwrap();
        let t_hi = ols_ridge(&x, &y, &[0.0, 0.0, 1.0, 1.0], 0.0).unwrap();
        assert!((t_lo[0] - 1.0).abs() < 1e-12);
        assert!((t_hi[0] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn nnls_clamps_negative_coefficients() {
        // y = -2*x: unconstrained OLS gives -2; NNLS must give 0.
        let mut rng = Pcg::seed(5);
        let rows: Vec<Vec<f64>> = (0..30).map(|_| vec![rng.f64() + 0.1]).collect();
        let y: Vec<f64> = rows.iter().map(|r| -2.0 * r[0]).collect();
        let x = Matrix::from_rows(&rows).unwrap();
        let theta = nnls(&x, &y, &vec![1.0; 30], 1e-8).unwrap();
        assert!(theta[0].abs() < 1e-9, "{theta:?}");
    }

    #[test]
    fn nnls_matches_ols_when_truth_nonneg() {
        forall_res(
            "nnls == ols for nonneg truth",
            30,
            |rng| {
                let n = rng.range(10, 40);
                let f = rng.range(1, 5);
                let beta: Vec<f64> = (0..f).map(|_| rng.f64() * 2.0 + 0.05).collect();
                let rows: Vec<Vec<f64>> = (0..n)
                    .map(|_| (0..f).map(|_| rng.f64() + 0.05).collect())
                    .collect();
                let y: Vec<f64> = rows
                    .iter()
                    .map(|r| r.iter().zip(&beta).map(|(a, b)| a * b).sum())
                    .collect();
                (rows, y, beta)
            },
            |(rows, y, beta)| {
                let x = Matrix::from_rows(rows).unwrap();
                let w = vec![1.0; rows.len()];
                let theta = nnls(&x, y, &w, 1e-10)?;
                for (t, b) in theta.iter().zip(beta) {
                    anyhow::ensure!((t - b).abs() < 1e-4, "{theta:?} vs {beta:?}");
                }
                Ok(())
            },
        );
    }
}
