//! Dense linear algebra for the native backend and feature engineering.
//!
//! Everything here operates on small matrices (the paper's feature spaces
//! are <= 8 columns); clarity and numerical robustness beat asymptotics.

pub mod matrix;
pub mod solve;

pub use matrix::Matrix;
pub use solve::{cholesky_solve, gauss_jordan_solve, nnls, ols_ridge};
