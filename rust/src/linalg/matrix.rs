//! Row-major dense f64 matrix.

use std::fmt;

use anyhow::bail;

/// Row-major dense matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (n x n).
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> crate::Result<Matrix> {
        if data.len() != rows * cols {
            bail!("matrix {}x{} needs {} elems, got {}", rows, cols, rows * cols, data.len());
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from nested rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> crate::Result<Matrix> {
        if rows.is_empty() {
            return Ok(Matrix::zeros(0, 0));
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            if r.len() != cols {
                bail!("ragged rows: {} vs {}", r.len(), cols);
            }
            data.extend_from_slice(r);
        }
        Ok(Matrix { rows: rows.len(), cols, data })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transpose.
    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix product `self * rhs`.
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, rhs.cols);
        // ikj loop order: streams rhs rows, vector-friendly.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let rrow = rhs.row(k);
                let orow = out.row_mut(i);
                for (o, &r) in orow.iter_mut().zip(rrow) {
                    *o += a * r;
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Gram matrix with per-row weights: `X^T diag(w) X + lam I`.
    pub fn weighted_gram(&self, w: &[f64], lam: f64) -> Matrix {
        assert_eq!(w.len(), self.rows);
        let f = self.cols;
        let mut g = Matrix::zeros(f, f);
        for (n, &wn) in w.iter().enumerate() {
            if wn == 0.0 {
                continue;
            }
            let row = self.row(n);
            for a in 0..f {
                let wa = wn * row[a];
                let grow = g.row_mut(a);
                for b in 0..f {
                    grow[b] += wa * row[b];
                }
            }
        }
        for i in 0..f {
            g[(i, i)] += lam;
        }
        g
    }

    /// `X^T (w .* y)`.
    pub fn weighted_xty(&self, w: &[f64], y: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.rows);
        assert_eq!(y.len(), self.rows);
        let mut c = vec![0.0; self.cols];
        for n in 0..self.rows {
            let wy = w[n] * y[n];
            if wy == 0.0 {
                continue;
            }
            for (ci, &xi) in c.iter_mut().zip(self.row(n)) {
                *ci += wy * xi;
            }
        }
        c
    }

    /// Max |a - b| over entries; panics on shape mismatch.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            writeln!(f, "  {:?}", self.row(i))?;
        }
        if self.rows > 8 {
            writeln!(f, "  ... ({} more rows)", self.rows - 8)?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]).unwrap();
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]).unwrap();
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn weighted_gram_matches_explicit() {
        let x = Matrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
        ])
        .unwrap();
        let w = vec![1.0, 0.0, 2.0];
        let g = x.weighted_gram(&w, 0.5);
        // X^T diag(w) X = [[1*1+2*25, 1*2+2*30],[., 4+2*36]]
        assert_eq!(g[(0, 0)], 51.0 + 0.5);
        assert_eq!(g[(0, 1)], 62.0);
        assert_eq!(g[(1, 0)], 62.0);
        assert_eq!(g[(1, 1)], 76.0 + 0.5);
    }

    #[test]
    fn weighted_xty_matches_explicit() {
        let x = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]).unwrap();
        let c = x.weighted_xty(&[2.0, 3.0], &[10.0, 20.0]);
        assert_eq!(c, vec![20.0, 60.0]);
    }

    #[test]
    fn matvec_identity() {
        let i = Matrix::eye(3);
        assert_eq!(i.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 2, vec![1.0]).is_err());
    }

    #[test]
    fn ragged_rows_rejected() {
        assert!(Matrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]).is_err());
    }
}
